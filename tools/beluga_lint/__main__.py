"""beluga-lint CLI.

    python -m tools.beluga_lint src/                 # run every pass
    python -m tools.beluga_lint --list               # pass catalog
    python -m tools.beluga_lint --pass lock_discipline src/
    python -m tools.beluga_lint --json src/          # machine output
    python -m tools.beluga_lint --emit-lock-graph graph.json src/
    python -m tools.beluga_lint --check-lock-log lock_logs/ src/

Exit status: 0 when every non-baselined finding count is zero (and, with
--check-lock-log, the combined static+runtime lock graph is acyclic and
the runtime recorded no inversions); 1 otherwise.  Baselines live in
``tools/beluga_lint/baselines/<pass>.txt`` (one fingerprint per line,
``#`` comments allowed) and ship EMPTY: CI enforces zero findings.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from tools.beluga_lint import PASSES, Finding, load_all_passes
from tools.beluga_lint.project import Project

_HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE_DIR = os.path.join(_HERE, "baselines")


def load_baseline(baseline_dir: str, pass_name: str) -> set[str]:
    path = os.path.join(baseline_dir, f"{pass_name}.txt")
    if not os.path.exists(path):
        return set()
    out = set()
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                out.add(line)
    return out


def write_baseline(baseline_dir: str, pass_name: str, findings) -> None:
    os.makedirs(baseline_dir, exist_ok=True)
    path = os.path.join(baseline_dir, f"{pass_name}.txt")
    with open(path, "w", encoding="utf-8") as f:
        f.write(f"# beluga-lint baseline for pass '{pass_name}'\n")
        f.write("# one finding fingerprint per line; keep EMPTY on main\n")
        for fp in sorted({x.fingerprint() for x in findings}):
            f.write(fp + "\n")


def emit_lock_graph(project: Project, path: str) -> None:
    from tools.beluga_lint.passes import lock_discipline

    decls, edges, _ = lock_discipline.build(project)
    payload = {
        "locks": [
            {
                "name": d.name, "blocking_ok": d.blocking_ok,
                "file": d.file, "line": d.line,
            }
            for d in decls
        ],
        "edges": sorted(list(e) for e in edges),
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"lock graph: {len(decls)} locks, {len(edges)} static edges "
          f"-> {path}")


def check_lock_log(project: Project, log_path: str) -> list[str]:
    """Merge runtime-recorded edges into the static graph; any inversion
    the sanitizer recorded, or a cycle in the combined graph, is an
    error.  ``log_path`` is one ``lock_order.<pid>.json`` dump or a
    directory of them (``BELUGA_SANITIZE_LOG``)."""
    from tools.beluga_lint.passes import lock_discipline

    paths = []
    if os.path.isdir(log_path):
        paths = [
            os.path.join(log_path, n)
            for n in sorted(os.listdir(log_path))
            if n.startswith("lock_order.") and n.endswith(".json")
        ]
    elif os.path.exists(log_path):
        paths = [log_path]
    if not paths:
        return [f"no lock-order logs found at {log_path}"]

    decls, static_edges, _ = lock_discipline.build(project)
    known = {d.name for d in decls}
    errors: list[str] = []
    combined = set(static_edges)
    runtime_edges = 0
    for p in paths:
        with open(p, encoding="utf-8") as f:
            dump = json.load(f)
        for v in dump.get("violations", []):
            errors.append(f"{os.path.basename(p)}: runtime inversion: {v}")
        for outer, inner in dump.get("edges", []):
            runtime_edges += 1
            combined.add((outer, inner))
            for n in (outer, inner):
                if n not in known:
                    errors.append(
                        f"{os.path.basename(p)}: runtime lock '{n}' has no "
                        "static declaration"
                    )
    cycle = lock_discipline.find_cycle(combined)
    if cycle:
        errors.append(
            "combined static+runtime lock graph has a cycle: "
            + " -> ".join(cycle)
        )
    print(
        f"lock log check: {len(paths)} dump(s), {runtime_edges} runtime "
        f"edge observation(s), {len(static_edges)} static edges, "
        f"{len(errors)} error(s)"
    )
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.beluga_lint",
        description="repo-specific static analysis for the Beluga repro",
    )
    ap.add_argument("paths", nargs="*", help="files/directories to scan")
    ap.add_argument("--pass", dest="passes", action="append", default=None,
                    metavar="NAME", help="run only this pass (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list registered passes and exit")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    ap.add_argument("--baseline-dir", default=DEFAULT_BASELINE_DIR)
    ap.add_argument("--update-baselines", action="store_true",
                    help="write current findings as the new baselines")
    ap.add_argument("--emit-lock-graph", metavar="FILE", default=None,
                    help="write the static lock graph (locks+edges) as JSON")
    ap.add_argument("--check-lock-log", metavar="PATH", default=None,
                    help="validate BELUGA_SANITIZE runtime dumps against "
                         "the static lock graph")
    args = ap.parse_args(argv)

    load_all_passes()
    if args.list:
        for name, info in sorted(PASSES.items()):
            first = info.doc.splitlines()[0] if info.doc else ""
            print(f"{name:20s} {first}")
        return 0

    if not args.paths:
        ap.error("no scan paths given (try: python -m tools.beluga_lint src/)")
    selected = args.passes or sorted(PASSES)
    for name in selected:
        if name not in PASSES:
            ap.error(f"unknown pass {name!r} (see --list)")

    project = Project.load(args.paths)
    all_findings: list[Finding] = []
    new_findings: list[Finding] = []
    baselined = 0
    for name in selected:
        findings = PASSES[name].run(project)
        all_findings.extend(findings)
        if args.update_baselines:
            write_baseline(args.baseline_dir, name, findings)
            continue
        baseline = load_baseline(args.baseline_dir, name)
        for f in findings:
            if f.fingerprint() in baseline:
                baselined += 1
            else:
                new_findings.append(f)

    errors: list[str] = []
    if args.emit_lock_graph:
        emit_lock_graph(project, args.emit_lock_graph)
    if args.check_lock_log:
        errors = check_lock_log(project, args.check_lock_log)

    if args.update_baselines:
        print(f"baselines updated for {len(selected)} pass(es) in "
              f"{args.baseline_dir}")
        return 0

    if args.json:
        print(json.dumps(
            {
                "findings": [
                    {
                        "pass": f.pass_name, "rule": f.rule, "file": f.file,
                        "line": f.line, "message": f.message,
                    }
                    for f in new_findings
                ],
                "baselined": baselined,
                "lock_log_errors": errors,
            },
            indent=2,
        ))
    else:
        for f in sorted(
            new_findings, key=lambda x: (x.file, x.line, x.rule)
        ):
            print(f.render())
        for e in errors:
            print(f"lock-log: {e}")
        note = f" ({baselined} baselined)" if baselined else ""
        status = "clean" if not (new_findings or errors) else "FAILED"
        print(
            f"beluga-lint: {len(project.modules)} file(s), "
            f"{len(selected)} pass(es), {len(new_findings)} finding(s)"
            f"{note} — {status}"
        )
    return 1 if (new_findings or errors) else 0


if __name__ == "__main__":
    sys.exit(main())

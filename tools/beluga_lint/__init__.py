"""beluga-lint: repo-specific static analysis for the Beluga repro.

The invariants that keep this multi-process shared-memory plane correct
— wire-protocol coverage, the creator-unlinks shm lifecycle, lock
ordering, exception hygiene — are checked by AST passes registered
here and run from one CLI:

    python -m tools.beluga_lint src/

Each pass is a function ``(Project) -> list[Finding]`` registered with
``@register_pass``.  Baselines (``baselines/<pass>.txt``, one finding
fingerprint per line) suppress known findings; the repo ships every
baseline EMPTY and CI enforces zero findings — the mechanism exists so
a future emergency can land with a documented, reviewable suppression
instead of deleting the gate.

The runtime companion is ``repro.core.locks`` (``BELUGA_SANITIZE=1``),
which records actual lock-acquisition orders; ``--check-lock-log``
asserts those against the static graph this package derives.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    pass_name: str  # registered pass name, e.g. "lock_discipline"
    rule: str  # stable rule id, e.g. "L003"
    file: str  # path relative to the scan root
    line: int  # 1-based source line
    message: str

    def fingerprint(self) -> str:
        """Line-number-free identity (baselines survive unrelated edits)."""
        return f"{self.pass_name}:{self.rule}:{self.file}:{self.message}"

    def render(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class PassInfo:
    name: str
    run: object  # callable(Project) -> list[Finding]
    doc: str = field(default="")


PASSES: dict[str, PassInfo] = {}


def register_pass(name: str):
    """Decorator: register ``fn(project) -> list[Finding]`` under ``name``."""

    def deco(fn):
        PASSES[name] = PassInfo(name=name, run=fn, doc=(fn.__doc__ or "").strip())
        return fn

    return deco


def load_all_passes() -> None:
    """Import every pass module (side effect: registration)."""
    from tools.beluga_lint.passes import (  # noqa: F401
        exception_hygiene,
        lock_discipline,
        shm_lifecycle,
        wire_protocol,
    )

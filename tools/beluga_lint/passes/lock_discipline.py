"""Lock discipline (rules L001-L003) + the static lock-order graph.

Every lock in the plane is declared through ``repro.core.locks.make_lock``
with a globally unique name and an explicit blocking policy.  This pass
reads those declarations, simulates held-lock stacks through each
function (resolving callees through ``self``-methods, module functions,
constructor-assigned attributes and parameter annotations, three levels
deep) and derives the static acquisition-order graph the runtime
sanitizer (``BELUGA_SANITIZE=1``) is checked against.

  L001  raw ``threading.Lock()`` / ``RLock()`` outside ``locks.py`` —
        undeclared locks are invisible to ordering analysis
  L002  cycle in the lock-acquisition-order graph (deadlock shape)
  L003  blocking call (sleep / join / collect / post / wait / poll /
        select / call) reachable while a lock declared WITHOUT
        ``blocking_ok=True`` is held

``build(project)`` returns ``(decls, edges, findings)`` so the CLI can
emit the graph (``--emit-lock-graph``) and merge in runtime-observed
edges (``--check-lock-log``) without re-running the pass.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass

from tools.beluga_lint import Finding, register_pass
from tools.beluga_lint.project import (
    Module,
    Project,
    annotation_name,
    call_name,
    dotted,
    iter_functions,
)

PASS = "lock_discipline"

# Callee names that park the calling thread (or can, under load).
# ``time.sleep(0)`` — the GIL-yield idiom — is exempted at the call site.
BLOCKING_NAMES = frozenset({
    "sleep", "join", "collect", "post", "wait", "wait_ready",
    "select", "poll", "call",
})
MAX_DEPTH = 3


@dataclass(frozen=True)
class LockDecl:
    name: str  # make_lock() declared name (globally unique)
    blocking_ok: bool
    file: str
    cls: str  # declaring class ("" for module level)
    attr: str  # attribute the lock is bound to ("" if not self.X)
    line: int


def _finding(rule: str, file: str, line: int, msg: str) -> Finding:
    return Finding(PASS, rule, file, line, msg)


def _is_make_lock(node: ast.expr) -> ast.Call | None:
    if isinstance(node, ast.Call) and call_name(node) == "make_lock":
        return node
    return None


# ---------------------------------------------------------------------------
# collection: declarations, class attr maps, type inference tables
# ---------------------------------------------------------------------------
class _World:
    """Everything the simulation needs to resolve names across modules."""

    def __init__(self, project: Project):
        self.project = project
        self.decls: list[LockDecl] = []
        self.findings: list[Finding] = []
        # (class name, attr) -> decl ; attr -> [decls] for the unique-attr
        # fallback (e.g. ``ledger.mutex`` with no type information)
        self.class_attr: dict[tuple[str, str], LockDecl] = {}
        self.attr_decls: dict[str, list[LockDecl]] = {}
        self.classes = project.class_index()
        # (class name, attr) -> type name, from ``self.X = ClassName(...)``
        # or ``self.X = <param>`` with an annotated __init__ param
        self.attr_types: dict[tuple[str, str], str] = {}
        self._collect()

    def _collect(self) -> None:
        for mod in self.project.modules:
            self._collect_module(mod)

    def _collect_module(self, mod: Module) -> None:
        in_locks_py = mod.name == "locks.py"
        cls_of: dict[int, str] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                for sub in ast.walk(node):
                    cls_of.setdefault(id(sub), node.name)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                name = call_name(node)
                recv = (
                    dotted(node.func.value)
                    if isinstance(node.func, ast.Attribute) else ""
                )
                if (
                    name in ("Lock", "RLock")
                    and recv in ("", "threading")
                    and not in_locks_py
                ):
                    self.findings.append(_finding(
                        "L001", mod.relpath, node.lineno,
                        "raw threading lock — declare it via "
                        "repro.core.locks.make_lock so ordering analysis "
                        "and the sanitizer can see it",
                    ))
            if not isinstance(node, ast.Assign):
                continue
            cls_name = cls_of.get(id(node), "")
            for target in node.targets:
                attr = self._self_attr(target)
                mk = _is_make_lock(node.value)
                if mk is not None:
                    self._add_decl(mod, cls_name, attr or "", node, mk)
                elif attr and cls_name:
                    t = self._value_type(node.value, mod, cls_name)
                    if t:
                        self.attr_types[(cls_name, attr)] = t

    @staticmethod
    def _self_attr(target: ast.expr) -> str | None:
        if (
            isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name)
            and target.value.id == "self"
        ):
            return target.attr
        return None

    def _value_type(self, value: ast.expr, mod: Module, cls_name: str) -> str:
        """Type of an assigned value: constructor call or annotated param."""
        if isinstance(value, ast.Call):
            n = call_name(value)
            if n in self.classes:
                return n
        if isinstance(value, ast.Name):
            # ``self.X = param``: look up the annotation on the enclosing
            # __init__ (the only method whose params flow to attributes
            # in this codebase's idiom)
            entry = self.classes.get(cls_name)
            if entry is not None:
                _, cls_node = entry
                for fn in iter_functions(cls_node):
                    if fn.name != "__init__":
                        continue
                    for a in fn.args.args + fn.args.kwonlyargs:
                        if a.arg == value.id:
                            t = annotation_name(a.annotation)
                            if t in self.classes:
                                return t
        return ""

    def _add_decl(self, mod, cls_name: str, attr: str, assign, call) -> None:
        if not (call.args and isinstance(call.args[0], ast.Constant)):
            return
        lock_name = str(call.args[0].value)
        blocking_ok = any(
            kw.arg == "blocking_ok"
            and isinstance(kw.value, ast.Constant)
            and kw.value.value is True
            for kw in call.keywords
        )
        decl = LockDecl(
            name=lock_name, blocking_ok=blocking_ok, file=mod.relpath,
            cls=cls_name, attr=attr, line=assign.lineno,
        )
        self.decls.append(decl)
        if cls_name and attr:
            self.class_attr[(cls_name, attr)] = decl
            self.attr_decls.setdefault(attr, []).append(decl)

    # -- resolution ------------------------------------------------------
    def lock_of_expr(
        self, expr: ast.expr, cls_name: str,
        local_types: dict[str, str] | None = None,
        param_types: dict[str, str] | None = None,
    ) -> LockDecl | None:
        """Resolve a ``with`` subject to a declared lock, or None."""
        if not isinstance(expr, ast.Attribute):
            return None
        attr = expr.attr
        recv = dotted(expr.value)
        if recv == "self" and (cls_name, attr) in self.class_attr:
            return self.class_attr[(cls_name, attr)]
        if recv.startswith("self.") and "." not in recv[5:]:
            t = self.attr_types.get((cls_name, recv[5:]), "")
            if (t, attr) in self.class_attr:
                return self.class_attr[(t, attr)]
        if recv and "." not in recv:
            t = (local_types or {}).get(recv) or (param_types or {}).get(recv, "")
            if (t, attr) in self.class_attr:
                return self.class_attr[(t, attr)]
        hits = self.attr_decls.get(attr, [])
        if len(hits) == 1:
            return hits[0]
        return None

    def resolve_callee(
        self, call: ast.Call, mod: Module, cls_name: str,
        local_types: dict[str, str], param_types: dict[str, str],
    ) -> tuple[Module, str, ast.AST] | None:
        """Map a call to (module, class name, FunctionDef) when possible."""
        func = call.func
        if isinstance(func, ast.Name):
            fns = self.project.module_functions(mod)
            if func.id in fns:
                return (mod, "", fns[func.id])
            return None
        if not isinstance(func, ast.Attribute):
            return None
        meth = func.attr
        recv = dotted(func.value)
        if recv == "self" and cls_name:
            return self._method(cls_name, meth)
        type_name = ""
        if recv.startswith("self.") and "." not in recv[5:]:
            type_name = self.attr_types.get((cls_name, recv[5:]), "")
        elif recv and "." not in recv:
            type_name = local_types.get(recv) or param_types.get(recv, "")
        if type_name:
            return self._method(type_name, meth)
        return None

    def _method(self, cls_name: str, meth: str):
        entry = self.classes.get(cls_name)
        if entry is None:
            return None
        mod, cls_node = entry
        for fn in iter_functions(cls_node):
            if fn.name == meth:
                return (mod, cls_name, fn)
        # ``on_retain = on_alloc``-style method aliases
        for node in cls_node.body:
            if (
                isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Name)
                and any(
                    isinstance(t, ast.Name) and t.id == meth
                    for t in node.targets
                )
            ):
                return self._method(cls_name, node.value.id)
        return None


# ---------------------------------------------------------------------------
# simulation: held-stack walk of every function
# ---------------------------------------------------------------------------
class _Simulator:
    def __init__(self, world: _World):
        self.world = world
        self.edges: set[tuple[str, str]] = set()  # (outer, inner) by name
        self.edge_sites: dict[tuple[str, str], tuple[str, int]] = {}
        self.findings: list[Finding] = []
        self._summary_cache: dict[tuple[int, int], tuple] = {}

    # -- function summaries (for callee effects) -------------------------
    def summary(self, mod, cls_name, fn, depth) -> tuple[set, list]:
        """(locks acquired within, blocking call sites within), with
        callee effects folded in down to ``depth`` more levels."""
        key = (id(fn), depth)
        hit = self._summary_cache.get(key)
        if hit is not None:
            return hit
        self._summary_cache[key] = (set(), [])  # recursion guard
        locks: set[str] = set()
        blocking: list[tuple[str, int]] = []
        param_types = self._param_types(fn)
        local_types = self._local_types(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.With):
                for item in node.items:
                    d = self.world.lock_of_expr(
                        item.context_expr, cls_name, local_types, param_types
                    )
                    if d is not None:
                        locks.add(d.name)
            elif isinstance(node, ast.Call):
                name = call_name(node)
                if name in BLOCKING_NAMES and not _is_yield_sleep(node):
                    blocking.append((name, node.lineno))
                elif depth > 0:
                    resolved = self.world.resolve_callee(
                        node, mod, cls_name, local_types, param_types
                    )
                    if resolved is not None:
                        cl, cb = self.summary(*resolved, depth - 1)
                        locks |= cl
                        blocking.extend(cb)
        self._summary_cache[key] = (locks, blocking)
        return locks, blocking

    def _param_types(self, fn) -> dict[str, str]:
        out = {}
        for a in fn.args.args + fn.args.kwonlyargs:
            t = annotation_name(a.annotation)
            if t in self.world.classes:
                out[a.arg] = t
        return out

    def _local_types(self, fn) -> dict[str, str]:
        out = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                n = call_name(node.value)
                if n in self.world.classes:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out[t.id] = n
        return out

    # -- held-stack walk -------------------------------------------------
    def run(self) -> None:
        for mod in self.world.project.modules:
            for fn in mod.tree.body:
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._walk_fn(mod, "", fn)
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ClassDef):
                    for fn in iter_functions(node):
                        self._walk_fn(mod, node.name, fn)

    def _walk_fn(self, mod, cls_name, fn) -> None:
        ctx = {
            "mod": mod, "cls": cls_name, "fn": fn,
            "params": self._param_types(fn),
            "locals": self._local_types(fn),
        }
        self._walk_stmts(fn.body, [], ctx)

    def _walk_stmts(self, stmts, held: list[LockDecl], ctx) -> None:
        for stmt in stmts:
            if isinstance(stmt, ast.With):
                acquired = []
                for item in stmt.items:
                    d = self.world.lock_of_expr(
                        item.context_expr, ctx["cls"],
                        ctx["locals"], ctx["params"],
                    )
                    if d is not None:
                        for h in held + acquired:
                            self._edge(h, d, ctx["mod"], stmt.lineno)
                        acquired.append(d)
                self._walk_stmts(stmt.body, held + acquired, ctx)
                continue
            # non-with statements: scan calls in this statement's own
            # expressions, then recurse into nested suites with the SAME
            # held stack (if/for/while/try bodies don't change holding)
            for expr in _stmt_exprs(stmt):
                for node in ast.walk(expr):
                    if isinstance(node, ast.Call):
                        self._check_call(node, held, ctx)
            for suite in _stmt_suites(stmt):
                self._walk_stmts(suite, held, ctx)

    def _edge(self, outer: LockDecl, inner: LockDecl, mod, line) -> None:
        if outer.name == inner.name:
            return
        e = (outer.name, inner.name)
        if e not in self.edges:
            self.edges.add(e)
            self.edge_sites[e] = (mod.relpath, line)

    def _check_call(self, node: ast.Call, held, ctx) -> None:
        if not held:
            return
        name = call_name(node)
        strict = [h for h in held if not h.blocking_ok]
        if name in BLOCKING_NAMES and not _is_yield_sleep(node):
            if strict:
                self.findings.append(_finding(
                    "L003", ctx["mod"].relpath, node.lineno,
                    f"blocking call '{name}' while holding "
                    f"{strict[-1].name} (declared non-blocking)",
                ))
            return
        resolved = self.world.resolve_callee(
            node, ctx["mod"], ctx["cls"], ctx["locals"], ctx["params"]
        )
        if resolved is None:
            return
        locks, blocking = self.summary(*resolved, MAX_DEPTH - 1)
        for lname in locks:
            inner = next(
                (d for d in self.world.decls if d.name == lname), None
            )
            if inner is not None:
                for h in held:
                    self._edge(h, inner, ctx["mod"], node.lineno)
        if strict and blocking:
            bname, bline = blocking[0]
            self.findings.append(_finding(
                "L003", ctx["mod"].relpath, node.lineno,
                f"call '{name}' reaches blocking '{bname}' while holding "
                f"{strict[-1].name} (declared non-blocking)",
            ))


def _is_yield_sleep(node: ast.Call) -> bool:
    """``time.sleep(0)`` is a GIL yield, not a park."""
    return (
        call_name(node) == "sleep"
        and len(node.args) == 1
        and isinstance(node.args[0], ast.Constant)
        and node.args[0].value == 0
    )


def _stmt_exprs(stmt: ast.stmt):
    """Expressions belonging to ``stmt`` itself (not its nested suites)."""
    for field_name, value in ast.iter_fields(stmt):
        if field_name in ("body", "orelse", "finalbody", "handlers"):
            continue
        if isinstance(value, ast.expr):
            yield value
        elif isinstance(value, list):
            for v in value:
                if isinstance(v, ast.expr):
                    yield v


def _stmt_suites(stmt: ast.stmt):
    for field_name in ("body", "orelse", "finalbody"):
        suite = getattr(stmt, field_name, None)
        if suite:
            yield suite
    for h in getattr(stmt, "handlers", None) or []:
        yield h.body


# ---------------------------------------------------------------------------
# cycles
# ---------------------------------------------------------------------------
def find_cycle(edges: set[tuple[str, str]]) -> list[str] | None:
    """One cycle as a node list (first == last), or None if acyclic."""
    graph: dict[str, list[str]] = {}
    for a, b in sorted(edges):
        graph.setdefault(a, []).append(b)
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in graph}
    stack: list[str] = []

    def dfs(n: str) -> list[str] | None:
        color[n] = GREY
        stack.append(n)
        for m in graph.get(n, []):
            c = color.get(m, WHITE)
            if c == GREY:
                return stack[stack.index(m):] + [m]
            if c == WHITE:
                found = dfs(m)
                if found:
                    return found
        stack.pop()
        color[n] = BLACK
        return None

    for n in list(graph):
        if color.get(n, 0) == WHITE:
            found = dfs(n)
            if found:
                return found
    return None


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def build(project: Project):
    """(decls, edges, findings) — reused by --emit-lock-graph and
    --check-lock-log in the CLI."""
    world = _World(project)
    sim = _Simulator(world)
    sim.run()
    findings = list(world.findings) + list(sim.findings)
    cycle = find_cycle(sim.edges)
    if cycle:
        e = (cycle[0], cycle[1])
        file, line = sim.edge_sites.get(e, ("<graph>", 0))
        findings.append(_finding(
            "L002", file, line,
            "lock-order cycle: " + " -> ".join(cycle),
        ))
    return world.decls, sim.edges, findings


@register_pass(PASS)
def run(project: Project) -> list[Finding]:
    """Declared locks only; acyclic order; no blocking under strict locks."""
    _decls, _edges, findings = build(project)
    return findings

"""Wire-protocol conformance (rules W001-W008).

Extracts the ``OP_*`` registry from any module that defines one (in this
repo: ``repro/core/wire.py``) and the ``WCMD_*`` worker-command registry
(``repro/serving/engineproc.py``) and proves every opcode is fully
plumbed.  A new ``OP_FOO = 21`` without a handler branch, reply-bound
entry, encoder — and, for ops that carry block ids, a ``prevalidate``
branch — fails here before any test would notice.

  W001  duplicate opcode value inside one registry
  W002  op has no handler branch (no ``op == OP_X`` in any ``handle_*``)
  W003  op missing from every ``*reply_bound`` sizing function
  W004  index-plane op carries block ids but has no ``prevalidate`` branch
  W005  op has no ``encode_*`` function packing it
  W006  dispatcher compares the op against a bare integer literal
  W007  worker command (WCMD) with no handler branch
  W008  worker command never packed/encoded anywhere
"""

from __future__ import annotations

import ast

from tools.beluga_lint import Finding, register_pass
from tools.beluga_lint.project import (
    Project,
    compared_names,
    const_int_assigns,
    referenced_names,
)

PASS = "wire_protocol"


def _finding(rule: str, mod, line: int, msg: str) -> Finding:
    return Finding(PASS, rule, mod.relpath, line, msg)


def _dup_values(consts: dict, mod, out: list, rule: str) -> None:
    by_val: dict[int, list[str]] = {}
    for name, (val, _line) in consts.items():
        by_val.setdefault(val, []).append(name)
    for val, names in sorted(by_val.items()):
        if len(names) > 1:
            line = min(consts[n][1] for n in names)
            out.append(_finding(
                rule, mod, line,
                f"duplicate opcode value {val}: {', '.join(sorted(names))}",
            ))


def _functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    return {
        n.name: n for n in tree.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }


def _check_op_registry(mod, out: list[Finding]) -> None:
    consts = const_int_assigns(mod.tree, "OP_")
    if len(consts) < 2:
        return
    names = set(consts)
    funcs = _functions(mod.tree)
    handlers = {n: f for n, f in funcs.items() if n.startswith("handle_")}
    bounds = {n: f for n, f in funcs.items() if n.endswith("reply_bound")}
    prevalidate = funcs.get("prevalidate")
    encoders = {n: f for n, f in funcs.items() if n.startswith("encode_")}

    _dup_values(consts, mod, out, "W001")

    handled: set[str] = set()
    for f in handlers.values():
        handled |= compared_names(f, names)
    bounded: set[str] = set()
    index_plane: set[str] = set()
    for bname, f in bounds.items():
        ops = compared_names(f, names)
        bounded |= ops
        if bname == "reply_bound":
            index_plane |= ops
    prechecked = (
        compared_names(prevalidate, names) if prevalidate is not None else set()
    )

    # op -> encoder functions that reference it; and whether any encoder
    # for the op takes an ids-shaped parameter (block ids cross the wire)
    op_encoders: dict[str, list[ast.FunctionDef]] = {n: [] for n in names}
    for f in encoders.values():
        for op in referenced_names(f, names):
            op_encoders[op].append(f)

    for name in sorted(names):
        _val, line = consts[name]
        if name not in handled:
            out.append(_finding(
                "W002", mod, line,
                f"{name} has no handler branch in any handle_* dispatcher",
            ))
        if name not in bounded:
            out.append(_finding(
                "W003", mod, line,
                f"{name} missing from every reply_bound sizing function",
            ))
        if not op_encoders[name]:
            out.append(_finding(
                "W005", mod, line,
                f"{name} has no encode_* function (orphaned opcode)",
            ))
        if name in index_plane and name not in prechecked:
            carries_ids = any(
                arg.arg == "ids" or arg.arg.endswith("_ids")
                for f in op_encoders[name]
                for arg in (f.args.args + f.args.kwonlyargs)
            )
            if carries_ids:
                out.append(_finding(
                    "W004", mod, line,
                    f"{name} carries block ids but has no prevalidate "
                    "range-check branch",
                ))

    # W006: dispatchers must compare against registry names, not literals
    dispatchers = list(handlers.values()) + list(bounds.values())
    if prevalidate is not None:
        dispatchers.append(prevalidate)
    known_values = {v for v, _l in consts.values()}
    for f in dispatchers:
        for node in ast.walk(f):
            if not isinstance(node, ast.Compare):
                continue
            target = node.left
            if not (isinstance(target, ast.Name) and target.id == "op"):
                continue
            for comp in node.comparators:
                if (
                    isinstance(comp, ast.Constant)
                    and isinstance(comp.value, int)
                ):
                    tag = (
                        "an unregistered" if comp.value not in known_values
                        else "a bare"
                    )
                    out.append(_finding(
                        "W006", mod, node.lineno,
                        f"{f.name} compares op against {tag} integer "
                        f"literal {comp.value}; use the OP_* constant",
                    ))


def _check_wcmd_registry(mod, out: list[Finding]) -> None:
    consts = const_int_assigns(mod.tree, "WCMD_")
    if len(consts) < 2:
        return
    names = set(consts)
    _dup_values(consts, mod, out, "W001")

    handled = compared_names(mod.tree, names)
    # encoded: packed into a frame (``_HDR.pack(WCMD_X, ...)`` or any
    # call argument) anywhere OUTSIDE a comparison
    encoded: set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            for arg in node.args:
                for ref in ast.walk(arg):
                    if isinstance(ref, ast.Name) and ref.id in names:
                        encoded.add(ref.id)

    for name in sorted(names):
        _val, line = consts[name]
        if name not in handled:
            out.append(_finding(
                "W007", mod, line,
                f"{name} has no worker-handler branch (no comparison "
                "against it anywhere in the module)",
            ))
        if name not in encoded:
            out.append(_finding(
                "W008", mod, line,
                f"{name} is never packed into a command frame",
            ))


@register_pass(PASS)
def run(project: Project) -> list[Finding]:
    """Opcode registries fully plumbed: handler, bound, codec, prevalidate."""
    out: list[Finding] = []
    for mod in project.modules:
        _check_op_registry(mod, out)
        _check_wcmd_registry(mod, out)
    return out

"""Shared-memory lifecycle (rules S001-S005).

The plane's shm contract is *creator-unlinks, attacher-never-unlinks*:
exactly one process (the creator of a named segment / FIFO) may remove
the name; every attacher only drops its mapping.  Violations corrupt
peers (early unlink) or leak ``/dev/shm`` entries (no unlink).  This
pass proves the contract structurally:

  S001  ``close_segment`` called without an explicit ``unlink=`` kwarg
  S002  attach-derived segment closed with literal ``unlink=True``
  S003  raw ``.unlink()`` outside ``close_segment`` / unguarded
        ``os.unlink`` in an ``_owner``-discriminated class
  S004  creator call's handle discarded (bare expression statement)
  S005  created segment with no reachable teardown (attribute never
        closed by any method; local that never escapes or closes)

Creator calls: ``create_segment``, ``ShmRing.create_shared``,
``<Class>.create`` (Doorbell, ShardJournal), ``SharedMemory(create=True)``.
Attach calls: ``attach_segment``, ``<Class>.attach``.  Flow tracking is
one-step on purpose — the repo's idiom is ``seg = create_segment(...)``
followed immediately by ``self._seg = seg`` / ``return cls(..., seg)``,
and keeping the analysis shallow keeps its verdicts explainable.
"""

from __future__ import annotations

import ast

from tools.beluga_lint import Finding, register_pass
from tools.beluga_lint.project import Project, call_name, dotted, iter_functions

PASS = "shm_lifecycle"

CREATE, ATTACH = "create", "attach"


def _finding(rule: str, mod, line: int, msg: str) -> Finding:
    return Finding(PASS, rule, mod.relpath, line, msg)


def _call_kind(node: ast.expr) -> str | None:
    """CREATE/ATTACH when ``node`` is a recognized lifecycle call."""
    if not isinstance(node, ast.Call):
        return None
    name = call_name(node)
    recv = dotted(node.func.value) if isinstance(node.func, ast.Attribute) else ""
    class_recv = bool(recv) and recv[:1].isupper()
    if name in ("create_segment", "create_shared"):
        return CREATE
    if name == "create" and class_recv:
        return CREATE
    if name == "SharedMemory":
        for kw in node.keywords:
            if (
                kw.arg == "create"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is True
            ):
                return CREATE
        return None
    if name == "attach_segment" or (name == "attach" and class_recv):
        return ATTACH
    return None


def _value_kinds(value: ast.expr) -> set[str]:
    """Lifecycle kinds an assigned value may carry (IfExp checks arms)."""
    kinds: set[str] = set()
    if isinstance(value, ast.IfExp):
        kinds |= _value_kinds(value.body)
        kinds |= _value_kinds(value.orelse)
        return kinds
    k = _call_kind(value)
    if k:
        kinds.add(k)
    return kinds


def _assign_pairs(node: ast.Assign):
    """Yield (target, value) pairs, unpacking parallel tuple assigns."""
    for target in node.targets:
        if (
            isinstance(target, ast.Tuple)
            and isinstance(node.value, ast.Tuple)
            and len(target.elts) == len(node.value.elts)
        ):
            yield from zip(target.elts, node.value.elts)
        else:
            yield target, node.value


def _self_attr(expr: ast.expr) -> str | None:
    if (
        isinstance(expr, ast.Attribute)
        and isinstance(expr.value, ast.Name)
        and expr.value.id == "self"
    ):
        return expr.attr
    return None


def _init_param_names(cls: ast.ClassDef) -> list[str]:
    for fn in iter_functions(cls):
        if fn.name == "__init__":
            pos = [a.arg for a in fn.args.args[1:]]  # skip self
            kw = [a.arg for a in fn.args.kwonlyargs]
            return pos + kw
    return []


def _init_attr_of_param(cls: ast.ClassDef) -> dict[str, str]:
    """``__init__`` flows ``param -> self.attr`` (direct assigns only)."""
    out: dict[str, str] = {}
    for fn in iter_functions(cls):
        if fn.name != "__init__":
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            for tgt, val in _assign_pairs(node):
                attr = _self_attr(tgt)
                if attr and isinstance(val, ast.Name):
                    out[val.id] = attr
    return out


class _ClassFacts:
    """Per-class segment-attribute ledger: sources + teardowns."""

    def __init__(self, mod, cls: ast.ClassDef):
        self.mod = mod
        self.cls = cls
        self.attr_sources: dict[str, set[str]] = {}
        self.attr_lines: dict[str, int] = {}
        self.torn_down: set[str] = set()
        self._collect()

    def _note_attr(self, attr: str, kinds: set[str], line: int) -> None:
        if not kinds:
            return
        self.attr_sources.setdefault(attr, set()).update(kinds)
        self.attr_lines.setdefault(attr, line)

    def _collect(self) -> None:
        init_params = _init_param_names(self.cls)
        param_attr = _init_attr_of_param(self.cls)
        for fn in iter_functions(self.cls):
            local_kinds: dict[str, set[str]] = {}
            local_alias: dict[str, str] = {}  # local <- self.attr
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign):
                    for tgt, val in _assign_pairs(node):
                        kinds = _value_kinds(val)
                        if isinstance(val, ast.Name) and val.id in local_kinds:
                            kinds = kinds | local_kinds[val.id]
                        attr = _self_attr(tgt)
                        if attr is not None:
                            self._note_attr(attr, kinds, node.lineno)
                            src_attr = _self_attr(val)
                            if (
                                isinstance(tgt, ast.Name)
                                and src_attr is not None
                            ):
                                local_alias[tgt.id] = src_attr
                        elif isinstance(tgt, ast.Name):
                            if kinds:
                                local_kinds[tgt.id] = kinds
                            src_attr = _self_attr(val)
                            if src_attr is not None:
                                local_alias[tgt.id] = src_attr
                if isinstance(node, ast.Call):
                    self._scan_call(node, fn, init_params, param_attr,
                                    local_kinds, local_alias)

    def _scan_call(self, node, fn, init_params, param_attr,
                   local_kinds, local_alias) -> None:
        name = call_name(node)
        # constructor flow: cls(seg, ...) / ClassName(seg, ...) inside a
        # classmethod routes a created handle into an __init__ param
        is_ctor = (
            isinstance(node.func, ast.Name)
            and node.func.id in ("cls", self.cls.name)
        )
        if is_ctor:
            def arg_kinds(a: ast.expr) -> set[str]:
                k = _value_kinds(a)
                if isinstance(a, ast.Name):
                    k = k | local_kinds.get(a.id, set())
                return k

            for i, a in enumerate(node.args):
                if i < len(init_params):
                    attr = param_attr.get(init_params[i])
                    if attr:
                        self._note_attr(attr, arg_kinds(a), node.lineno)
            for kw in node.keywords:
                attr = param_attr.get(kw.arg or "")
                if attr:
                    self._note_attr(attr, arg_kinds(kw.value), node.lineno)
        # teardowns ----------------------------------------------------
        if name == "close_segment" and node.args:
            a0 = node.args[0]
            attr = _self_attr(a0)
            if attr is None and isinstance(a0, ast.Name):
                attr = local_alias.get(a0.id)
            if attr:
                self.torn_down.add(attr)
        elif name in ("close", "unshare_meta", "unshare_data"):
            recv = (
                node.func.value
                if isinstance(node.func, ast.Attribute) else None
            )
            if recv is not None:
                attr = _self_attr(recv)
                if attr is None and isinstance(recv, ast.Name):
                    attr = local_alias.get(recv.id)
                if attr:
                    self.torn_down.add(attr)


def _attach_only_targets(mod, facts_by_class: dict) -> dict[str, set[str]]:
    """class name -> attrs whose ONLY source is attach (S002 targets)."""
    out: dict[str, set[str]] = {}
    for cname, facts in facts_by_class.items():
        out[cname] = {
            a for a, srcs in facts.attr_sources.items() if srcs == {ATTACH}
        }
    return out


def _check_module(mod, project: Project, out: list[Finding]) -> None:
    facts_by_class: dict[str, _ClassFacts] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef):
            facts_by_class[node.name] = _ClassFacts(mod, node)

    attach_only = _attach_only_targets(mod, facts_by_class)

    class _Visitor(ast.NodeVisitor):
        def __init__(self):
            self.cls_stack: list[str] = []
            self.fn_stack: list[ast.FunctionDef] = []
            self.owner_guard = 0  # depth of `if ..._owner...:` ancestors
            self.local_attach: dict[int, set[str]] = {}  # per-fn frame

        # -- structure -------------------------------------------------
        def visit_ClassDef(self, node):
            self.cls_stack.append(node.name)
            self.generic_visit(node)
            self.cls_stack.pop()

        def _visit_fn(self, node):
            self.fn_stack.append(node)
            frame: set[str] = set()
            self.local_attach[id(node)] = frame
            # pre-scan: locals assigned from attach calls
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assign):
                    for tgt, val in _assign_pairs(sub):
                        if (
                            isinstance(tgt, ast.Name)
                            and ATTACH in _value_kinds(val)
                        ):
                            frame.add(tgt.id)
            self.generic_visit(node)
            self.fn_stack.pop()
            del self.local_attach[id(node)]

        visit_FunctionDef = _visit_fn
        visit_AsyncFunctionDef = _visit_fn

        def visit_If(self, node):
            guarded = any(
                isinstance(n, (ast.Name, ast.Attribute))
                and (getattr(n, "id", "") or getattr(n, "attr", ""))
                .endswith("_owner")
                for n in ast.walk(node.test)
            )
            self.owner_guard += 1 if guarded else 0
            self.generic_visit(node)
            self.owner_guard -= 1 if guarded else 0

        # -- statements ------------------------------------------------
        def visit_Expr(self, node):
            if _call_kind(node.value) == CREATE:
                out.append(_finding(
                    "S004", mod, node.lineno,
                    "created segment handle is discarded; bind it so a "
                    "teardown path can unlink it",
                ))
            self.generic_visit(node)

        def visit_Call(self, node):
            name = call_name(node)
            if name == "close_segment":
                self._check_close_segment(node)
            elif name == "unlink":
                self._check_unlink(node)
            self.generic_visit(node)

        # -- rules -----------------------------------------------------
        def _check_close_segment(self, node: ast.Call) -> None:
            unlink_kw = next(
                (kw for kw in node.keywords if kw.arg == "unlink"), None
            )
            if unlink_kw is None:
                out.append(_finding(
                    "S001", mod, node.lineno,
                    "close_segment without explicit unlink= — ownership "
                    "must be stated at every teardown site",
                ))
                return
            literal_true = (
                isinstance(unlink_kw.value, ast.Constant)
                and unlink_kw.value.value is True
            )
            if not (literal_true and node.args):
                return
            a0 = node.args[0]
            target_attach = False
            attr = _self_attr(a0)
            if attr is not None and self.cls_stack:
                target_attach = attr in attach_only.get(self.cls_stack[-1], ())
            elif isinstance(a0, ast.Name) and self.fn_stack:
                frame = self.local_attach[id(self.fn_stack[-1])]
                target_attach = a0.id in frame
            if target_attach:
                out.append(_finding(
                    "S002", mod, node.lineno,
                    "attach-derived segment closed with unlink=True — only "
                    "the creator may unlink a shared name",
                ))

        def _check_unlink(self, node: ast.Call) -> None:
            recv = (
                dotted(node.func.value)
                if isinstance(node.func, ast.Attribute) else ""
            )
            in_fn = self.fn_stack[-1].name if self.fn_stack else ""
            if recv == "os":
                owner_classes = {
                    c for c, f in facts_by_class.items()
                    if any(
                        fn.name == "__init__" and any(
                            a.arg == "_owner"
                            for a in fn.args.args + fn.args.kwonlyargs
                        )
                        for fn in iter_functions(f.cls)
                    )
                }
                if (
                    self.cls_stack
                    and self.cls_stack[-1] in owner_classes
                    and self.owner_guard == 0
                ):
                    out.append(_finding(
                        "S003", mod, node.lineno,
                        "os.unlink in an _owner-discriminated class must be "
                        "guarded by the owner flag",
                    ))
            elif in_fn != "close_segment":
                out.append(_finding(
                    "S003", mod, node.lineno,
                    "raw segment .unlink() outside close_segment — route "
                    "teardown through close_segment(seg, unlink=...)",
                ))

    _Visitor().visit(mod.tree)

    # S005: creator attributes need a reachable teardown -----------------
    for cname, facts in facts_by_class.items():
        for attr, srcs in sorted(facts.attr_sources.items()):
            if CREATE in srcs and attr not in facts.torn_down:
                out.append(_finding(
                    "S005", mod, facts.attr_lines[attr],
                    f"{cname}.{attr} is created but no method of "
                    f"{cname} ever closes/unlinks it",
                ))

    # S005 (locals): created handle that never escapes the function ------
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        created: dict[str, int] = {}
        escaped: set[str] = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Assign):
                for tgt, val in _assign_pairs(sub):
                    if isinstance(tgt, ast.Name):
                        if CREATE in _value_kinds(val):
                            created[tgt.id] = sub.lineno
                        elif isinstance(val, ast.Name):
                            escaped.add(val.id)  # aliased onward
                    else:
                        for ref in ast.walk(val):
                            if isinstance(ref, ast.Name):
                                escaped.add(ref.id)
            elif isinstance(sub, ast.Return) and sub.value is not None:
                for ref in ast.walk(sub.value):
                    if isinstance(ref, ast.Name):
                        escaped.add(ref.id)
            elif isinstance(sub, ast.Call):
                if call_name(sub) in ("close", "close_segment", "unlink"):
                    recv = (
                        sub.func.value
                        if isinstance(sub.func, ast.Attribute) else None
                    )
                    if isinstance(recv, ast.Name):
                        escaped.add(recv.id)
                for a in list(sub.args) + [kw.value for kw in sub.keywords]:
                    for ref in ast.walk(a):
                        if isinstance(ref, ast.Name):
                            escaped.add(ref.id)
        for name, line in sorted(created.items()):
            if name not in escaped:
                out.append(_finding(
                    "S005", mod, line,
                    f"created segment '{name}' neither escapes "
                    f"{node.name} nor is closed — leaked /dev/shm entry",
                ))


@register_pass(PASS)
def run(project: Project) -> list[Finding]:
    """Creator-unlinks contract: every created segment has a teardown."""
    out: list[Finding] = []
    for mod in project.modules:
        _check_module(mod, project, out)
    return out

"""Exception hygiene (rule E001).

Broad handlers (``except Exception`` / ``except BaseException`` / bare
``except:``) are load-bearing in this codebase — teardown paths and ring
service loops must survive anything — but a broad handler that silently
discards the exception erases the only evidence of a real fault.  Every
broad handler must leave a trace:

  * re-raise (``raise`` / ``raise X``), or
  * reference the bound exception variable (relay it in-band, log it), or
  * bump a counter (``stats.errors += 1`` or a ``diag.note(...)`` /
    logger call — any call whose name is in ``OK_CALLS``).

Handlers for *specific* exception types (``BufferError``, ``OSError``,
``FileNotFoundError``...) are exempt: naming the type IS the analysis of
why swallowing is safe.
"""

from __future__ import annotations

import ast

from tools.beluga_lint import Finding, register_pass
from tools.beluga_lint.project import Project, call_name

PASS = "exception_hygiene"

# Call names that count as "the exception left a trace"
OK_CALLS = frozenset({
    "debug", "info", "warning", "warn", "error", "exception", "critical",
    "log", "print", "note", "record", "fail",
})
BROAD_TYPES = ("Exception", "BaseException")


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:  # bare except:
        return True
    if isinstance(t, ast.Name):
        return t.id in BROAD_TYPES
    if isinstance(t, ast.Tuple):
        return any(
            isinstance(e, ast.Name) and e.id in BROAD_TYPES for e in t.elts
        )
    return False


def _leaves_trace(handler: ast.ExceptHandler) -> bool:
    exc_var = handler.name
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.AugAssign):
            return True  # counter bump (stats.errors += 1)
        if exc_var and isinstance(node, ast.Name) and node.id == exc_var:
            return True
        if isinstance(node, ast.Call) and call_name(node) in OK_CALLS:
            return True
    return False


@register_pass(PASS)
def run(project: Project) -> list[Finding]:
    """Broad except handlers must re-raise, log, or count the failure."""
    out: list[Finding] = []
    for mod in project.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not _is_broad(node):
                continue
            if _leaves_trace(node):
                continue
            out.append(Finding(
                PASS, "E001", mod.relpath, node.lineno,
                "broad except swallows the exception — re-raise, use the "
                "bound variable, log, or bump a diag/stats counter",
            ))
    return out

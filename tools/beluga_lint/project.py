"""Parsed-source model + shared AST helpers for beluga-lint passes.

A ``Project`` is the set of parsed Python modules under the scan roots.
Passes never import the scanned code — everything is derived from the
AST — so the linter runs on a bare checkout with no dependencies and
can analyze deliberately broken trees (its own mutation tests).
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field


@dataclass
class Module:
    path: str  # absolute
    relpath: str  # relative to the scan root (stable in findings)
    tree: ast.Module
    source: str

    @property
    def name(self) -> str:
        return os.path.basename(self.path)


@dataclass
class Project:
    modules: list[Module] = field(default_factory=list)

    @classmethod
    def load(cls, roots: list[str]) -> "Project":
        proj = cls()
        for root in roots:
            root = os.path.abspath(root)
            if os.path.isfile(root):
                proj._add(root, os.path.basename(root))
                continue
            base = os.path.dirname(root.rstrip(os.sep))
            for dirpath, dirnames, filenames in os.walk(root):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d != "__pycache__" and not d.startswith(".")
                )
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        full = os.path.join(dirpath, fn)
                        proj._add(full, os.path.relpath(full, base))
        return proj

    def _add(self, path: str, relpath: str) -> None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        self.modules.append(Module(
            path=path, relpath=relpath,
            tree=ast.parse(source, filename=path), source=source,
        ))

    # -- cross-module indexes -------------------------------------------
    def classes(self):
        """Yield (module, ClassDef) for every class in the project."""
        for mod in self.modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ClassDef):
                    yield mod, node

    def class_index(self) -> dict[str, tuple[Module, ast.ClassDef]]:
        """Class name -> (module, node); later duplicates win (rare)."""
        out = {}
        for mod, cls in self.classes():
            out[cls.name] = (mod, cls)
        return out

    def module_functions(self, mod: Module) -> dict[str, ast.FunctionDef]:
        """Top-level function defs of one module, by name."""
        return {
            n.name: n for n in mod.tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }


# ---------------------------------------------------------------------------
# AST helpers shared by passes
# ---------------------------------------------------------------------------
def call_name(call: ast.Call) -> str:
    """Last path component of the called thing ('x.y.z(...)' -> 'z')."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def call_receiver(call: ast.Call) -> ast.expr | None:
    """The object a method is called on, or None for bare calls."""
    if isinstance(call.func, ast.Attribute):
        return call.func.value
    return None


def dotted(expr: ast.expr) -> str:
    """'self._pool_ring' / 'os.path' rendered as a dotted string ('' if
    the expression is not a plain name/attribute chain)."""
    parts = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if isinstance(expr, ast.Name):
        parts.append(expr.id)
        return ".".join(reversed(parts))
    return ""


def const_int_assigns(tree: ast.AST, prefix: str) -> dict[str, tuple[int, int]]:
    """Module-level ``NAME = <int>`` (and tuple-unpack) constants whose
    name starts with ``prefix``; returns name -> (value, lineno)."""
    out: dict[str, tuple[int, int]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        for target in node.targets:
            if (
                isinstance(target, ast.Name)
                and target.id.startswith(prefix)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, int)
            ):
                out[target.id] = (node.value.value, node.lineno)
            elif (
                isinstance(target, ast.Tuple)
                and isinstance(node.value, ast.Tuple)
                and len(target.elts) == len(node.value.elts)
            ):
                for t, v in zip(target.elts, node.value.elts):
                    if (
                        isinstance(t, ast.Name)
                        and t.id.startswith(prefix)
                        and isinstance(v, ast.Constant)
                        and isinstance(v.value, int)
                    ):
                        out[t.id] = (v.value, node.lineno)
    return out


def compared_names(func: ast.AST, names: set[str]) -> set[str]:
    """Names from ``names`` used in ``x == NAME`` / ``x in (NAME, ...)``
    comparisons anywhere under ``func``."""
    hit: set[str] = set()
    for node in ast.walk(func):
        if not isinstance(node, ast.Compare):
            continue
        for comp in node.comparators:
            for ref in ast.walk(comp):
                if isinstance(ref, ast.Name) and ref.id in names:
                    hit.add(ref.id)
    return hit


def referenced_names(node: ast.AST, names: set[str]) -> set[str]:
    """Subset of ``names`` referenced as plain Names under ``node``."""
    return {
        n.id for n in ast.walk(node)
        if isinstance(n, ast.Name) and n.id in names
    }


def annotation_name(ann: ast.expr | None) -> str:
    """Class name out of a parameter annotation (Name, string constant,
    or 'X | None' unions); '' when unresolvable."""
    if ann is None:
        return ""
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value.split("|")[0].strip()
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        left = annotation_name(ann.left)
        return left or annotation_name(ann.right)
    return ""


def iter_functions(cls: ast.ClassDef):
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node

"""Mamba-2 (SSD — state-space duality) mixer, chunked scan + decode step.

Follows the minimal SSD formulation of arXiv:2405.21060:
  h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t h_t + D x_t
computed chunkwise: intra-chunk quadratic attention-like term + inter-chunk
state recurrence via ``jax.lax.associative_scan`` (static tree — counted
correctly by the roofline HLO analyzer, unlike data-dependent while loops).

TP sharding (Megatron-Mamba style): z/x/dt projections and heads sharded over
``model``; B/C (n_groups=1) replicated; out_proj row-parallel (+psum).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import AxisRules, ParamSpec, constrain
from repro.models.layers import rms_norm


def ssm_dims(cfg: ModelConfig):
    ssm = cfg.ssm
    di = ssm.d_inner(cfg.d_model)
    nh = ssm.n_heads(cfg.d_model)
    conv_dim = di + 2 * ssm.n_groups * ssm.d_state
    return di, nh, conv_dim


def mamba_params(cfg: ModelConfig, tp: int) -> dict:
    d = cfg.d_model
    ssm = cfg.ssm
    di, nh, conv_dim = ssm_dims(cfg)
    g, n, ker = ssm.n_groups, ssm.d_state, ssm.d_conv
    dt = cfg.dtype
    return {
        "wz": ParamSpec((d, di), dt, ("embed", "ssm_inner")),
        "wx": ParamSpec((d, di), dt, ("embed", "ssm_inner")),
        "wBC": ParamSpec((d, 2 * g * n), dt, ("embed", "conv_dim")),
        "wdt": ParamSpec((d, nh), dt, ("embed", "ssm_inner")),
        "conv_x": ParamSpec((ker, di), dt, (None, "ssm_inner")),
        "conv_BC": ParamSpec((ker, 2 * g * n), dt, (None, "conv_dim")),
        "conv_bias_x": ParamSpec((di,), dt, ("ssm_inner",), init="zeros"),
        "conv_bias_BC": ParamSpec((2 * g * n,), dt, ("conv_dim",), init="zeros"),
        "A_log": ParamSpec((nh,), "float32", ("ssm_inner",), init="ssm_a"),
        "D": ParamSpec((nh,), "float32", ("ssm_inner",), init="ones"),
        "dt_bias": ParamSpec((nh,), "float32", ("ssm_inner",), init="ssm_dt"),
        "norm_w": ParamSpec((di,), dt, ("ssm_inner",), init="ones"),
        "out": ParamSpec((di, d), dt, ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (b, s, c); w: (k, c)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # sum of shifted slices — small k (4), avoids conv lowering issues
    out = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for i in range(k):
        out = out + xp[:, i : i + s].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + bias.astype(jnp.float32)).astype(x.dtype)


def _ssd_chunked(x, a_log, b_mat, c_mat, chunk: int):
    """Chunked SSD.

    x:     (b, s, nh, hp)   already multiplied by dt
    a_log: (b, s, nh)       log decay per step (dt * A, <= 0)
    b_mat: (b, s, g, n)
    c_mat: (b, s, g, n)
    returns y: (b, s, nh, hp)
    """
    bsz, s_in, nh, hp = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = nh // g
    s = -(-s_in // chunk) * chunk
    if s != s_in:
        # pad with zero dt-scaled inputs and zero log-decay (a=1): the padded
        # tail neither contributes to nor decays the running state.
        pad = ((0, 0), (0, s - s_in), (0, 0), (0, 0))
        x = jnp.pad(x, pad)
        a_log = jnp.pad(a_log, ((0, 0), (0, s - s_in), (0, 0)))
        b_mat = jnp.pad(b_mat, pad)
        c_mat = jnp.pad(c_mat, pad)
    nc = s // chunk

    xr = x.reshape(bsz, nc, chunk, nh, hp)
    ar = a_log.reshape(bsz, nc, chunk, nh).astype(jnp.float32)
    br = b_mat.reshape(bsz, nc, chunk, g, n)
    cr = c_mat.reshape(bsz, nc, chunk, g, n)
    # broadcast groups to heads
    bh = jnp.broadcast_to(
        br[:, :, :, :, None, :], (bsz, nc, chunk, g, rep, n)
    ).reshape(bsz, nc, chunk, nh, n)
    ch = jnp.broadcast_to(
        cr[:, :, :, :, None, :], (bsz, nc, chunk, g, rep, n)
    ).reshape(bsz, nc, chunk, nh, n)

    cum = jnp.cumsum(ar, axis=2)  # (b, nc, L, nh) prefix log-decay incl. self

    # ---- intra-chunk (quadratic within chunk) ----
    # L[i, j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b, nc, L, L, nh)
    li = jnp.arange(chunk)
    causal = li[:, None] >= li[None, :]
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bzlhn,bzmhn->bzlmh", ch, bh,
                        preferred_element_type=jnp.float32)
    y_intra = jnp.einsum(
        "bzlmh,bzlmh,bzmhp->bzlhp", scores, decay,
        xr.astype(jnp.float32),
    )

    # ---- chunk-final states ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (b, nc, L, nh)
    states = jnp.einsum(
        "bzlhn,bzlh,bzlhp->bzhnp", bh.astype(jnp.float32), decay_to_end,
        xr.astype(jnp.float32),
    )  # (b, nc, nh, n, hp)

    # ---- inter-chunk recurrence over nc (associative scan) ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b, nc, nh) total decay per chunk

    def combine(lhs, rhs):
        d1, s1 = lhs
        d2, s2 = rhs
        return d1 * d2, s1 * d2[..., None, None] + s2

    run_decay, run_states = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1
    )
    # state entering chunk z = running state after chunk z-1
    prev_states = jnp.concatenate(
        [jnp.zeros_like(run_states[:, :1]), run_states[:, :-1]], axis=1
    )

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(cum)  # decay from chunk start to position (incl. self)
    y_inter = jnp.einsum(
        "bzlhn,bzlh,bzhnp->bzlhp", ch.astype(jnp.float32), in_decay, prev_states
    )

    y = (y_intra + y_inter).reshape(bsz, s, nh, hp)[:, :s_in]
    final_state = run_states[:, -1]  # (b, nh, n, hp)
    return y, final_state


def mamba_apply(
    p: dict,
    x: jax.Array,  # (b, s, d)
    cfg: ModelConfig,
    rules: AxisRules | None,
    return_state: bool = False,
):
    """Full-sequence SSD pass (train / prefill)."""
    ssm = cfg.ssm
    di, nh, conv_dim = ssm_dims(cfg)
    g, n = ssm.n_groups, ssm.d_state
    hp = ssm.head_dim
    bsz, s, d = x.shape

    z = x @ p["wz"]  # (b, s, di)
    xi = x @ p["wx"]
    bc = x @ p["wBC"]  # (b, s, 2gn)
    dt_raw = x @ p["wdt"]  # (b, s, nh)
    if rules is not None:
        z = constrain(z, rules, ("batch", "seq", "act_mlp"))
        xi = constrain(xi, rules, ("batch", "seq", "act_mlp"))

    # raw pre-conv tail -> decode conv state (last d_conv-1 inputs)
    if return_state:
        xbc_raw = jnp.concatenate([xi, bc], axis=-1)
        conv_tail = xbc_raw[:, s - (ssm.d_conv - 1) :, :]  # (b, k-1, conv_dim)

    xi = _causal_conv(xi, p["conv_x"], p["conv_bias_x"])
    bc = _causal_conv(bc, p["conv_BC"], p["conv_bias_BC"])
    xi = jax.nn.silu(xi)
    bc = jax.nn.silu(bc)
    b_mat = bc[..., : g * n].reshape(bsz, s, g, n)
    c_mat = bc[..., g * n :].reshape(bsz, s, g, n)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b,s,nh)
    a = -jnp.exp(p["A_log"])  # (nh,) negative
    a_log_step = dt * a  # (b, s, nh)

    xh = xi.reshape(bsz, s, nh, hp)
    y, final_state = _ssd_chunked(
        xh.astype(jnp.float32) * dt[..., None], a_log_step, b_mat, c_mat,
        chunk=min(ssm.chunk_size, s),
    )
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(x.dtype)

    # gated RMSNorm then out projection (row-parallel)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    if rules is not None and rules.rowp_bf16:
        from repro.distributed.collectives import row_parallel_matmul

        out = row_parallel_matmul(y, p["out"], rules)
    else:
        out = y @ p["out"]
    if rules is not None:
        out = constrain(out, rules, ("batch", "seq", "act_embed"))
    if return_state:
        return out, final_state, conv_tail
    return out


def mamba_decode(
    p: dict,
    x: jax.Array,  # (b, 1, d)
    state: jax.Array,  # (b, nh, n, hp)
    conv_state: jax.Array,  # (b, k-1, conv_dim)
    cfg: ModelConfig,
    rules: AxisRules | None,
):
    """Single-token recurrent step."""
    ssm = cfg.ssm
    di, nh, conv_dim = ssm_dims(cfg)
    g, n = ssm.n_groups, ssm.d_state
    hp = ssm.head_dim
    bsz = x.shape[0]
    xt = x[:, 0]  # (b, d)

    z = xt @ p["wz"]
    xi = xt @ p["wx"]
    bc = xt @ p["wBC"]
    dt_raw = xt @ p["wdt"]

    # conv via cached window
    xbc = jnp.concatenate([xi, bc], axis=-1)  # (b, conv_dim)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (b,k,cd)
    w_full = jnp.concatenate([p["conv_x"], p["conv_BC"]], axis=1)  # (k, cd)
    bias_full = jnp.concatenate([p["conv_bias_x"], p["conv_bias_BC"]], axis=0)
    conv_out = (
        jnp.sum(window.astype(jnp.float32) * w_full[None].astype(jnp.float32), axis=1)
        + bias_full.astype(jnp.float32)
    )
    conv_out = jax.nn.silu(conv_out)
    new_conv_state = window[:, 1:]

    xi = conv_out[:, :di]
    bc = conv_out[:, di:]
    b_vec = bc[:, : g * n].reshape(bsz, g, n)
    c_vec = bc[:, g * n :].reshape(bsz, g, n)
    rep = nh // g
    b_h = jnp.repeat(b_vec, rep, axis=1)  # (b, nh, n)
    c_h = jnp.repeat(c_vec, rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b, nh)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)  # (b, nh)

    xh = xi.reshape(bsz, nh, hp).astype(jnp.float32)
    # state: (b, nh, n, hp)
    new_state = state * decay[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", b_h.astype(jnp.float32) * dt[..., None], xh
    )
    y = jnp.einsum("bhn,bhnp->bhp", c_h.astype(jnp.float32), new_state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(bsz, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = (y @ p["out"])[:, None, :]  # (b, 1, d)
    if rules is not None:
        out = constrain(out, rules, ("batch", "seq", "act_embed"))
    return out, new_state, new_conv_state

"""Decoder stack: homogeneous scan over layer *periods*.

A "period" is the smallest repeating pattern of layers:
  dense/moe/audio/vlm : period 1  (n_periods = n_layers)
  mamba2              : period 1  (ssm mixer, no MLP)
  jamba               : period 8  (pos 7 = attention, others mamba;
                        odd positions = MoE FFN, even = dense FFN)

Params for each position-in-period are stacked with a leading (n_periods,)
dim and consumed as scan xs — one compiled layer body regardless of depth
(the roofline analyzer multiplies while-loop bodies by their trip count).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RuntimeConfig
from repro.distributed.sharding import AxisRules, ParamSpec, constrain, is_param_spec
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.layers import mlp_apply, mlp_params, norm_apply, norm_params


@dataclass(frozen=True)
class LayerKind:
    mixer: str  # "attn" | "ssm"
    ffn: str  # "mlp" | "moe" | "none"


def period_length(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        p = cfg.attn_period
        if cfg.moe.enabled:
            import math

            p = p * cfg.moe.layer_period // math.gcd(p, cfg.moe.layer_period)
        return p
    return 1


def layer_kinds(cfg: ModelConfig) -> list[LayerKind]:
    """Kind of each position within one period."""
    p = period_length(cfg)
    attn_ids = set(cfg.attn_layer_ids())
    moe_ids = set(cfg.moe_layer_ids())
    kinds = []
    for pos in range(p):
        mixer = "attn" if pos in attn_ids or (p == 1 and cfg.family != "ssm") else "ssm"
        if p == 1:
            mixer = "ssm" if cfg.family == "ssm" else "attn"
        if cfg.family == "ssm":
            ffn = "none"
        elif cfg.moe.enabled and (p == 1 or pos in moe_ids):
            ffn = "moe" if (p > 1 and pos in moe_ids) or (p == 1) else "mlp"
        else:
            ffn = "mlp"
        kinds.append(LayerKind(mixer=mixer, ffn=ffn))
    return kinds


def n_periods(cfg: ModelConfig) -> int:
    p = period_length(cfg)
    assert cfg.n_layers % p == 0, (cfg.n_layers, p)
    return cfg.n_layers // p


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _stack_spec(spec: ParamSpec, n: int) -> ParamSpec:
    return ParamSpec(
        (n, *spec.shape), spec.dtype, ("layers", *spec.logical_axes),
        init=spec.init, scale=spec.scale,
    )


def _position_params(cfg: ModelConfig, kind: LayerKind, tp: int) -> dict:
    p: dict = {"ln1": norm_params(cfg)}
    if kind.mixer == "attn":
        p["attn"] = attn_lib.attn_params(cfg, tp)
    else:
        p["ssm"] = mamba_lib.mamba_params(cfg, tp)
    if kind.ffn != "none":
        p["ln2"] = norm_params(cfg)
        if kind.ffn == "moe":
            p["moe"] = moe_lib.moe_params(cfg, tp)
        else:
            p["mlp"] = mlp_params(cfg, cfg.d_ff)
    return p


def stack_params(cfg: ModelConfig, tp: int) -> dict:
    np_ = n_periods(cfg)
    kinds = layer_kinds(cfg)
    out = {}
    for pos, kind in enumerate(kinds):
        sub = _position_params(cfg, kind, tp)
        out[f"pos_{pos}"] = jax.tree.map(
            lambda s: _stack_spec(s, np_), sub, is_leaf=is_param_spec
        )
    return out


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------


def cache_specs(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    tp: int,
    kv_axes: tuple,
    kv_dtype: str | None = None,
) -> dict:
    """ShapeDtypeStruct-compatible ParamSpec tree for the decode cache.

    kv_axes: logical axes for the (batch, seq) dims of the kv cache, e.g.
    ("batch", "kv_seq") for decode_32k or (None, "kv_seq_long") for long_500k.
    """
    np_ = n_periods(cfg)
    kinds = layer_kinds(cfg)
    di, nh, conv_dim = (0, 0, 0)
    if cfg.has_ssm_layers:
        di, nh, conv_dim = mamba_lib.ssm_dims(cfg)
    out = {}
    b_ax, s_ax = kv_axes
    for pos, kind in enumerate(kinds):
        if kind.mixer == "attn":
            kv = ParamSpec(
                (np_, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                kv_dtype or cfg.dtype,
                ("layers", b_ax, s_ax, None, None),
                init="zeros",
            )
            out[f"pos_{pos}"] = {"k": kv, "v": kv}
        else:
            out[f"pos_{pos}"] = {
                "state": ParamSpec(
                    (np_, batch, nh, cfg.ssm.d_state, cfg.ssm.head_dim),
                    "float32",
                    ("layers", b_ax, "ssm_inner", None, None),
                    init="zeros",
                ),
                "conv": ParamSpec(
                    (np_, batch, cfg.ssm.d_conv - 1, conv_dim),
                    cfg.dtype,
                    ("layers", b_ax, None, None),
                    init="zeros",
                ),
            }
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _mixer_full(pos_params, kind, x, positions, cfg, runtime, rules,
                collect_cache: bool, max_len: int | None):
    """Full-sequence mixer (train / prefill). Returns (out, cache_entry)."""
    h = norm_apply(pos_params["ln1"], x, cfg)
    if kind.mixer == "attn":
        q, k, v = attn_lib.qkv_proj(pos_params["attn"], h, cfg, positions, rules)
        o = attn_lib.flash_attention(
            q, k, v, causal=True,
            chunk_q=runtime.attn_chunk_q, chunk_kv=runtime.attn_chunk_kv,
        )
        out = attn_lib.out_proj(pos_params["attn"], o, rules)
        cache = None
        if collect_cache:
            b, s = x.shape[0], x.shape[1]
            pad = max_len - s
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            if runtime.use_fp8_kv:
                kc = kc.astype(jnp.float8_e4m3fn)
                vc = vc.astype(jnp.float8_e4m3fn)
            cache = {"k": kc, "v": vc}
        return out, cache
    else:
        if collect_cache:
            out, state, conv = mamba_lib.mamba_apply(
                pos_params["ssm"], h, cfg, rules, return_state=True
            )
            return out, {"state": state, "conv": conv}
        out = mamba_lib.mamba_apply(pos_params["ssm"], h, cfg, rules)
        return out, None


def _ffn(pos_params, kind, x, cfg, runtime, rules):
    if kind.ffn == "none":
        return x, 0.0
    h = norm_apply(pos_params["ln2"], x, cfg)
    if kind.ffn == "moe":
        out, aux = moe_lib.moe_apply(pos_params["moe"], h, cfg, runtime, rules)
        return x + out, aux["load_balance_loss"]
    return x + mlp_apply(pos_params["mlp"], h, cfg, rules), 0.0


def forward_full(
    params: dict,
    x: jax.Array,  # (b, s, d) embedded inputs
    positions: jax.Array,  # (b, s)
    cfg: ModelConfig,
    runtime: RuntimeConfig,
    rules: AxisRules | None,
    collect_cache: bool = False,
    max_len: int | None = None,
):
    """Run the full stack; returns (hidden, aux_loss, cache|None)."""
    kinds = layer_kinds(cfg)

    def period_fn(carry, xs_params):
        h, aux = carry
        caches = {}
        for pos, kind in enumerate(kinds):
            pp = xs_params[f"pos_{pos}"]
            mix_out, cache = _mixer_full(
                pp, kind, h, positions, cfg, runtime, rules,
                collect_cache, max_len,
            )
            h = h + mix_out
            h, lb = _ffn(pp, kind, h, cfg, runtime, rules)
            if rules is not None:
                h = constrain(h, rules, ("batch", "seq", "act_embed"))
            if collect_cache:
                caches[f"pos_{pos}"] = cache
            aux = aux + lb
        return (h, aux), caches if collect_cache else None

    body = period_fn
    if runtime.remat != "none":
        policy = {
            "full": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        }[runtime.remat]
        body = jax.checkpoint(period_fn, policy=policy, prevent_cse=False)

    (h, aux), caches = jax.lax.scan(body, (x, 0.0), params["stack"])
    return h, aux, caches


def decode_step_stack(
    params: dict,
    cache: dict,
    x: jax.Array,  # (b, 1, d)
    pos: jax.Array,  # (b,) current positions (write index)
    cfg: ModelConfig,
    runtime: RuntimeConfig,
    rules: AxisRules | None,
    mesh=None,
    kv_shard_axes: tuple[str, ...] = (),
    kv_batch_axes: tuple[str, ...] = (),
):
    """One decode token through the stack; returns (hidden, new_cache)."""
    kinds = layer_kinds(cfg)
    cache_len = pos + 1

    def period_fn(carry, xs):
        h = carry
        pp, pc = xs
        new_caches = {}
        for p_i, kind in enumerate(kinds):
            layer_p = pp[f"pos_{p_i}"]
            layer_c = pc[f"pos_{p_i}"]
            hn = norm_apply(layer_p["ln1"], h, cfg)
            if kind.mixer == "attn":
                q, k_new, v_new = attn_lib.qkv_proj(
                    layer_p["attn"], hn, cfg, pos[:, None], rules
                )
                kc, vc = attn_lib.update_kv_cache(
                    layer_c["k"], layer_c["v"], k_new, v_new, pos
                )
                if runtime.decode_kv == "pool_interleaved" and mesh is not None:
                    o = attn_lib.decode_attention_interleaved(
                        q, kc, vc, cache_len, mesh,
                        axes=kv_shard_axes, batch_axes=kv_batch_axes,
                    )
                else:
                    o = attn_lib.decode_attention_replicated(q, kc, vc, cache_len)
                mix_out = attn_lib.out_proj(layer_p["attn"], o, rules)
                new_caches[f"pos_{p_i}"] = {"k": kc, "v": vc}
            else:
                mix_out, state, conv = mamba_lib.mamba_decode(
                    layer_p["ssm"], hn, layer_c["state"], layer_c["conv"],
                    cfg, rules,
                )
                new_caches[f"pos_{p_i}"] = {"state": state, "conv": conv}
            h = h + mix_out
            h, _ = _ffn(layer_p, kind, h, cfg, runtime, rules)
            if rules is not None:
                h = constrain(h, rules, ("batch", "seq", "act_embed"))
        return h, new_caches

    h, new_cache = jax.lax.scan(period_fn, x, (params["stack"], cache))
    return h, new_cache

"""Shared layers: norms, rotary embeddings, MLPs, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import AxisRules, ParamSpec, constrain


def rms_norm(x: jax.Array, w: jax.Array | None, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    if w is not None:
        x = x * w.astype(jnp.float32)
    return x.astype(dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., s, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [
            x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin,
            x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin,
        ],
        axis=-1,
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_params(cfg: ModelConfig, d_ff: int) -> dict:
    d = cfg.d_model
    dt = cfg.dtype
    p = {
        "wi_gate": ParamSpec((d, d_ff), dt, ("embed", "mlp")),
        "wi_up": ParamSpec((d, d_ff), dt, ("embed", "mlp")),
        "wo": ParamSpec((d_ff, d), dt, ("mlp", "embed")),
    }
    if cfg.mlp_bias:
        p["bi_gate"] = ParamSpec((d_ff,), dt, ("mlp",), init="zeros")
        p["bi_up"] = ParamSpec((d_ff,), dt, ("mlp",), init="zeros")
        p["bo"] = ParamSpec((d,), dt, ("norm",), init="zeros")
    return p


def mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig, rules: AxisRules | None) -> jax.Array:
    act = act_fn(cfg.act)
    g = x @ p["wi_gate"]
    u = x @ p["wi_up"]
    if "bi_gate" in p:
        g = g + p["bi_gate"]
        u = u + p["bi_up"]
    h = act(g) * u
    if rules is not None:
        h = constrain(h, rules, ("batch", "seq", "act_mlp"))
    if rules is not None and rules.rowp_bf16:
        from repro.distributed.collectives import row_parallel_matmul

        out = row_parallel_matmul(h, p["wo"], rules)
    else:
        out = h @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    if rules is not None:
        out = constrain(out, rules, ("batch", "seq", "act_embed"))
    return out


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_params(cfg: ModelConfig, tp: int) -> dict:
    v = cfg.padded_vocab(tp)
    p = {"table": ParamSpec((v, cfg.d_model), cfg.dtype, ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        p["head"] = ParamSpec((cfg.d_model, v), cfg.dtype, ("embed", "vocab"))
    return p


def embed_apply(p: dict, tokens: jax.Array, rules: AxisRules | None) -> jax.Array:
    out = jnp.take(p["table"], tokens, axis=0)
    if rules is not None:
        out = constrain(out, rules, ("batch", "seq", "act_embed"))
    return out


def unembed_apply(p: dict, x: jax.Array, rules: AxisRules | None) -> jax.Array:
    head = p["head"] if "head" in p else p["table"].T
    logits = x @ head.astype(x.dtype)
    logits = logits.astype(jnp.float32)
    if rules is not None:
        logits = constrain(logits, rules, ("batch", "seq", "act_vocab"))
    return logits


def norm_params(cfg: ModelConfig) -> dict:
    if cfg.nonparametric_ln:
        return {}
    return {"w": ParamSpec((cfg.d_model,), cfg.dtype, ("norm",), init="ones")}


def norm_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    return rms_norm(x, p.get("w"), cfg.norm_eps)

"""Public model API: init / loss / prefill / decode / input_specs.

``Model`` binds a ModelConfig + RuntimeConfig + (optional) mesh AxisRules and
exposes pure functions suitable for jit/lower: ``loss_fn``, ``prefill_fn``,
``decode_fn``. Inputs are produced by ``input_specs`` (ShapeDtypeStructs —
the same objects the multi-pod dry-run lowers against).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RuntimeConfig, ShapeConfig
from repro.distributed import sharding as shlib
from repro.distributed.sharding import AxisRules
from repro.models import transformer as stack_lib
from repro.models.layers import embed_apply, norm_apply, norm_params, unembed_apply
from repro.models.layers import embed_params


@dataclass
class Model:
    cfg: ModelConfig
    runtime: RuntimeConfig = RuntimeConfig()
    rules: AxisRules | None = None  # None => single-device (tests/examples)

    # ------------------------------------------------------------------
    @property
    def tp(self) -> int:
        return self.rules.tp if self.rules is not None else 1

    @property
    def mesh(self):
        return self.rules.mesh if self.rules is not None else None

    # ------------------------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        p = {
            "embed": embed_params(cfg, self.tp),
            "stack": stack_lib.stack_params(cfg, self.tp),
            "final_ln": norm_params(cfg),
        }
        return p

    def init(self, key: jax.Array) -> dict:
        return shlib.init_tree(self.param_specs(), key)

    def param_shardings(self):
        assert self.rules is not None
        return shlib.tree_shardings(self.param_specs(), self.rules)

    def param_shape_dtypes(self):
        return shlib.tree_shape_dtype(self.param_specs())

    # ------------------------------------------------------------------
    # Embedding of batch inputs (handles stub frontends)
    # ------------------------------------------------------------------
    def embed(self, params: dict, batch: dict) -> tuple[jax.Array, jax.Array]:
        """Returns (x, positions)."""
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            x = batch["frame_embeds"].astype(cfg.dtype)
        elif cfg.frontend == "vision_stub":
            tok_x = embed_apply(params["embed"], batch["tokens"], self.rules)
            patch = batch["patch_embeds"].astype(cfg.dtype)
            x = jnp.concatenate([patch, tok_x], axis=1)
        else:
            x = embed_apply(params["embed"], batch["tokens"], self.rules)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return x, positions

    # ------------------------------------------------------------------
    # Training loss
    # ------------------------------------------------------------------
    def loss_fn(self, params: dict, batch: dict) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x, positions = self.embed(params, batch)
        h, aux_lb, _ = stack_lib.forward_full(
            params, x, positions, cfg, self.runtime, self.rules
        )
        h = norm_apply(params["final_ln"], h, cfg)
        logits = unembed_apply(params["embed"], h, self.rules)  # (b, s, V) f32

        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if cfg.frontend == "vision_stub":
            # only the text segment (after the patch prefix) predicts tokens
            npatch = cfg.n_frontend_tokens
            logits = logits[:, npatch:]
        # next-token shift
        logits = logits[:, :-1]
        targets = labels[:, 1:]
        if mask is not None:
            mask = mask[:, 1:]
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, targets[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        nll = lse - picked
        if mask is not None:
            denom = jnp.maximum(mask.sum(), 1.0)
            loss = jnp.sum(nll * mask) / denom
        else:
            loss = jnp.mean(nll)
        aux = {"lm_loss": loss, "load_balance_loss": aux_lb}
        if self.cfg.moe.enabled:
            loss = loss + 0.01 * aux_lb
        return loss, aux

    # ------------------------------------------------------------------
    # Prefill: returns last-position logits + populated cache
    # ------------------------------------------------------------------
    def prefill_fn(
        self, params: dict, batch: dict, max_len: int | None = None
    ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x, positions = self.embed(params, batch)
        max_len = max_len if max_len is not None else x.shape[1]
        h, _, cache = stack_lib.forward_full(
            params, x, positions, cfg, self.runtime, self.rules,
            collect_cache=True, max_len=max_len,
        )
        h = norm_apply(params["final_ln"], h, cfg)
        logits = unembed_apply(params["embed"], h[:, -1:, :], self.rules)
        return logits, cache

    # ------------------------------------------------------------------
    # Decode: one token for every sequence in the batch
    # ------------------------------------------------------------------
    def decode_fn(
        self,
        params: dict,
        cache: dict,
        tokens: jax.Array,  # (b,) int32 previous tokens
        pos: jax.Array,  # (b,) int32 write positions (= context length so far)
        kv_shard_axes: tuple[str, ...] = ("model",),
        kv_batch_axes: tuple[str, ...] = ("data",),
    ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            x = embed_apply(params["embed"], tokens[:, None], self.rules)
        else:
            x = embed_apply(params["embed"], tokens[:, None], self.rules)
        h, new_cache = stack_lib.decode_step_stack(
            params, cache, x, pos, cfg, self.runtime, self.rules,
            mesh=self.mesh,
            kv_shard_axes=kv_shard_axes,
            kv_batch_axes=kv_batch_axes,
        )
        h = norm_apply(params["final_ln"], h, cfg)
        logits = unembed_apply(params["embed"], h, self.rules)  # (b, 1, V)
        return logits[:, 0], new_cache

    # ------------------------------------------------------------------
    # Cache construction
    # ------------------------------------------------------------------
    def cache_specs(self, batch: int, max_len: int, kv_axes=("batch", "kv_seq")):
        kv_dtype = "float8_e4m3fn" if self.runtime.use_fp8_kv else None
        return stack_lib.cache_specs(
            self.cfg, batch, max_len, self.tp, kv_axes, kv_dtype
        )

    def init_cache(self, batch: int, max_len: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_specs(batch, max_len),
            is_leaf=shlib.is_param_spec,
        )

    def cache_shardings(self, batch: int, max_len: int, kv_axes=("batch", "kv_seq")):
        assert self.rules is not None
        return shlib.tree_shardings(
            self.cache_specs(batch, max_len, kv_axes), self.rules
        )

    # ------------------------------------------------------------------
    # Dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict[str, Any]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32

        def sds(shp, dt):
            return jax.ShapeDtypeStruct(shp, dt)

        if shape.kind in ("train", "prefill"):
            if cfg.frontend == "audio_stub":
                batch = {
                    "frame_embeds": sds((b, s, cfg.d_model), jnp.bfloat16),
                    "labels": sds((b, s), i32),
                }
            elif cfg.frontend == "vision_stub":
                npatch = cfg.n_frontend_tokens
                batch = {
                    "tokens": sds((b, s - npatch), i32),
                    "patch_embeds": sds((b, npatch, cfg.d_model), jnp.bfloat16),
                    "labels": sds((b, s - npatch), i32),
                }
            else:
                batch = {
                    "tokens": sds((b, s), i32),
                    "labels": sds((b, s), i32),
                }
            if shape.kind == "prefill":
                batch.pop("labels")
            return batch
        else:  # decode
            return {
                "tokens": sds((b,), i32),
                "pos": sds((b,), i32),
            }

    def input_shardings(self, shape: ShapeConfig) -> dict[str, Any]:
        assert self.rules is not None
        r = self.rules
        specs = self.input_specs(shape)
        out = {}
        for k, v in specs.items():
            if v.ndim >= 2:
                out[k] = r.sharding(("batch",) + (None,) * (v.ndim - 1))
            elif shape.global_batch >= r.dp or shape.kind != "decode":
                out[k] = r.sharding(("batch",))
            else:
                out[k] = r.sharding((None,))
            if shape.kind == "decode" and shape.global_batch < r.dp:
                # tiny decode batch (long_500k b=1): replicate batch dims
                out[k] = r.sharding((None,) * v.ndim)
        return out

from repro.models.model import Model  # noqa: F401

"""Mixture-of-Experts with expert parallelism over the `model` mesh axis.

Baseline dispatch is the dense one-hot einsum path (MaxText / GShard style,
capacity-factor token dropping) — robust under GSPMD for the dry-run.  The
`ragged` dispatch (sort-based, no capacity waste) is the hillclimb variant.

Supports:
  * top-k routing (llama4-maverick top-1, arctic & jamba top-2)
  * Arctic's dense-residual MLP in parallel with the experts
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RuntimeConfig
from repro.distributed.sharding import AxisRules, ParamSpec, constrain
from repro.models.layers import act_fn, mlp_apply, mlp_params


def moe_params(cfg: ModelConfig, tp: int) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    dt = cfg.dtype
    p = {
        "router": ParamSpec((d, e), "float32", ("embed", "experts")),
        "wi_gate": ParamSpec((e, d, f), dt, ("experts", "embed", "expert_mlp")),
        "wi_up": ParamSpec((e, d, f), dt, ("experts", "embed", "expert_mlp")),
        "wo": ParamSpec((e, f, d), dt, ("experts", "expert_mlp", "embed")),
    }
    if cfg.moe.dense_residual:
        p["dense"] = mlp_params(cfg, cfg.moe.dense_residual_ff)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    moe = cfg.moe
    cap = int(moe.capacity_factor * moe.top_k * n_tokens / moe.n_experts)
    return max(4, -(-cap // 4) * 4)


def moe_apply(
    p: dict,
    x: jax.Array,  # (b, s, d)
    cfg: ModelConfig,
    runtime: RuntimeConfig,
    rules: AxisRules | None,
) -> tuple[jax.Array, dict]:
    b, s, d = x.shape
    moe = cfg.moe

    # a2a pays off when there are enough tokens per shard to fill the
    # all-to-all buffers; decode-sized batches fall back to einsum dispatch
    # (measured: a2a decode_32k inflated flops ~6x on arctic/llama4).
    if (
        runtime.moe_dispatch == "a2a"
        and rules is not None
        and s % rules.tp == 0
        and (b // max(rules.dp, 1) if b >= rules.dp else b) * (s // rules.tp) >= 16
    ):
        out, lb = _a2a_dispatch(p, x, cfg, rules)
        if moe.dense_residual:
            out = out + mlp_apply(p["dense"], x, cfg, rules)
        return out, {"load_balance_loss": lb}

    t = b * s
    xt = x.reshape(t, d)

    gates = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (t, e)
    probs = jax.nn.softmax(gates, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, moe.top_k)  # (t, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    if runtime.moe_dispatch == "einsum":
        out = _einsum_dispatch(p, xt, top_w, top_e, cfg, rules)
    else:
        out = _ragged_dispatch(p, xt, top_w, top_e, cfg, rules)
    out = out.reshape(b, s, d).astype(x.dtype)

    if moe.dense_residual:
        out = out + mlp_apply(p["dense"], x, cfg, rules)

    # aux stats for load-balance loss / monitoring
    me = probs.mean(axis=0)  # (e,)
    ce = jnp.zeros_like(me).at[top_e.reshape(-1)].add(
        jnp.ones((t * moe.top_k,), jnp.float32)
    ) / (t * moe.top_k)
    aux = {"load_balance_loss": moe.n_experts * jnp.sum(me * ce)}
    return out, aux


def _einsum_dispatch(p, xt, top_w, top_e, cfg, rules):
    """GShard-style dense dispatch with capacity-factor token dropping."""
    t, d = xt.shape
    e = cfg.moe.n_experts
    cap = _capacity(t, cfg)
    act = act_fn(cfg.act)

    # position of each (token, k) within its expert's capacity
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)  # (t, k, e)
    pos_in_e = (jnp.cumsum(onehot.reshape(t * cfg.moe.top_k, e), axis=0) - 1)
    pos_in_e = pos_in_e.reshape(t, cfg.moe.top_k, e)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)  # (t, k)
    keep = pos < cap
    w = jnp.where(keep, top_w, 0.0)

    # dispatch (t, e, cap) — combine weights and boolean dispatch mask
    disp = jnp.einsum(
        "tke,tkc->tec",
        jax.nn.one_hot(top_e, e, dtype=jnp.float32) * keep[..., None],
        jax.nn.one_hot(pos, cap, dtype=jnp.float32),
    )
    comb = jnp.einsum(
        "tke,tkc->tec",
        jax.nn.one_hot(top_e, e, dtype=jnp.float32) * w[..., None],
        jax.nn.one_hot(pos, cap, dtype=jnp.float32),
    )
    if rules is not None:
        disp = constrain(disp, rules, ("batch", "experts", None))
        comb = constrain(comb, rules, ("batch", "experts", None))

    xin = jnp.einsum("tec,td->ecd", disp.astype(xt.dtype), xt)  # (e, cap, d)
    if rules is not None:
        xin = constrain(xin, rules, ("experts", None, None))
    g = jnp.einsum("ecd,edf->ecf", xin, p["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", xin, p["wi_up"])
    h = act(g) * u
    eo = jnp.einsum("ecf,efd->ecd", h, p["wo"])  # (e, cap, d)
    if rules is not None:
        eo = constrain(eo, rules, ("experts", None, None))
    out = jnp.einsum("tec,ecd->td", comb.astype(eo.dtype), eo)
    if rules is not None:
        out = constrain(out, rules, ("batch", "act_embed"))
    return out


def _round4(x: int) -> int:
    return max(4, -(-x // 4) * 4)


def _a2a_dispatch(p, x, cfg, rules):
    """Expert parallelism with explicit all-to-all inside shard_map.

    The production path (beyond-paper distributed optimization): tokens stay
    on their data shard; only the routed rows cross the `model` axis in two
    all-to-alls (forward + return). Dispatch is local scatter/gather —
    O(t·k·d) data movement, ZERO dispatch matmul FLOPs — versus the GShard
    one-hot einsum path whose dispatch costs O(t·e·cap·d) and dominated the
    MoE cells' compute term ~10x in the baseline roofline.

    Two capacity stages, both local: per-destination-shard capacity for the
    a2a buffer, then per-local-expert capacity for the batched matmuls.
    """
    moe = cfg.moe
    mesh = rules.mesh
    tp = rules.tp
    e = moe.n_experts
    e_loc = e // tp
    k = moe.top_k
    d = cfg.d_model
    f = cfg.d_ff
    act = act_fn(cfg.act)
    batch_ax = rules.rules.get("batch")
    if isinstance(batch_ax, str):
        batch_ax = (batch_ax,)

    b, s, _ = x.shape
    dp = rules.dp
    # tokens are sequence-sharded over `model` INSIDE the shard_map: without
    # this, all tp model-peers hold identical tokens and each would route +
    # send + compute the same rows — a measured 16x duplication of expert
    # FLOPs in the first a2a iteration (EXPERIMENTS.md §Perf iter 3b).
    t_shard = (b // dp if b >= dp else b) * (s // tp)
    cap_pair = _round4(int(moe.capacity_factor * k * max(t_shard, 1) / tp))
    # per-local-expert matmul capacity: with e_loc == 1 every valid row goes
    # to the single local expert, so NO extra slack is needed (a 1.5x slack
    # here inflated jamba's expert FLOPs 1.5x — measured); with e_loc > 1
    # keep slack for imbalance among local experts.
    rows = tp * cap_pair
    cap_e = rows if e_loc == 1 else _round4(int(1.25 * rows / e_loc))

    def local_fn(x_loc, router_w, wi_g, wi_u, wo):
        bl, sl, _ = x_loc.shape
        tl = bl * sl
        xt = x_loc.reshape(tl, d)
        gates = xt.astype(jnp.float32) @ router_w.astype(jnp.float32)
        probs = jax.nn.softmax(gates, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)  # (tl, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        flat_e = top_e.reshape(-1)  # (tl*k,)
        flat_w = top_w.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(tl), k)
        dest = flat_e // e_loc  # destination model-shard
        leid = flat_e % e_loc  # local expert id at the destination

        # position within the destination shard's send capacity
        onehot_d = jax.nn.one_hot(dest, tp, dtype=jnp.int32)  # (tl*k, tp)
        pos = jnp.sum((jnp.cumsum(onehot_d, axis=0) - 1) * onehot_d, -1)
        keep = pos < cap_pair
        pos = jnp.where(keep, pos, cap_pair - 1)
        w = jnp.where(keep, flat_w, 0.0)

        send_x = jnp.zeros((tp, cap_pair, d), x_loc.dtype)
        send_x = send_x.at[dest, pos].add(
            xt[flat_tok] * keep[:, None].astype(xt.dtype), mode="drop"
        )
        send_eid = jnp.full((tp, cap_pair), e_loc, jnp.int32)  # e_loc = empty
        send_eid = send_eid.at[dest, pos].set(
            jnp.where(keep, leid, e_loc), mode="drop"
        )

        # ---- forward all-to-all over the model axis ----
        recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=True)
        recv_eid = jax.lax.all_to_all(send_eid, "model", 0, 0, tiled=True)

        rows_x = recv_x.reshape(tp * cap_pair, d)
        rows_e = recv_eid.reshape(tp * cap_pair)
        valid = rows_e < e_loc

        # pack rows by local expert (second local scatter)
        onehot_e = jax.nn.one_hot(
            jnp.where(valid, rows_e, e_loc), e_loc + 1, dtype=jnp.int32
        )[:, :e_loc]
        pos_e = jnp.sum((jnp.cumsum(onehot_e, axis=0) - 1) * onehot_e, -1)
        keep_e = jnp.logical_and(valid, pos_e < cap_e)
        pos_e = jnp.where(keep_e, pos_e, cap_e - 1)
        eidx = jnp.where(valid, rows_e, 0)

        xin = jnp.zeros((e_loc, cap_e, d), rows_x.dtype)
        xin = xin.at[eidx, pos_e].add(
            rows_x * keep_e[:, None].astype(rows_x.dtype), mode="drop"
        )

        g = jnp.einsum("ecd,edf->ecf", xin, wi_g)
        u = jnp.einsum("ecd,edf->ecf", xin, wi_u)
        h = act(g) * u
        eo = jnp.einsum("ecf,efd->ecd", h, wo)  # (e_loc, cap_e, d)

        y_rows = eo[eidx, pos_e] * keep_e[:, None].astype(eo.dtype)
        y_send = y_rows.reshape(tp, cap_pair, d)

        # ---- return all-to-all ----
        y_recv = jax.lax.all_to_all(y_send, "model", 0, 0, tiled=True)

        out = jnp.zeros((tl, d), y_recv.dtype)
        out = out.at[flat_tok].add(
            y_recv[dest, pos] * w[:, None].astype(y_recv.dtype), mode="drop"
        )

        # load-balance stats (replicated via pmean so out_spec can be P())
        me = probs.mean(axis=0)
        ce = (
            jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0, mode="drop")
            / max(tl * k, 1)
        )
        lb = e * jnp.sum(me * ce)
        axes = tuple(batch_ax or ()) + ("model",)
        lb = jax.lax.pmean(lb, axes)
        return out.reshape(bl, sl, d).astype(x_loc.dtype), lb

    in_specs = (
        P(batch_ax, "model", None),  # x: batch over data, SEQ over model
        P(None, None),  # router (replicated)
        P("model", None, None),  # wi_gate
        P("model", None, None),  # wi_up
        P("model", None, None),  # wo
    )
    out_specs = (P(batch_ax, "model", None), P())
    from repro.distributed.sharding import shard_map_compat

    fn = shard_map_compat(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )
    return fn(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])


def _ragged_dispatch(p, xt, top_w, top_e, cfg, rules):
    """Scatter-based dispatch (hillclimb variant).

    Replaces the O(t·e·cap) one-hot dispatch/combine einsums with
    scatter-add into the (e, cap, d) expert buffer and gather back out —
    O(t·k·d) data movement. The per-expert matmuls are unchanged.
    """
    t, d = xt.shape
    e = cfg.moe.n_experts
    k = cfg.moe.top_k
    cap = _capacity(t, cfg)
    act = act_fn(cfg.act)

    flat_e = top_e.reshape(-1)  # (t*k,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)

    # position of each (token, k) within its expert's capacity
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (t*k, e)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=-1)  # (t*k,)
    keep = pos < cap
    pos = jnp.where(keep, pos, cap - 1)
    w = jnp.where(keep, flat_w, 0.0)

    xin = jnp.zeros((e, cap, d), xt.dtype)
    src = xt[flat_tok] * keep[:, None].astype(xt.dtype)
    xin = xin.at[flat_e, pos].add(src, mode="drop")
    if rules is not None:
        xin = constrain(xin, rules, ("experts", None, None))

    g = jnp.einsum("ecd,edf->ecf", xin, p["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", xin, p["wi_up"])
    h = act(g) * u
    eo = jnp.einsum("ecf,efd->ecd", h, p["wo"])  # (e, cap, d)
    if rules is not None:
        eo = constrain(eo, rules, ("experts", None, None))

    picked = eo[flat_e, pos] * w[:, None].astype(eo.dtype)  # (t*k, d)
    out = jnp.zeros((t, d), eo.dtype).at[flat_tok].add(picked)
    if rules is not None:
        out = constrain(out, rules, ("batch", "act_embed"))
    return out

"""Attention: chunked flash (jnp portable path) + GQA decode.

Two decode strategies (RuntimeConfig.decode_kv):

* ``replicated``       — paper-faithful baseline: KV heads replicated across
                         TP shards, every chip reads the full KV cache.
* ``pool_interleaved`` — beyond-paper (Beluga O9 made TPU-native): the KV
                         sequence dimension is interleaved across chips; each
                         chip attends over its local shard and partial results
                         are merged with a log-sum-exp ``psum`` (distributed
                         flash-decode) inside ``shard_map``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RuntimeConfig
from repro.distributed.sharding import AxisRules, ParamSpec, constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def kv_heads_sharded(cfg: ModelConfig, rules: AxisRules | None) -> bool:
    """True when the KV heads themselves divide the TP degree."""
    return rules is not None and cfg.n_kv_heads % rules.tp == 0


def attn_params(cfg: ModelConfig, tp: int) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    hq = cfg.padded_heads(tp)
    hkv = cfg.n_kv_heads
    dt = cfg.dtype
    # KV projections are stored flattened (d, hkv*hd) and TP-sharded over
    # `model` on the flattened dim: the matmul is always balanced; when
    # hkv % tp != 0 the (small) activation is all-gathered before attention
    # instead of replicating the projection compute 16x.
    p = {
        "wq": ParamSpec((d, hq, hd), dt, ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, hkv * hd), dt, ("embed", "kv_flat")),
        "wv": ParamSpec((d, hkv * hd), dt, ("embed", "kv_flat")),
        "wo": ParamSpec((hq, hd, d), dt, ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((hq, hd), dt, ("heads", "head_dim"), init="zeros")
        p["bk"] = ParamSpec((hkv * hd,), dt, ("kv_flat",), init="zeros")
        p["bv"] = ParamSpec((hkv * hd,), dt, ("kv_flat",), init="zeros")
    if cfg.attn_out_bias:
        p["bo"] = ParamSpec((d,), dt, ("norm",), init="zeros")
    return p


def qkv_proj(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
             rules: AxisRules | None):
    """x: (b, s, d) -> q (b,s,hq,hd), k/v (b,s,hkv,hd), with RoPE applied."""
    b, s, _ = x.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k2 = x @ p["wk"]  # (b, s, hkv*hd) sharded over model
    v2 = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k2 = k2 + p["bk"]
        v2 = v2 + p["bv"]
    if rules is not None:
        k2 = constrain(k2, rules, ("batch", "seq", "act_mlp"))
        v2 = constrain(v2, rules, ("batch", "seq", "act_mlp"))
    k = k2.reshape(b, s, hkv, hd)
    v = v2.reshape(b, s, hkv, hd)
    q = apply_rope_heads(q, positions, cfg.rope_theta)
    k = apply_rope_heads(k, positions, cfg.rope_theta)
    if rules is not None:
        kv_ax = "act_heads" if kv_heads_sharded(cfg, rules) else None
        q = constrain(q, rules, ("batch", "seq", "act_heads", None))
        k = constrain(k, rules, ("batch", "seq", kv_ax, None))
        v = constrain(v, rules, ("batch", "seq", kv_ax, None))
    return q, k, v


def apply_rope_heads(x, positions, theta):
    from repro.models.layers import apply_rope

    return apply_rope(x, positions, theta)


def out_proj(p: dict, attn_out: jax.Array, rules: AxisRules | None) -> jax.Array:
    if rules is not None and rules.rowp_bf16:
        from repro.distributed.collectives import row_parallel_matmul

        b, s, hq, hd = attn_out.shape
        out = row_parallel_matmul(
            attn_out.reshape(b, s, hq * hd), p["wo"].reshape(hq * hd, -1), rules
        )
    else:
        out = jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"])
    if "bo" in p:
        out = out + p["bo"]
    if rules is not None:
        out = constrain(out, rules, ("batch", "seq", "act_embed"))
    return out


# ---------------------------------------------------------------------------
# Chunked flash attention (portable jnp path; the TPU hot path is the Pallas
# kernel in repro.kernels.flash_attention, numerics-checked against this).
# ---------------------------------------------------------------------------


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(b, s, hkv, d) -> (b, s, hkv*n_rep, d) by group broadcast."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
    softmax_scale: float | None = None,
) -> jax.Array:
    """Chunked (flash-style) attention with running softmax.

    q: (b, sq, hq, d); k, v: (b, skv, hkv, d); GQA via on-the-fly repeat of
    the kv chunk.  ``q_offset`` is the absolute position of q[:, 0] for
    causal masking against the kv positions; ``kv_len`` masks a ragged tail.
    """
    b, sq_in, hq, d = q.shape
    _, skv_in, hkv, _ = k.shape
    n_rep = hq // hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)

    chunk_q = min(chunk_q, sq_in)
    chunk_kv = min(chunk_kv, skv_in)
    # pad ragged tails up to chunk multiples; tail is masked via kv_len
    sq = -(-sq_in // chunk_q) * chunk_q
    skv = -(-skv_in // chunk_kv) * chunk_kv
    if sq != sq_in:
        q = jnp.pad(q, ((0, 0), (0, sq - sq_in), (0, 0), (0, 0)))
    if skv != skv_in:
        k = jnp.pad(k, ((0, 0), (0, skv - skv_in), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv - skv_in), (0, 0), (0, 0)))
        kv_len = jnp.minimum(
            skv_in if kv_len is None else kv_len, jnp.asarray(skv_in)
        )
    nq = sq // chunk_q
    nkv = skv // chunk_kv

    q = q * scale
    qs = q.reshape(b, nq, chunk_q, hq, d).transpose(1, 0, 2, 3, 4)

    def per_q_chunk(qi, q_chunk):
        q_pos = q_offset + qi * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry, ci):
            acc, m, l = carry
            k_chunk = jax.lax.dynamic_slice_in_dim(k, ci * chunk_kv, chunk_kv, 1)
            v_chunk = jax.lax.dynamic_slice_in_dim(v, ci * chunk_kv, chunk_kv, 1)
            k_chunk = _repeat_kv(k_chunk, n_rep)
            v_chunk = _repeat_kv(v_chunk, n_rep)
            s_ij = jnp.einsum(
                "bqhd,bkhd->bhqk", q_chunk, k_chunk, preferred_element_type=jnp.float32
            )
            kv_pos = ci * chunk_kv + jnp.arange(chunk_kv)
            mask = jnp.ones((chunk_q, chunk_kv), jnp.bool_)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if kv_len is not None:
                mask &= kv_pos[None, :] < kv_len
            s_ij = jnp.where(mask[None, None], s_ij, NEG_INF)
            m_new = jnp.maximum(m, s_ij.max(axis=-1))
            p_ij = jnp.exp(s_ij - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p_ij.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p_ij.astype(v_chunk.dtype), v_chunk,
                preferred_element_type=jnp.float32,
            )
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, hq, chunk_q, d), jnp.float32)
        m0 = jnp.full((b, hq, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, chunk_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), jnp.arange(nkv)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3)  # (b, cq, hq, d)

    outs = jax.lax.map(
        lambda args: per_q_chunk(args[0], args[1]), (jnp.arange(nq), qs)
    )  # (nq, b, cq, hq, d)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, d)
    return out[:, :sq_in].astype(v.dtype)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------


def decode_attention_replicated(
    q: jax.Array,  # (b, 1, hq, d)
    k_cache: jax.Array,  # (b, s_max, hkv, d)
    v_cache: jax.Array,
    cache_len: jax.Array,  # (b,) or scalar
) -> jax.Array:
    """Baseline: every chip reads the full KV cache (KV replicated over TP)."""
    b, _, hq, d = q.shape
    k_cache, v_cache = _dequant(k_cache), _dequant(v_cache)
    hkv = k_cache.shape[2]
    n_rep = hq // hkv
    scale = 1.0 / math.sqrt(d)
    # keep q in the cache dtype: a mixed-dtype einsum would make XLA
    # materialize an f32 copy of the whole cache (seen in the roofline HLO)
    qg = (q[:, 0] * scale).astype(k_cache.dtype).reshape(b, hkv, n_rep, d)
    s = jnp.einsum(
        "bgrd,bkgd->bgrk", qg, k_cache, preferred_element_type=jnp.float32
    )
    pos = jnp.arange(k_cache.shape[1])
    mask = pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bgrk,bkgd->bgrd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def _local_partial_attn(q, k_shard, v_shard, local_mask):
    """Per-shard partial flash-decode: returns (num, den, max) for LSE merge.

    q: (b, hq, d) pre-scaled; k/v_shard: (b, s_loc, hkv, d);
    local_mask: (b, s_loc) bool validity.
    """
    b, hq, d = q.shape
    k_shard, v_shard = _dequant(k_shard), _dequant(v_shard)
    hkv = k_shard.shape[2]
    n_rep = hq // hkv
    qg = q.astype(k_shard.dtype).reshape(b, hkv, n_rep, d)  # no f32 cache copy
    s = jnp.einsum(
        "bgrd,bkgd->bgrk", qg, k_shard, preferred_element_type=jnp.float32
    )
    s = jnp.where(local_mask[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)  # (b, g, r)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(local_mask[:, None, None, :], p, 0.0)
    den = p.sum(axis=-1)
    num = jnp.einsum(
        "bgrk,bkgd->bgrd", p.astype(v_shard.dtype), v_shard,
        preferred_element_type=jnp.float32,
    )
    return num, den, m


def decode_attention_interleaved(
    q: jax.Array,  # (b, 1, hq, d) -- globally replicated heads inside shard_map
    k_cache: jax.Array,  # (b, s_max, hkv, d) seq-sharded over `axes`
    v_cache: jax.Array,
    cache_len: jax.Array,  # (b,)
    mesh,
    axes: tuple[str, ...],
    batch_axes: tuple[str, ...] = (),
) -> jax.Array:
    """Beluga-O9 decode: KV seq interleaved across `axes`; LSE-merge psum.

    Entered from the GSPMD world via shard_map. q must be replicated over
    `axes`; the kv caches are sharded on their seq dim.
    """
    b, _, hq, d = q.shape
    scale = 1.0 / math.sqrt(d)

    b_ax = batch_axes if batch_axes else None

    def local_fn(q, k_shard, v_shard, cache_len):
        # row-major shard id across the (possibly multiple) kv axes
        # (lax.axis_size only exists in jax >= 0.4.38; psum(1) is the
        # classic spelling of the same quantity)
        shard_id = 0
        for ax in axes:
            size = (
                jax.lax.axis_size(ax)
                if hasattr(jax.lax, "axis_size")
                else jax.lax.psum(1, ax)
            )
            shard_id = shard_id * size + jax.lax.axis_index(ax)
        b_loc, s_loc = k_shard.shape[0], k_shard.shape[1]
        pos = shard_id * s_loc + jnp.arange(s_loc)
        local_mask = pos[None, :] < jnp.reshape(cache_len, (-1, 1))
        num, den, m = _local_partial_attn(q[:, 0] * scale, k_shard, v_shard, local_mask)
        # LSE merge across shards
        g_m = jax.lax.pmax(m, axes)
        corr = jnp.exp(m - g_m)
        num = jax.lax.psum(num * corr[..., None], axes)
        den = jax.lax.psum(den * corr, axes)
        out = num / jnp.maximum(den, 1e-30)[..., None]
        return out.reshape(b_loc, 1, hq, d).astype(q.dtype)

    in_specs = (
        P(b_ax, None, None, None),  # q: (b, 1, hq, d)
        P(b_ax, axes, None, None),  # k: seq interleaved across `axes`
        P(b_ax, axes, None, None),  # v
        P(b_ax),  # cache_len
    )
    out_specs = P(b_ax, None, None, None)
    from repro.distributed.sharding import shard_map_compat

    fn = shard_map_compat(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )
    return fn(q, k_cache, v_cache, cache_len)


def update_kv_cache(
    k_cache: jax.Array,  # (b, s_max, hkv, d)
    v_cache: jax.Array,
    k_new: jax.Array,  # (b, 1, hkv, d)
    v_new: jax.Array,
    pos: jax.Array,  # (b,) write positions
):
    """Scatter one new token into the ring cache at per-sequence positions.

    Handles quantized (fp8) caches: new KV is cast to the cache dtype (keys
    after RoPE are O(1), within e4m3 range — standard scale-free fp8 KV).
    """
    b = k_cache.shape[0]
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, pos].set(k_new[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, pos].set(v_new[:, 0].astype(v_cache.dtype))
    return k_cache, v_cache


def _dequant(kv: jax.Array) -> jax.Array:
    """fp8 caches are dequantized to bf16 at the attention boundary (on TPU
    the convert fuses into the attention kernel's tile loads)."""
    if kv.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2):
        return kv.astype(jnp.bfloat16)
    return kv

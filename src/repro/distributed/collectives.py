"""Explicit collective patterns (beyond what GSPMD chooses on its own).

``row_parallel_matmul`` — the Megatron TP epilogue with *collective
precision control*: each chip multiplies its column shard of the activation
by its row shard of the weight, downcasts the partial result to the
activation dtype (bf16), and THEN psums across the ``model`` axis.  Letting
the partitioner place the all-reduce instead reduces the f32 accumulator —
2x the bytes on every TP boundary (measured on all train cells; cf.
EXPERIMENTS.md §Perf iteration 4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import AxisRules


def row_parallel_matmul(
    x: jax.Array,  # (b, s, f) activation, f sharded over `model`
    w: jax.Array,  # (f, d) weight, rows sharded over `model`
    rules: AxisRules,
) -> jax.Array:
    """psum_bf16(x_loc @ w_loc) over the model axis."""
    mesh = rules.mesh
    batch_ax = rules.rules.get("batch")
    if isinstance(batch_ax, str):
        batch_ax = (batch_ax,)
    dp = rules.dp
    b = x.shape[0]
    b_ax = tuple(batch_ax) if (batch_ax and b % dp == 0 and b >= dp) else None
    out_dtype = x.dtype

    def local_fn(xl, wl):
        out = jnp.einsum("bsf,fd->bsd", xl, wl)
        out = out.astype(out_dtype)  # downcast BEFORE the cross-chip sum
        return jax.lax.psum(out, "model")

    fn = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(b_ax, None, "model"), P("model", None)),
        out_specs=P(b_ax, None, None),
        check_vma=False,
    )
    return fn(x, w)

"""Logical-axis sharding rules -> mesh PartitionSpecs.

Every parameter / activation in the model zoo is annotated with a tuple of
*logical* axis names.  ``AxisRules`` maps logical names to mesh axes for the
production meshes:

  single-pod  : (16, 16)      axes ("data", "model")
  multi-pod   : (2, 16, 16)   axes ("pod", "data", "model")

Weights are TP-sharded over ``model`` (heads / d_ff / vocab / experts) and
FSDP-sharded over ``data`` (+``pod`` in the multi-pod mesh) on the remaining
large dimension.  The ``pod`` axis is pure data parallelism for activations.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


MeshAxes = tuple[str, ...] | str | None


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (jax >= 0.4.38, kwarg ``check_vma``) or the
    ``jax.experimental.shard_map`` original (kwarg ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


def _default_rules(multi_pod: bool) -> dict[str, MeshAxes]:
    fsdp: MeshAxes = ("pod", "data") if multi_pod else ("data",)
    batch: MeshAxes = ("pod", "data") if multi_pod else ("data",)
    return {
        # --- weight axes ---
        "embed": fsdp,  # d_model dim of weights (FSDP)
        "vocab": "model",
        "heads": "model",
        "kv_heads": None,  # replicated (GQA kv < TP degree)
        "kv_flat": "model",  # flattened (hkv*hd) KV projection columns
        "head_dim": None,
        "mlp": "model",
        "experts": "model",
        "expert_mlp": None,
        "ssm_inner": "model",  # d_inner / ssm heads
        "ssm_state": None,
        "conv_dim": None,
        "layers": None,  # stacked-scan leading dim
        "norm": None,
        # --- activation axes ---
        "batch": batch,
        "seq": None,
        "act_embed": None,  # d_model dim of activations
        "act_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "kv_seq": "model",  # pool-interleaved KV sequence (Beluga O9)
        "kv_seq_long": ("data", "model"),  # long-context single-request decode
        "pool_blocks": "model",  # Beluga pool block interleaving
    }


@dataclass(frozen=True)
class AxisRules:
    mesh: Mesh
    rules: dict[str, MeshAxes]
    # Explicit row-parallel matmuls: shard_map + psum of bf16 partials.
    # Halves TP all-reduce bytes vs letting the partitioner reduce the f32
    # accumulator (measured 2x on every train cell) — Megatron-style
    # collective precision control.
    rowp_bf16: bool = False

    @classmethod
    def create(
        cls,
        mesh: Mesh,
        overrides: dict[str, MeshAxes] | None = None,
        rowp_bf16: bool = False,
    ) -> "AxisRules":
        multi_pod = "pod" in mesh.axis_names
        rules = _default_rules(multi_pod)
        if overrides:
            rules.update(overrides)
        return cls(mesh=mesh, rules=rules, rowp_bf16=rowp_bf16)

    # ------------------------------------------------------------------
    def spec(self, logical_axes: tuple[str | None, ...]) -> P:
        """PartitionSpec for a tuple of logical axis names."""
        out: list[MeshAxes] = []
        used: set[str] = set()
        for ax in logical_axes:
            if ax is None:
                out.append(None)
                continue
            if ax not in self.rules:
                raise KeyError(f"unknown logical axis {ax!r}")
            mesh_ax = self.rules[ax]
            # drop mesh axes already used by an earlier dim (illegal in a spec)
            if isinstance(mesh_ax, tuple):
                mesh_ax = tuple(m for m in mesh_ax if m not in used)
                mesh_ax = mesh_ax if mesh_ax else None
            elif mesh_ax in used:
                mesh_ax = None
            if mesh_ax is None:
                out.append(None)
            elif isinstance(mesh_ax, tuple):
                used.update(mesh_ax)
                out.append(mesh_ax)
            else:
                used.add(mesh_ax)
                out.append(mesh_ax)
        return P(*out)

    def sharding(self, logical_axes: tuple[str | None, ...]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes))

    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name]

    @property
    def tp(self) -> int:
        return self.mesh.shape["model"]

    @property
    def dp(self) -> int:
        n = self.mesh.shape["data"]
        if "pod" in self.mesh.axis_names:
            n *= self.mesh.shape["pod"]
        return n


def constrain(x: jax.Array, rules: AxisRules, logical_axes: tuple) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(logical_axes))


# ---------------------------------------------------------------------------
# Param-tree <-> spec-tree plumbing
# ---------------------------------------------------------------------------


class ParamSpec:
    """A leaf descriptor: shape + dtype + logical axes + init scale."""

    __slots__ = ("shape", "dtype", "logical_axes", "init", "scale")

    def __init__(self, shape, dtype, logical_axes, init="normal", scale=0.02):
        assert len(shape) == len(logical_axes), (shape, logical_axes)
        self.shape = tuple(int(s) for s in shape)
        self.dtype = dtype
        self.logical_axes = tuple(logical_axes)
        self.init = init
        self.scale = scale

    def __repr__(self):
        return f"ParamSpec({self.shape}, {self.dtype}, {self.logical_axes})"


def is_param_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_specs(param_tree: Any, rules: AxisRules) -> Any:
    """Map a tree of ParamSpec leaves to PartitionSpecs."""
    return jax.tree.map(
        lambda p: rules.spec(p.logical_axes), param_tree, is_leaf=is_param_spec
    )


def tree_shardings(param_tree: Any, rules: AxisRules) -> Any:
    return jax.tree.map(
        lambda p: rules.sharding(p.logical_axes), param_tree, is_leaf=is_param_spec
    )


def tree_shape_dtype(param_tree: Any) -> Any:
    import jax.numpy as jnp

    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype)),
        param_tree,
        is_leaf=is_param_spec,
    )


def init_tree(param_tree: Any, key: jax.Array) -> Any:
    """Materialize parameters (smoke tests / examples only)."""
    import jax.numpy as jnp

    leaves, treedef = jax.tree.flatten(param_tree, is_leaf=is_param_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        elif spec.init == "normal":
            arr = (
                jax.random.normal(k, spec.shape, jnp.float32) * spec.scale
            ).astype(spec.dtype)
        elif spec.init == "ssm_a":  # A_log init: log of uniform [1, 16]
            u = jax.random.uniform(k, spec.shape, jnp.float32, 1.0, 16.0)
            arr = jnp.log(u).astype(spec.dtype)
        elif spec.init == "ssm_dt":  # dt_bias: softplus^-1(uniform[1e-3, 1e-1])
            u = jax.random.uniform(k, spec.shape, jnp.float32, 1e-3, 1e-1)
            arr = (u + jnp.log(-jnp.expm1(-u))).astype(spec.dtype)
        else:
            raise ValueError(spec.init)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)

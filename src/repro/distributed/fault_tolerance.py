"""Fault tolerance for 1000+-node runs: failure detection, elastic re-mesh,
straggler mitigation.

The container runs one process, so the *policies* here are exercised by unit
tests + the cluster sim while the multi-host wiring points (noted inline)
use the standard jax.distributed primitives on a real pod.

Training-side contract:
  * ``HeartbeatMonitor``   — per-host liveness with grace windows (on a real
    pod: backed by the coordination service barrier/KV; here: injected
    clocks for tests);
  * ``ElasticPlan``        — given a failed host set, compute the largest
    valid production sub-mesh and the re-shard plan: which checkpoint shards
    each surviving host loads (checkpointer shards are host-agnostic, so a
    (2,16,16) run restarts as (16,16) by re-reading the manifest with the
    smaller mesh's shardings — no per-host affinity);
  * ``StragglerPolicy``    — per-step duration tracking; hosts slower than
    ``k × median`` over a window are flagged for replacement (training) —
    the serving twin is the fetch-vs-recompute cutover in KVCacheManager.

Serving-side contract (the self-healing metadata plane, PR 6):
  * ``FaultEvent``/``FaultPlan`` — declarative chaos schedule: kill a
    shard service, or open a delayed/dropped-reply window, at a virtual
    time into the run;
  * ``FaultInjector``   — applies a plan against live ``ShardSupervisor``s
    (kills) and wraps shard RPC clients (delay/drop windows), so the
    differential equivalence harness and the exp11 chaos sweep drive the
    SAME failure schedule through real processes.
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field


@dataclass
class HeartbeatMonitor:
    n_hosts: int
    timeout_s: float = 30.0
    last_beat: dict[int, float] = field(default_factory=dict)

    def beat(self, host: int, now: float | None = None) -> None:
        self.last_beat[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: float | None = None) -> list[int]:
        t = time.monotonic() if now is None else now
        return [
            h
            for h in range(self.n_hosts)
            if t - self.last_beat.get(h, -1e18) > self.timeout_s
        ]


@dataclass(frozen=True)
class ElasticPlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axes: tuple[str, ...]
    restart_step: int
    note: str

    @property
    def degraded(self) -> bool:
        import math

        return math.prod(self.new_shape) < math.prod(self.old_shape)


def plan_elastic_remesh(
    mesh_shape: tuple[int, ...],
    axes: tuple[str, ...],
    hosts_per_unit: int,
    failed_hosts: list[int],
    checkpoint_step: int,
) -> ElasticPlan:
    """Shrink along the outermost data-parallel axis.

    Model sharding (the `model` axis) is never shrunk — TP degree is a
    property of the checkpointed layout. DP (pod then data) shrinks by whole
    slices: fail one host in a pod slice -> drop that slice, redistribute
    batch. Checkpoints are mesh-agnostic (manifest + index ranges), so the
    surviving mesh simply re-reads with its own shardings.
    """
    if not failed_hosts:
        return ElasticPlan(mesh_shape, mesh_shape, axes, checkpoint_step, "no-op")
    shape = list(mesh_shape)
    # outermost DP axis: "pod" when present, else "data"
    dp_axis = 0 if axes[0] in ("pod", "data") else None
    assert dp_axis is not None, axes
    units_per_slice = 1
    for d in shape[1:]:
        units_per_slice *= d
    # map failed hosts to slices of the outer axis
    failed_slices = sorted(
        {h // max(1, (units_per_slice // hosts_per_unit) or 1) for h in failed_hosts}
    )
    new_outer = shape[0] - len([s for s in failed_slices if s < shape[0]])
    if new_outer < 1:
        raise RuntimeError("all DP slices failed; cannot re-mesh")
    new_shape = tuple([new_outer] + shape[1:])
    return ElasticPlan(
        tuple(mesh_shape),
        new_shape,
        axes,
        checkpoint_step,
        f"dropped {len(failed_slices)} {axes[0]}-slice(s); restart from "
        f"step {checkpoint_step}; global batch rescaled by "
        f"{new_outer}/{shape[0]}",
    )


@dataclass
class StragglerPolicy:
    window: int = 20
    slow_factor: float = 1.5
    history: dict[int, list[float]] = field(default_factory=dict)

    def record(self, host: int, step_time: float) -> None:
        h = self.history.setdefault(host, [])
        h.append(step_time)
        if len(h) > self.window:
            h.pop(0)

    def stragglers(self) -> list[int]:
        if len(self.history) < 2:
            return []
        medians = {h: statistics.median(v) for h, v in self.history.items() if v}
        if not medians:
            return []
        global_med = statistics.median(medians.values())
        return [
            h for h, m in medians.items() if m > self.slow_factor * global_med
        ]


# ---------------------------------------------------------------------------
# serving-side chaos: declarative fault schedules against the metadata plane
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``kind``:
      * ``"kill"``  — SIGKILL the shard's service process at ``t`` (the
        supervisor detects and heals it);
      * ``"delay"`` — for ``[t, t + duration)`` every RPC post on the
        shard — serial ``call`` and pipelined rounds alike — sleeps
        ``delay_s`` before posting (slow-service window);
      * ``"drop"``  — for ``[t, t + duration)`` every RPC post on the
        shard raises ``TimeoutError`` instead of posting (lost-request
        window; the client's retry/degrade policy decides what happens);
      * ``"kill_worker"``    — SIGKILL engine worker ``shard`` (the
        worker supervisor detects it, reconciles its pool leases,
        respawns it and replays its un-acked submits);
      * ``"kill_allocator"`` — trigger the cluster's allocator-outage
        hook (a rolling allocator-ring restart: workers cut over via the
        command-plane ADOPT, in-flight allocator ops retry).
    """

    t: float
    kind: str  # "kill" | "delay" | "drop" | "kill_worker" | "kill_allocator"
    shard: int = 0
    duration: float = 0.0
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in (
            "kill", "delay", "drop", "kill_worker", "kill_allocator"
        ):
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultPlan:
    """A time-sorted fault schedule with a one-way cursor.

    ``due(now)`` hands back every not-yet-applied event whose time has
    come (kills are applied once); ``active(shard, now)`` reports the
    delay/drop windows covering ``now`` (windows are stateless — purely
    a function of the plan and the clock)."""

    def __init__(self, events: list[FaultEvent]):
        self.events = sorted(events, key=lambda e: e.t)
        self._cursor = 0

    def due(self, now: float) -> list[FaultEvent]:
        out = []
        while self._cursor < len(self.events) and \
                self.events[self._cursor].t <= now:
            out.append(self.events[self._cursor])
            self._cursor += 1
        return out

    def pending(self) -> int:
        return len(self.events) - self._cursor

    def active(self, shard: int, now: float) -> list[FaultEvent]:
        return [
            e
            for e in self.events
            if e.kind in ("delay", "drop")
            and e.shard == shard
            and e.t <= now < e.t + e.duration
        ]


class FaultInjector:
    """Drive a ``FaultPlan`` against a live sharded metadata plane.

    * kills go through ``supervisors[shard].kill()`` — a real SIGKILL of
      a real child process, healed by the real supervisor;
    * delay/drop windows wrap each shard's ``CxlRpcClient.post`` — the
      single choke point BOTH transfer paths funnel through (``call`` is
      ``collect(post(...))`` and pipelined pure-read rounds post each
      chunk themselves) — so the wire client's OWN retry/backoff/degrade
      machinery, not a test double, absorbs the fault.  A drop in a
      pipelined round surfaces exactly like a real wire loss: the round
      aborts, drains its outstanding slots and re-runs serially under
      the retry policy (still through the injected ``post``).

    The harness calls ``advance()`` between ops (or on a timer); the
    virtual clock starts at ``start()``.
    """

    def __init__(self, plan: FaultPlan, supervisors, clock=time.monotonic,
                 worker_supervisors=(), allocator=None):
        self.plan = plan
        self.supervisors = list(supervisors)
        # data-plane targets (PR 8): engine-worker supervisors for
        # ``kill_worker`` events, and the cluster's allocator-outage hook
        # (``Cluster.restart_allocator``) for ``kill_allocator``
        self.worker_supervisors = list(worker_supervisors)
        self.allocator = allocator
        self._clock = clock
        self._t0: float | None = None
        self.applied: list[FaultEvent] = []

    def start(self) -> "FaultInjector":
        self._t0 = self._clock()
        return self

    def now(self) -> float:
        return 0.0 if self._t0 is None else self._clock() - self._t0

    def attach_client(self, shard: int, rpc_client) -> None:
        """Wrap ``rpc_client.post`` with this plan's delay/drop windows
        (intercepts the serial ``call`` AND the pipelined split — both
        resolve ``post`` through the instance attribute)."""
        orig = rpc_client.post

        def post(payload: bytes) -> int:
            for ev in self.plan.active(shard, self.now()):
                if ev.kind == "drop":
                    raise TimeoutError(
                        f"fault-injected dropped request (shard {shard})"
                    )
                time.sleep(ev.delay_s)
            return orig(payload)

        rpc_client.post = post

    def advance(self, now: float | None = None) -> list[FaultEvent]:
        """Apply every event whose time has come; returns them."""
        fired = self.plan.due(self.now() if now is None else now)
        for ev in fired:
            if ev.kind == "kill" and ev.shard < len(self.supervisors):
                self.supervisors[ev.shard].kill()
            elif ev.kind == "kill_worker" and ev.shard < len(
                self.worker_supervisors
            ):
                self.worker_supervisors[ev.shard].kill()
            elif ev.kind == "kill_allocator" and self.allocator is not None:
                self.allocator()
            self.applied.append(ev)
        return fired

"""Two-tier KVCache manager: HBM paged cache <-> Beluga pool (paper §6).

Per-engine-instance object orchestrating the paper's full KVCache flow:

  new request  -> GlobalIndex.match_prefix (via CXL-RPC in the cluster sim)
               -> hit blocks: scatter-read pool -> HBM slots (TransferEngine)
               -> miss tokens: prefill computes them -> gather-write to pool
               -> publish (key, block, epoch) in the index
  decode       -> paged attention over HBM slots (device kernel)
  eviction     -> HBM slots recycled per-sequence; pool blocks LRU-evicted
                  by the index when the pool fills

Straggler mitigation (fetch-vs-recompute cutover): if the modeled fetch
latency for the hit prefix exceeds ``recompute_cutover`` x the estimated
recompute time, the manager *recomputes* instead of waiting on a slow/
contended pool — bounding tail latency under pool pressure (§6.3 story).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, OutOfPoolMemory
from repro.core.transfer import TransferEngine
from repro.kvcache.hbm_cache import HbmPagedCache, OutOfHbmBlocks


@dataclass
class FetchPlan:
    n_hit_tokens: int
    n_miss_tokens: int
    hit_blocks: list[tuple[bytes, int, int]]  # (key, block_id, epoch)
    fetch_latency: float  # modeled
    recompute: bool  # cutover decision
    keys: list[bytes] | None = None  # full chain (hashed once per request)


@dataclass
class ManagerStats:
    prefix_hits_tokens: int = 0
    prefix_miss_tokens: int = 0
    fetches: int = 0
    writebacks: int = 0
    recompute_cutovers: int = 0
    pool_evictions: int = 0


class KVCacheManager:
    def __init__(
        self,
        pool: BelugaPool,
        index: GlobalIndex,
        hbm: HbmPagedCache,
        transfer: TransferEngine,
        recompute_cutover: float | None = None,
        prefill_tok_per_s: float = 8000.0,
    ):
        self.pool = pool
        self.index = index
        self.hbm = hbm
        self.transfer = transfer
        self.recompute_cutover = recompute_cutover
        self.prefill_tok_per_s = prefill_tok_per_s
        self.stats = ManagerStats()

    # ------------------------------------------------------------------
    def plan_fetch(self, tokens: list[int]) -> FetchPlan:
        """Prefix match + fetch-vs-recompute decision."""
        bt = self.pool.layout.block_tokens
        keys = self.index.keys_for(tokens)
        hits = self.index.match_prefix_keys(keys)
        n_hit = len(hits) * bt
        n_miss = len(tokens) - n_hit
        # modeled fetch latency for the hit prefix (one fused kernel)
        t0 = self.transfer.stats.modeled_read_s
        lat = 0.0
        if hits:
            lat = self._fetch_latency(len(hits))
        recompute_time = n_hit / self.prefill_tok_per_s
        # straggler mitigation (beyond-paper): recompute instead of waiting
        # on a fetch slower than `cutover x` the recompute time. Disabled by
        # default so the RDMA baseline behaves like MoonCake (Fig. 13c).
        cutover = (
            self.recompute_cutover is not None
            and bool(hits)
            and lat > self.recompute_cutover * max(recompute_time, 1e-9)
        )
        if cutover:
            self.stats.recompute_cutovers += 1
            hits, n_hit, n_miss = [], 0, len(tokens)
        self.stats.prefix_hits_tokens += n_hit
        self.stats.prefix_miss_tokens += max(0, n_miss)
        return FetchPlan(n_hit, max(0, n_miss), hits, lat, cutover, keys)

    def _fetch_latency(self, n_blocks: int) -> float:
        import math

        from repro.core import fabric

        lay = self.pool.layout
        size = n_blocks * lay.block_bytes
        nfrag = n_blocks * lay.n_fragments
        if self.transfer.mode == "beluga":
            return fabric.gpu_transfer_latency(
                size, nfrag, method="fused_kernel", c=self.transfer.constants
            )
        t = fabric.rdma_transfer_latency(
            size, nfrag, gpu_side=True, c=self.transfer.constants
        )
        # LMCache-style super-block staging cost (alloc + CPU copies)
        sbt = max(self.transfer.super_block_tokens, lay.block_tokens)
        n_super = math.ceil(n_blocks * lay.block_tokens / sbt)
        return t + n_super * self.transfer.constants.rdma_sw_per_superblock

    # ------------------------------------------------------------------
    def fetch_into_hbm(self, seq_id: str, plan: FetchPlan) -> list[int]:
        """Scatter-read hit blocks into freshly allocated HBM slots."""
        if not plan.hit_blocks:
            self.hbm.register_sequence(seq_id, [])
            return []
        keys = [k for k, _, _ in plan.hit_blocks]
        block_ids = [b for _, b, _ in plan.hit_blocks]
        epochs = [e for _, _, e in plan.hit_blocks]
        self.pool.retain(block_ids)
        try:
            slots = self.hbm.allocate(len(block_ids), keys=keys)
        except OutOfHbmBlocks:
            self.pool.release(block_ids)
            raise
        try:
            self.transfer.scatter_read(block_ids, epochs)
            self.stats.fetches += 1
        finally:
            self.pool.release(block_ids)
        self.hbm.register_sequence(seq_id, slots)
        return slots

    def writeback(
        self, seq_id: str, tokens: list[int], kv_payload=None, keys=None
    ) -> int:
        """After prefill: gather-write full blocks to the pool + publish.

        Returns the number of blocks written. ``kv_payload`` optionally
        carries real per-block KV (tests); the cluster sim passes None and
        only the control plane + modeled latency run. ``keys`` optionally
        carries the chain from an earlier ``plan_fetch`` (hash once).
        """
        bt = self.pool.layout.block_tokens
        if keys is None:
            keys = self.index.keys_for(tokens)
        # only blocks not already in the pool need writing: one batched
        # index lookup + one vectorized epoch check (no per-key round-trips)
        entries = self.index.lookup_many(keys)
        known = [(i, e) for i, e in enumerate(entries) if e is not None]
        valid = set()
        if known:
            ok = self.pool.validate_epochs(
                [e.block_id for _, e in known], [e.epoch for _, e in known]
            )
            valid = {i for (i, _), good in zip(known, ok) if good}
        new_keys = [(i, k) for i, k in enumerate(keys) if i not in valid]
        if not new_keys:
            return 0
        try:
            block_ids = self.pool.allocate(len(new_keys))
        except OutOfPoolMemory:
            freed = self.index.evict_lru(len(new_keys) * 2)
            self.stats.pool_evictions += len(freed)
            try:
                block_ids = self.pool.allocate(len(new_keys))
            except OutOfPoolMemory:
                return 0  # pool full of referenced blocks: skip offload
        lay = self.pool.layout
        if kv_payload is None and self.pool.data is not None:
            kv_payload = np.zeros(
                (
                    len(new_keys),
                    lay.n_fragments,
                    lay.block_tokens,
                    lay.n_kv_heads,
                    lay.head_dim,
                ),
                np.float16,
            )
        epochs = self.transfer.gather_write(block_ids, kv_payload)
        self.index.publish_many(
            [key for _, key in new_keys], block_ids, epochs, bt
        )
        self.stats.writebacks += 1
        return len(new_keys)

    # ------------------------------------------------------------------
    def finish(self, seq_id: str) -> None:
        self.hbm.finish_sequence(seq_id)

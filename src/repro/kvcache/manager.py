"""Two-tier KVCache manager: HBM paged cache <-> Beluga pool (paper §6).

Per-engine-instance object orchestrating the paper's full KVCache flow:

  new request  -> GlobalIndex.match_prefix (via CXL-RPC in the cluster sim)
               -> hit blocks: scatter-read pool -> HBM slots (TransferEngine)
               -> miss tokens: prefill computes them -> gather-write to pool
               -> publish (key, block, epoch) in the index
  decode       -> paged attention over HBM slots (device kernel)
  eviction     -> HBM slots recycled per-sequence; pool blocks LRU-evicted
                  by the index when the pool fills

Straggler mitigation (fetch-vs-recompute cutover): if the modeled fetch
latency for the hit prefix exceeds ``recompute_cutover`` x the estimated
recompute time, the manager *recomputes* instead of waiting on a slow/
contended pool — bounding tail latency under pool pressure (§6.3 story).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, OutOfPoolMemory
from repro.core.rpc import ServiceDiedError
from repro.core.transfer import TransferEngine
from repro.kvcache.hbm_cache import HbmPagedCache, OutOfHbmBlocks

# sentinel: "the metadata plane was down and we degraded" (distinct from
# any legitimate index return value, including None/0/[])
_DEGRADED = object()


@dataclass
class FetchPlan:
    n_hit_tokens: int
    n_miss_tokens: int
    hit_blocks: list[tuple[bytes, int, int]]  # (key, block_id, epoch)
    fetch_latency: float  # modeled
    recompute: bool  # cutover decision
    keys: list[bytes] | None = None  # full chain (hashed once per request)


@dataclass
class ManagerStats:
    prefix_hits_tokens: int = 0
    prefix_miss_tokens: int = 0
    fetches: int = 0
    writebacks: int = 0
    recompute_cutovers: int = 0
    pool_evictions: int = 0
    degraded_ops: int = 0  # index ops absorbed while the plane was down


class KVCacheManager:
    def __init__(
        self,
        pool: BelugaPool,
        index: GlobalIndex,
        hbm: HbmPagedCache,
        transfer: TransferEngine,
        recompute_cutover: float | None = None,
        prefill_tok_per_s: float = 8000.0,
        queues=None,
        degraded_ok: bool = False,
    ):
        self.pool = pool
        self.index = index
        self.hbm = hbm
        self.transfer = transfer
        self.recompute_cutover = recompute_cutover
        self.prefill_tok_per_s = prefill_tok_per_s
        # degraded mode: a metadata-plane outage (crashed shard service
        # mid-restart) turns index ops into no-ops — match as all-miss
        # (full recompute, worse TTFT), writeback skipped — instead of an
        # exception reaching the engine.  Only transient transport faults
        # degrade; in-band handler errors still raise (they are bugs).
        self.degraded_ok = degraded_ok
        # shared fabric.DeviceQueues (tiered mode): foreground fetches
        # queue on the same pool devices as background migration traffic
        self.queues = queues
        self.stats = ManagerStats()

    def _index_op(self, fn):
        """Run one remote index op under the degraded-mode contract:
        transient transport faults (service died / timed out after the
        client's own retries) return ``_DEGRADED`` instead of raising."""
        if not self.degraded_ok:
            return fn()
        try:
            return fn()
        except (ServiceDiedError, TimeoutError):
            self.stats.degraded_ops += 1
            return _DEGRADED

    # ------------------------------------------------------------------
    def plan_fetch(self, tokens: list[int], now: float = 0.0) -> FetchPlan:
        """Prefix match + fetch-vs-recompute decision.

        ``now`` (engine virtual time) only matters in tiered mode: it
        drives hotness decay and device-queue contention."""
        bt = self.pool.layout.block_tokens
        keys = self.index.keys_for(tokens)
        hits = self._index_op(lambda: self.index.match_prefix_keys(keys))
        if hits is _DEGRADED:
            hits = []  # plane down: all-miss, ride the recompute path
        n_hit = len(hits) * bt
        n_miss = len(tokens) - n_hit
        # modeled fetch latency for the hit prefix (one fused kernel)
        lat = 0.0
        if hits:
            if getattr(self.pool, "is_tiered", False):
                lat = self._fetch_latency_tiered(
                    [b for _, b, _ in hits], now
                )
            else:
                lat = self._fetch_latency(len(hits))
        recompute_time = n_hit / self.prefill_tok_per_s
        # straggler mitigation (beyond-paper): recompute instead of waiting
        # on a fetch slower than `cutover x` the recompute time. Disabled by
        # default so the RDMA baseline behaves like MoonCake (Fig. 13c).
        cutover = (
            self.recompute_cutover is not None
            and bool(hits)
            and lat > self.recompute_cutover * max(recompute_time, 1e-9)
        )
        if cutover:
            self.stats.recompute_cutovers += 1
            hits, n_hit, n_miss = [], 0, len(tokens)
        self.stats.prefix_hits_tokens += n_hit
        self.stats.prefix_miss_tokens += max(0, n_miss)
        return FetchPlan(n_hit, max(0, n_miss), hits, lat, cutover, keys)

    def _fetch_latency(self, n_blocks: int) -> float:
        import math

        from repro.core import fabric

        lay = self.pool.layout
        size = n_blocks * lay.block_bytes
        nfrag = n_blocks * lay.n_fragments
        if self.transfer.mode == "beluga":
            return fabric.gpu_transfer_latency(
                size, nfrag, method="fused_kernel", c=self.transfer.constants
            )
        t = fabric.rdma_transfer_latency(
            size, nfrag, gpu_side=True, c=self.transfer.constants
        )
        # LMCache-style super-block staging cost (alloc + CPU copies)
        sbt = max(self.transfer.super_block_tokens, lay.block_tokens)
        n_super = math.ceil(n_blocks * lay.block_tokens / sbt)
        return t + n_super * self.transfer.constants.rdma_sw_per_superblock

    def _fetch_latency_tiered(self, block_ids: list[int], now: float) -> float:
        """Tier-aware fetch latency: fast-tier blocks ride the normal CXL
        path; down-chain blocks first pay their tier's media (RDMA-DRAM/
        SSD/...) plus the GPU-ingest bandwidth term, priced per tier. The
        access is also recorded as heat (promotion signal) and, when a
        shared ``DeviceQueues`` is wired, the transfer queues behind
        in-flight migration traffic."""
        from repro.core import fabric

        pool = self.pool
        counts = pool.touch_demand(block_ids, now)
        lay = pool.layout
        media = getattr(pool, "tier_media", None) or ("cxl", pool.spill_media)
        lat = self._fetch_latency(counts[0]) if counts[0] else 0.0
        for t, n in enumerate(counts[1:], start=1):
            if not n:
                continue
            size = n * lay.block_bytes
            lat += fabric.spill_transfer_latency(
                size, media[t], self.transfer.constants
            ) + size / self.transfer.constants.gpu_cxl_bw
        if self.queues is not None:
            # migration batches occupy the pool devices (the migrator
            # submits its copies into these queues): a fetch overlapping
            # that backlog degrades toward half bandwidth, so it pays up
            # to its own duration again — bounded, so out-of-sync engine
            # clocks can't manufacture phantom multi-second waits.
            backlog = max(self.queues.busy_until) - now
            if backlog > 0.0:
                lat += min(backlog, lat)
        return lat

    # ------------------------------------------------------------------
    def fetch_into_hbm(self, seq_id: str, plan: FetchPlan) -> list[int]:
        """Scatter-read hit blocks into freshly allocated HBM slots.

        On ANY failure the sequence is still registered (empty) and every
        intermediate resource is rolled back, so the caller can always
        fall through to full recompute with ``hbm.seq_tables[seq_id]``
        present and no leaked pool refs or HBM slots."""
        if not plan.hit_blocks:
            self.hbm.register_sequence(seq_id, [])
            return []
        keys = [k for k, _, _ in plan.hit_blocks]
        block_ids = [b for _, b, _ in plan.hit_blocks]
        epochs = [e for _, _, e in plan.hit_blocks]
        self.pool.retain(block_ids)
        try:
            slots = self.hbm.allocate(len(block_ids), keys=keys)
        except OutOfHbmBlocks:
            self.pool.release(block_ids)
            self._fetch_failed(seq_id, plan)
            raise
        try:
            self.transfer.scatter_read(block_ids, epochs)
            self.stats.fetches += 1
        except BaseException:
            self.pool.release(block_ids)
            self.hbm.release(slots)
            self._fetch_failed(seq_id, plan)
            raise
        self.pool.release(block_ids)
        if getattr(self.pool, "is_tiered", False):
            self.pool.count_tier_hits(block_ids)
        self.hbm.register_sequence(seq_id, slots)
        return slots

    def _fetch_failed(self, seq_id: str, plan: FetchPlan) -> None:
        """Common failure bookkeeping: the caller falls back to full
        recompute, so the planned hit tokens were in fact missed."""
        self.hbm.register_sequence(seq_id, [])
        self.stats.prefix_hits_tokens -= plan.n_hit_tokens
        self.stats.prefix_miss_tokens += plan.n_hit_tokens

    def writeback(
        self, seq_id: str, tokens: list[int], kv_payload=None, keys=None,
        now: float = 0.0,
    ) -> int:
        """After prefill: gather-write full blocks to the pool + publish.

        Returns the number of blocks written. ``kv_payload`` optionally
        carries real per-block KV (tests); the cluster sim passes None and
        only the control plane + modeled latency run. ``keys`` optionally
        carries the chain from an earlier ``plan_fetch`` (hash once).
        ``now`` feeds the tiered pool's hotness clock (ignored otherwise).
        """
        bt = self.pool.layout.block_tokens
        tiered = getattr(self.pool, "is_tiered", False)
        if tiered:
            self.pool.tick(now)
        if keys is None:
            keys = self.index.keys_for(tokens)
        # only blocks not already in the pool need writing: ONE metadata
        # round-trip (lookup + vectorized epoch check fused server-side)
        missing = self._index_op(lambda: self.index.filter_unpublished(keys))
        if missing is _DEGRADED:
            return 0  # plane down: skip the offload, blocks recompute later
        new_keys = [(i, keys[i]) for i in missing]
        if not new_keys:
            return 0

        def _alloc():
            if tiered:  # keys feed the ghost-LRU admission filter
                return self.pool.allocate(
                    len(new_keys), keys=[k for _, k in new_keys]
                )
            return self.pool.allocate(len(new_keys))

        try:
            block_ids = _alloc()
        except OutOfPoolMemory:
            freed = self._index_op(lambda: self.index.evict_lru(len(new_keys) * 2))
            if freed is _DEGRADED:
                return 0  # can't evict while the plane is down: skip offload
            self.stats.pool_evictions += len(freed)
            try:
                block_ids = _alloc()
            except OutOfPoolMemory:
                return 0  # pool full of referenced blocks: skip offload
        lay = self.pool.layout
        if kv_payload is None and self.pool.data is not None:
            kv_payload = np.zeros(
                (
                    len(new_keys),
                    lay.n_fragments,
                    lay.block_tokens,
                    lay.n_kv_heads,
                    lay.head_dim,
                ),
                np.float16,
            )
        epochs = self.transfer.gather_write(block_ids, kv_payload)
        published = self._index_op(lambda: self.index.publish_many(
            [key for _, key in new_keys], block_ids, epochs, bt
        ))
        if published is _DEGRADED:
            # unpublished blocks would strand (the index can never evict
            # what it never learned about): hand them straight back
            self.pool.release(block_ids)
            return 0
        self.stats.writebacks += 1
        return len(new_keys)

    # ------------------------------------------------------------------
    def finish(self, seq_id: str) -> None:
        self.hbm.finish_sequence(seq_id)

"""Device-tier paged KV cache: block allocator + per-sequence block tables.

vLLM-style PagedAttention bookkeeping for ONE engine instance:
  * fixed pool of HBM slots (16-token blocks by default);
  * per-sequence block tables (slot lists);
  * refcounted intra-instance prefix sharing (copy-on-extend);
  * LRU free-slot reuse.

The actual KV payloads live in per-layer device arrays owned by the model
runner; this class owns the *slot* arithmetic only, so the same allocator
drives both the real CPU model runner and the simulated cluster engines.
"""

from __future__ import annotations

from dataclasses import dataclass, field


class OutOfHbmBlocks(RuntimeError):
    pass


@dataclass
class HbmBlock:
    slot: int
    refcount: int = 0
    # identity of the content for intra-instance sharing
    key: bytes | None = None


class HbmPagedCache:
    def __init__(self, n_slots: int, block_tokens: int = 16):
        self.n_slots = n_slots
        self.block_tokens = block_tokens
        self.blocks = [HbmBlock(slot=i) for i in range(n_slots)]
        self._free: list[int] = list(range(n_slots))
        self._by_key: dict[bytes, int] = {}
        self.seq_tables: dict[str, list[int]] = {}
        self.alloc_count = 0

    # ------------------------------------------------------------------
    def free_slots(self) -> int:
        return len(self._free)

    def lookup_shared(self, key: bytes) -> int | None:
        """Intra-instance prefix block reuse (no transfer needed at all)."""
        slot = self._by_key.get(key)
        if slot is not None:
            self.blocks[slot].refcount += 1
        return slot

    def allocate(self, n: int, keys: list[bytes] | None = None) -> list[int]:
        if len(self._free) < n:
            raise OutOfHbmBlocks(f"need {n} slots, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        for i, slot in enumerate(out):
            b = self.blocks[slot]
            b.refcount = 1
            b.key = keys[i] if keys else None
            if b.key is not None:
                self._by_key[b.key] = slot
        self.alloc_count += n
        return out

    def release(self, slots: list[int]) -> None:
        for slot in slots:
            b = self.blocks[slot]
            b.refcount -= 1
            assert b.refcount >= 0, f"double free of HBM slot {slot}"
            if b.refcount == 0:
                if b.key is not None:
                    self._by_key.pop(b.key, None)
                    b.key = None
                self._free.append(slot)

    # ------------------------------------------------------------------
    def register_sequence(self, seq_id: str, slots: list[int]) -> None:
        self.seq_tables[seq_id] = list(slots)

    def extend_sequence(self, seq_id: str, n_new_tokens: int, seq_len: int) -> list[int]:
        """Ensure the table covers seq_len + n_new_tokens; allocate as needed."""
        table = self.seq_tables[seq_id]
        need = -(-(seq_len + n_new_tokens) // self.block_tokens)
        new = []
        if need > len(table):
            new = self.allocate(need - len(table))
            table.extend(new)
        return new

    def finish_sequence(self, seq_id: str) -> None:
        table = self.seq_tables.pop(seq_id, [])
        self.release(table)

    def table(self, seq_id: str) -> list[int]:
        return self.seq_tables[seq_id]

"""Device-tier paged KV cache: block allocator + per-sequence block tables.

vLLM-style PagedAttention bookkeeping for ONE engine instance:
  * fixed pool of HBM slots (16-token blocks by default);
  * per-sequence block tables (slot lists);
  * refcounted intra-instance prefix sharing (copy-on-extend);
  * LRU free-slot reuse.

The actual KV payloads live in per-layer device arrays owned by the model
runner; this class owns the *slot* arithmetic only, so the same allocator
drives both the real CPU model runner and the simulated cluster engines.
Refcounts live in a flat numpy array (a sequence finish releases its whole
~1000-slot table in one vectorized batch, not a per-slot object walk).
"""

from __future__ import annotations

import numpy as np


class OutOfHbmBlocks(RuntimeError):
    pass


class HbmPagedCache:
    def __init__(self, n_slots: int, block_tokens: int = 16):
        self.n_slots = n_slots
        self.block_tokens = block_tokens
        self.refcounts = np.zeros(n_slots, np.int32)
        self._slot_key: list[bytes | None] = [None] * n_slots
        self._free: list[int] = list(range(n_slots))
        self._by_key: dict[bytes, int] = {}
        self.seq_tables: dict[str, list[int]] = {}
        self.alloc_count = 0

    # ------------------------------------------------------------------
    def free_slots(self) -> int:
        return len(self._free)

    def has_key(self, key: bytes) -> bool:
        """Whether a slot currently holds this prefix block (no refcount
        side effects — the cache-aware scheduler's locality probe)."""
        return key in self._by_key

    def lookup_shared(self, key: bytes) -> int | None:
        """Intra-instance prefix block reuse (no transfer needed at all)."""
        slot = self._by_key.get(key)
        if slot is not None:
            self.refcounts[slot] += 1
        return slot

    def allocate(self, n: int, keys: list[bytes] | None = None) -> list[int]:
        free = self._free
        if len(free) < n:
            raise OutOfHbmBlocks(f"need {n} slots, have {len(free)}")
        out = free[len(free) - n:]
        out.reverse()  # preserve the seed pop()-order
        del free[len(free) - n:]
        self.refcounts[out] = 1
        if keys:
            slot_key = self._slot_key
            by_key = self._by_key
            for slot, key in zip(out, keys):
                slot_key[slot] = key
                if key is not None:
                    by_key[key] = slot
        self.alloc_count += n
        return out

    def release(self, slots: list[int]) -> None:
        if not len(slots):
            return
        uniq, counts = np.unique(np.asarray(slots, np.intp), return_counts=True)
        self.refcounts[uniq] -= counts.astype(np.int32)
        left = self.refcounts[uniq]
        assert (left >= 0).all(), "double free of HBM slot"
        freed = uniq[left == 0]
        if not len(freed):
            return
        slot_key = self._slot_key
        free_append = self._free.append
        for slot in freed.tolist():
            key = slot_key[slot]
            if key is not None:
                self._by_key.pop(key, None)
                slot_key[slot] = None
            free_append(slot)

    # ------------------------------------------------------------------
    def register_sequence(self, seq_id: str, slots: list[int]) -> None:
        self.seq_tables[seq_id] = list(slots)

    def extend_sequence(self, seq_id: str, n_new_tokens: int, seq_len: int) -> list[int]:
        """Ensure the table covers seq_len + n_new_tokens; allocate as needed."""
        table = self.seq_tables[seq_id]
        need = -(-(seq_len + n_new_tokens) // self.block_tokens)
        new = []
        if need > len(table):
            new = self.allocate(need - len(table))
            table.extend(new)
        return new

    def finish_sequence(self, seq_id: str) -> None:
        table = self.seq_tables.pop(seq_id, [])
        self.release(table)

    def table(self, seq_id: str) -> list[int]:
        return self.seq_tables[seq_id]

"""Optimizers from scratch (no optax): AdamW, Lion, SGD + schedules + clipping.

Optimizer state mirrors the parameter tree (and therefore its sharding);
moments are fp32 regardless of param dtype. Update math runs in fp32 and
casts back — master-weight-free mixed precision, chosen to keep optimizer
bytes/chip at 8·N/shards (documented in EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # gradient compression for DP all-reduce (distributed-optimization trick):
    # "none" | "bf16" — grads cast before the reduction, error feedback off.
    grad_compression: str = "bf16"


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def init_opt_state(cfg: OptimizerConfig, params: Any) -> dict:
    def zeros_like_f32(p):
        return jnp.zeros(p.shape, jnp.float32)

    state = {"step": jnp.zeros((), jnp.int32)}
    if cfg.name in ("adamw",):
        state["m"] = jax.tree.map(zeros_like_f32, params)
        state["v"] = jax.tree.map(zeros_like_f32, params)
    elif cfg.name == "lion":
        state["m"] = jax.tree.map(zeros_like_f32, params)
    elif cfg.name == "sgd":
        pass
    else:
        raise ValueError(cfg.name)
    return state


def opt_state_specs(cfg: OptimizerConfig, param_specs: Any) -> dict:
    """ParamSpec tree for the optimizer state (same logical axes, fp32)."""
    from repro.distributed.sharding import ParamSpec, is_param_spec

    def f32(p):
        return ParamSpec(p.shape, "float32", p.logical_axes, init="zeros")

    state = {"step": ParamSpec((), "int32", (), init="zeros")}
    if cfg.name == "adamw":
        state["m"] = jax.tree.map(f32, param_specs, is_leaf=is_param_spec)
        state["v"] = jax.tree.map(f32, param_specs, is_leaf=is_param_spec)
    elif cfg.name == "lion":
        state["m"] = jax.tree.map(f32, param_specs, is_leaf=is_param_spec)
    return state


def apply_updates(
    cfg: OptimizerConfig, params: Any, grads: Any, state: dict
) -> tuple[Any, dict]:
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)

    if cfg.name == "adamw":
        bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, td = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        new_p, new_m, new_v = [], [], []
        for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
            a, b, c = upd(p, g, m, v)
            new_p.append(a)
            new_m.append(b)
            new_v.append(c)
        return jax.tree.unflatten(td, new_p), {
            "step": step,
            "m": jax.tree.unflatten(td, new_m),
            "v": jax.tree.unflatten(td, new_v),
        }

    if cfg.name == "lion":
        def upd(p, g, m):
            g = g.astype(jnp.float32)
            u = jnp.sign(cfg.b1 * m + (1 - cfg.b1) * g)
            if p.ndim >= 2:
                u = u + cfg.weight_decay * p.astype(jnp.float32)
            m = cfg.b2 * m + (1 - cfg.b2) * g
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m

        flat_p, td = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        new_p, new_m = [], []
        for p, g, m in zip(flat_p, flat_g, flat_m):
            a, b = upd(p, g, m)
            new_p.append(a)
            new_m.append(b)
        return jax.tree.unflatten(td, new_p), {
            "step": step,
            "m": jax.tree.unflatten(td, new_m),
        }

    if cfg.name == "sgd":
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(
                p.dtype
            ),
            params,
            grads,
        )
        return new_p, {"step": step}

    raise ValueError(cfg.name)

"""Train-step factory + host-side training loop with fault tolerance hooks.

``make_train_step`` builds the jittable step used both by the real trainer
(`launch/train.py`) and the multi-pod dry-run (`launch/dryrun.py`):

    (params, opt_state, batch) -> (params, opt_state, metrics)

Distributed-optimization features:
  * gradient compression: grads cast to bf16 before the DP all-reduce
    (OptimizerConfig.grad_compression="bf16") — halves gradient all-reduce
    bytes on the `data`/`pod` axes;
  * microbatch gradient accumulation (``accum_steps``) via lax.scan —
    trades activation memory for steps (remat lever for big cells);
  * global-norm clipping; load-balance aux loss for MoE.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training import optimizer as opt_lib
from repro.training.optimizer import OptimizerConfig


def make_train_step(
    model: Model,
    opt_cfg: OptimizerConfig,
    accum_steps: int = 1,
) -> Callable:
    def loss_for_grad(params, batch):
        loss, aux = model.loss_fn(params, batch)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def compress(g):
        if opt_cfg.grad_compression == "bf16":
            return jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)
        return g

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, aux), grads = grad_fn(params, batch)
            grads = compress(grads)
        else:
            # split batch leading dim into microbatches and accumulate
            def split(x):
                b = x.shape[0]
                assert b % accum_steps == 0, (b, accum_steps)
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (l, aux), g = grad_fn(params, mb)
                g = compress(g)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(a.dtype), g_acc, g
                )
                return (g_acc, l_acc + l), aux

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), aux = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            aux = jax.tree.map(lambda x: x[-1], aux)

        grads, gnorm = opt_lib.clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = opt_lib.apply_updates(opt_cfg, params, grads, opt_state)
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": opt_lib.lr_schedule(opt_cfg, opt_state["step"]),
            **{f"aux/{k}": v for k, v in aux.items()},
        }
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Host loop (CPU-runnable; used by examples + integration tests)
# ---------------------------------------------------------------------------


@dataclass
class TrainLoopConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    keep_checkpoints: int = 2


def run_train_loop(
    model: Model,
    opt_cfg: OptimizerConfig,
    loop_cfg: TrainLoopConfig,
    data_iter,
    params=None,
    opt_state=None,
    start_step: int = 0,
    step_fn=None,
    on_metrics=None,
):
    """Simple single-process loop; the multi-host launcher wraps this."""
    from repro.checkpoint.checkpointer import Checkpointer

    if params is None:
        params = model.init(jax.random.key(0))
    if opt_state is None:
        opt_state = opt_lib.init_opt_state(opt_cfg, params)
    if step_fn is None:
        step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))

    ckpt = None
    if loop_cfg.checkpoint_dir:
        ckpt = Checkpointer(loop_cfg.checkpoint_dir, keep=loop_cfg.keep_checkpoints)

    history = []
    for step in range(start_step, loop_cfg.steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % loop_cfg.log_every == 0 or step == start_step:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step + 1, **m})
            if on_metrics:
                on_metrics(step + 1, m)
        if ckpt and (step + 1) % loop_cfg.checkpoint_every == 0:
            ckpt.save(
                step + 1,
                {"params": params, "opt_state": opt_state},
                extra={"data_state": getattr(data_iter, "state_dict", lambda: {})()},
            )
    return params, opt_state, history

"""repro: Beluga (CXL pooled-memory KVCache) reproduced as a TPU/JAX framework."""

__version__ = "0.1.0"

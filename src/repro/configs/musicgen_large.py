"""musicgen-large - exact assigned config.

[audio] 48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048 - decoder-only over EnCodec tokens [arXiv:2306.05284; hf]

Single source of truth lives in ``repro.configs.registry.MUSICGEN_LARGE``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch musicgen-large`` selector.
"""

from repro.configs.registry import MUSICGEN_LARGE as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("musicgen-large")

"""Config system: model architectures, input shapes, runtime knobs.

Every assigned architecture is a ``ModelConfig`` in ``repro.configs.<id>``;
``repro.configs.registry`` maps ``--arch`` ids to configs.  Shape cells
(train_4k / prefill_32k / decode_32k / long_500k) are ``ShapeConfig`` entries
in ``SHAPES``.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    # 1 = every layer is MoE; 2 = every other layer (alternating), etc.
    layer_period: int = 1
    # Arctic: dense residual MLP in parallel with the expert MLP.
    dense_residual: bool = False
    dense_residual_ff: int = 0
    # Token-dropping capacity factor for the einsum dispatch path.
    capacity_factor: float = 1.25
    # Router softmax over experts; jitter etc. omitted (inference-focused).
    router_dtype: str = "float32"

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) hyperparameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # hybrid (Jamba): one attention layer every `attn_period` layers; the rest
    # are Mamba layers. 0 = pure attention stack; n_layers -> pure SSM.
    attn_period: int = 0
    # frontends for audio/vlm: stub providing precomputed embeddings.
    frontend: str = "none"  # none | audio_stub | vision_stub
    n_frontend_tokens: int = 0  # e.g. image patches prepended to the sequence
    qkv_bias: bool = False  # qwen1.5
    attn_out_bias: bool = False
    mlp_bias: bool = False
    nonparametric_ln: bool = False  # olmo
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"  # silu | gelu
    dtype: str = "bfloat16"
    # --- notes for DESIGN.md §Arch-applicability / padding ---
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_ssm_layers(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def attn_layer_ids(self) -> list[int]:
        """Indices of attention layers in the stack."""
        if self.family == "ssm":
            return []
        if self.attn_period and self.attn_period > 1:
            # Jamba: attention at position (attn_period - 1) of each period.
            return [
                i
                for i in range(self.n_layers)
                if i % self.attn_period == self.attn_period - 1
            ]
        return list(range(self.n_layers))

    def moe_layer_ids(self) -> list[int]:
        if not self.moe.enabled:
            return []
        p = self.moe.layer_period
        return [i for i in range(self.n_layers) if (i % p) == (p - 1)]

    # ---------------- padding for TP divisibility -----------------------
    def padded_heads(self, tp: int) -> int:
        return _round_up(self.n_heads, tp)

    def padded_kv_heads(self, tp: int) -> int:
        # KV heads are replicated up to min(tp, n_heads) shards; when
        # n_kv_heads < tp we *replicate* KV per group rather than pad
        # (standard GQA TP). For layout purposes we keep the true count.
        return self.n_kv_heads

    def padded_vocab(self, tp: int) -> int:
        return _round_up(self.vocab_size, tp * 8)

    # ---------------- parameter counts ---------------------------------
    def param_count(self) -> int:
        """True (unpadded) parameter count."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        return _param_count(self, active_only=True)

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KVCache bytes per token across all attention layers."""
        n_attn = len(self.attn_layer_ids())
        return n_attn * 2 * self.n_kv_heads * self.head_dim * dtype_bytes

    def ssm_state_bytes(self, dtype_bytes: int = 4) -> int:
        if not self.has_ssm_layers:
            return 0
        n_ssm = self.n_layers - len(self.attn_layer_ids())
        nh = self.ssm.n_heads(self.d_model)
        conv_dim = self.ssm.d_inner(self.d_model) + 2 * self.ssm.n_groups * self.ssm.d_state
        per_layer = (
            nh * self.ssm.head_dim * self.ssm.d_state  # SSD state
            + conv_dim * (self.ssm.d_conv - 1)  # conv tail
        )
        return n_ssm * per_layer * dtype_bytes


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    hd = cfg.head_dim
    attn_ids = set(cfg.attn_layer_ids())
    moe_ids = set(cfg.moe_layer_ids())
    total = cfg.vocab_size * d  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d  # lm head
    for i in range(cfg.n_layers):
        # mixer
        if i in attn_ids:
            total += d * cfg.n_heads * hd  # q
            total += 2 * d * cfg.n_kv_heads * hd  # k, v
            total += cfg.n_heads * hd * d  # o
        elif cfg.has_ssm_layers:
            ssm = cfg.ssm
            di = ssm.d_inner(d)
            nh = ssm.n_heads(d)
            conv_dim = di + 2 * ssm.n_groups * ssm.d_state
            total += d * (2 * di + 2 * ssm.n_groups * ssm.d_state + nh)  # in_proj
            total += conv_dim * ssm.d_conv  # conv
            total += nh * 2  # A_log, D
            total += di  # dt_bias ~ nh actually; negligible
            total += di * d  # out_proj
        # mlp
        if i in moe_ids:
            e = cfg.moe.top_k if active_only else cfg.moe.n_experts
            total += e * 3 * d * cfg.d_ff  # gate/up/down per expert
            total += d * cfg.moe.n_experts  # router
            if cfg.moe.dense_residual:
                total += 3 * d * cfg.moe.dense_residual_ff
        else:
            total += 3 * d * cfg.d_ff
        # norms
        if not cfg.nonparametric_ln:
            total += 2 * d
    return total


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (ssm/hybrid), per DESIGN.md."""
    if shape.name == "long_500k" and model.family not in ("ssm", "hybrid"):
        return False, (
            f"{model.name} is a pure full-attention arch; long_500k requires "
            "sub-quadratic attention (skip recorded in DESIGN.md §5)"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Runtime / parallelism knobs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RuntimeConfig:
    """Knobs that do not change model math, only execution."""

    kernel_mode: str = "auto"  # auto | pallas | jnp
    remat: str = "full"  # none | full | dots (checkpoint policy for train)
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    # decode KV strategy: "replicated" (paper-faithful baseline: KV heads
    # replicated across TP) or "pool_interleaved" (beyond-paper: sequence
    # blocks interleaved across chips, LSE-merge flash decode = Beluga O9).
    decode_kv: str = "pool_interleaved"
    moe_dispatch: str = "einsum"  # einsum | ragged | a2a (shard_map EP)
    # row-parallel matmuls: psum bf16 partials via shard_map (halves the TP
    # all-reduce bytes vs the partitioner's f32 reduction) — §Perf iter 4
    rowp_bf16_psum: bool = False
    # beluga pool
    pool_block_tokens: int = 16
    pool_blocks_per_shard: int = 4096
    use_fp8_kv: bool = False


def describe(cfg: ModelConfig) -> str:
    n = cfg.param_count()
    a = cfg.active_param_count()
    parts = [
        f"{cfg.name}: {cfg.family}",
        f"{cfg.n_layers}L d={cfg.d_model} H={cfg.n_heads}/{cfg.n_kv_heads}kv",
        f"ff={cfg.d_ff} vocab={cfg.vocab_size}",
        f"params={n/1e9:.1f}B",
    ]
    if cfg.moe.enabled:
        parts.append(
            f"moe={cfg.moe.n_experts}e top{cfg.moe.top_k} active={a/1e9:.1f}B"
        )
    return " ".join(parts)

"""internvl2-26b - exact assigned config.

[vlm] 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 - InternViT + InternLM2 [arXiv:2404.16821; hf]

Single source of truth lives in ``repro.configs.registry.INTERNVL2_26B``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch internvl2-26b`` selector.
"""

from repro.configs.registry import INTERNVL2_26B as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("internvl2-26b")

"""arctic-480b - exact assigned config.

[moe] 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 + dense residual [hf:Snowflake/snowflake-arctic-base; hf]

Single source of truth lives in ``repro.configs.registry.ARCTIC_480B``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch arctic-480b`` selector.
"""

from repro.configs.registry import ARCTIC_480B as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("arctic-480b")

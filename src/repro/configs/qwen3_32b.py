"""qwen3-32b - exact assigned config.

paper's own eval model: 64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936 [arXiv:2505.09388]

Single source of truth lives in ``repro.configs.registry.QWEN3_32B``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch qwen3-32b`` selector.
"""

from repro.configs.registry import QWEN3_32B as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("qwen3-32b")

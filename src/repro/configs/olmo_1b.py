"""olmo-1b - exact assigned config.

[dense] 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304 - non-parametric LN [arXiv:2402.00838; hf]

Single source of truth lives in ``repro.configs.registry.OLMO_1B``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch olmo-1b`` selector.
"""

from repro.configs.registry import OLMO_1B as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("olmo-1b")

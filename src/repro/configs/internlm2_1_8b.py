"""internlm2-1.8b - exact assigned config.

[dense] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544 - GQA [arXiv:2403.17297; hf]

Single source of truth lives in ``repro.configs.registry.INTERNLM2_1_8B``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch internlm2-1.8b`` selector.
"""

from repro.configs.registry import INTERNLM2_1_8B as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("internlm2-1.8b")

"""llama3.1-8b - exact assigned config.

paper's transfer-bench model: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256 [arXiv:2407.21783]

Single source of truth lives in ``repro.configs.registry.LLAMA31_8B``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch llama3.1-8b`` selector.
"""

from repro.configs.registry import LLAMA31_8B as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("llama3.1-8b")

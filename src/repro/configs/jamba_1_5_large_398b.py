"""jamba-1.5-large-398b - exact assigned config.

[hybrid] 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2 - Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887; hf]

Single source of truth lives in ``repro.configs.registry.JAMBA_1_5_LARGE``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch jamba-1.5-large-398b`` selector.
"""

from repro.configs.registry import JAMBA_1_5_LARGE as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("jamba-1.5-large-398b")

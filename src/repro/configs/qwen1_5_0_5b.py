"""qwen1.5-0.5b - exact assigned config.

[dense] 24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936 - QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]

Single source of truth lives in ``repro.configs.registry.QWEN1_5_0_5B``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch qwen1.5-0.5b`` selector.
"""

from repro.configs.registry import QWEN1_5_0_5B as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("qwen1.5-0.5b")

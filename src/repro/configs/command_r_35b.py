"""command-r-35b - exact assigned config.

[dense] 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 - GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified]

Single source of truth lives in ``repro.configs.registry.COMMAND_R_35B``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch command-r-35b`` selector.
"""

from repro.configs.registry import COMMAND_R_35B as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("command-r-35b")

"""mamba2-2.7b - exact assigned config.

[ssm] 64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128 - SSD (state-space duality) [arXiv:2405.21060; unverified]

Single source of truth lives in ``repro.configs.registry.MAMBA2_2_7B``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch mamba2-2.7b`` selector.
"""

from repro.configs.registry import MAMBA2_2_7B as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("mamba2-2.7b")

"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Assigned architectures (10) + the paper's own evaluation model (qwen3-32b).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

# ---------------------------------------------------------------------------
# Assigned architectures (exact configs from the assignment block).
# ---------------------------------------------------------------------------

JAMBA_1_5_LARGE = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, top_k=2, layer_period=2),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
    attn_period=8,  # 1 attention : 7 mamba per 8-layer period
    source="arXiv:2403.19887; hf",
)

LLAMA4_MAVERICK = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(n_experts=128, top_k=1, layer_period=1),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)

ARCTIC_480B = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(
        n_experts=128,
        top_k=2,
        layer_period=1,
        dense_residual=True,
        dense_residual_ff=4864,
    ),
    source="hf:Snowflake/snowflake-arctic-base; hf",
)

MUSICGEN_LARGE = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio_stub",
    n_frontend_tokens=0,  # EnCodec frame embeddings replace token embeddings
    act="gelu",
    source="arXiv:2306.05284; hf",
)

MAMBA2_2_7B = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    d_head=64,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)

INTERNLM2_1_8B = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    source="arXiv:2403.17297; hf",
)

OLMO_1B = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    nonparametric_ln=True,
    tie_embeddings=True,
    source="arXiv:2402.00838; hf",
)

QWEN1_5_0_5B = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)

COMMAND_R_35B = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)

INTERNVL2_26B = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    frontend="vision_stub",
    n_frontend_tokens=256,  # precomputed InternViT patch embeddings (stub)
    source="arXiv:2404.16821; hf",
)

# The paper's own evaluation model (Qwen3-32B, GQA: 64L x 2 = 128 fragments
# per KV block — the layout used throughout Beluga's transfer experiments).
QWEN3_32B = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    source="arXiv:2505.09388 (paper's eval model)",
)

# Llama-3.1-8B: used by the paper's transfer benchmarks (64 fragments).
LLAMA31_8B = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    source="arXiv:2407.21783 (paper's transfer bench)",
)

ASSIGNED: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        JAMBA_1_5_LARGE,
        LLAMA4_MAVERICK,
        ARCTIC_480B,
        MUSICGEN_LARGE,
        MAMBA2_2_7B,
        INTERNLM2_1_8B,
        OLMO_1B,
        QWEN1_5_0_5B,
        COMMAND_R_35B,
        INTERNVL2_26B,
    ]
}

EXTRA: dict[str, ModelConfig] = {c.name: c for c in [QWEN3_32B, LLAMA31_8B]}

REGISTRY: dict[str, ModelConfig] = {**ASSIGNED, **EXTRA}


def get_config(name: str) -> ModelConfig:
    key = name.strip().lower()
    if key in REGISTRY:
        return REGISTRY[key]
    alt = key.replace("_", "-")
    if alt in REGISTRY:
        return REGISTRY[alt]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests: same family/topology, tiny dims.
# ---------------------------------------------------------------------------


def reduced_config(name: str) -> ModelConfig:
    import dataclasses

    cfg = get_config(name)
    n_layers = {  # keep topology periods intact
        "hybrid": 8,  # one full Jamba period (7 mamba + 1 attn), MoE alt
        "ssm": 4,
    }.get(cfg.family, 4)
    n_heads = 4 if cfg.n_heads else 0
    kv = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0
    moe = cfg.moe
    if moe.enabled:
        moe = dataclasses.replace(moe, n_experts=4, top_k=min(moe.top_k, 2))
    ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk_size=32)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=kv,
        d_head=16 if cfg.n_heads else 16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        moe=moe,
        ssm=ssm,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 8),
    )

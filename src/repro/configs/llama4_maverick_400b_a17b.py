"""llama4-maverick-400b-a17b - exact assigned config.

[moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1 - MoE, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Single source of truth lives in ``repro.configs.registry.LLAMA4_MAVERICK``;
this module exposes it as ``CONFIG`` (and a reduced smoke config) for the
``--arch llama4-maverick-400b-a17b`` selector.
"""

from repro.configs.registry import LLAMA4_MAVERICK as CONFIG  # noqa: F401
from repro.configs.registry import reduced_config

SMOKE_CONFIG = reduced_config("llama4-maverick-400b-a17b")

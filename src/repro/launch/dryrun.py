import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract roofline inputs from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        --out results/dryrun

Per cell this records (JSON, one file per cell):
  * compiled.memory_analysis()   (per-device bytes: args/output/temp)
  * compiled.cost_analysis()     (XLA's numbers — under-count scans; kept
                                  for reference)
  * our HLO analysis             (repro.launch.hlo_analysis — trip-count
                                  corrected flops/bytes/collective bytes)
  * lower/compile wall time, HLO sizes, analytic MODEL_FLOPS
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import gzip  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs for the cell (6·N·D train, 2·N_active fwd)."""
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


def attn_model_flops(cfg, shape) -> float:
    """Analytic causal-attention FLOPs (not in 6·N·D; reported separately)."""
    n_attn = len(cfg.attn_layer_ids())
    if n_attn == 0 or cfg.n_heads == 0:
        return 0.0
    h, d = cfg.n_heads, cfg.head_dim
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        per = 2 * 2 * h * d * s * s / 2  # causal half, fwd
        return 3 * per * b * n_attn  # fwd + bwd(2x)
    if shape.kind == "prefill":
        return 2 * 2 * h * d * s * s / 2 * b * n_attn
    return 2 * 2 * h * d * s * b * n_attn  # decode: q=1 vs kv=s


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             runtime_overrides: dict | None = None, tag: str = "") -> dict:
    from repro.configs.base import RuntimeConfig, SHAPES, shape_applicable
    from repro.configs.registry import get_config
    from repro.distributed.sharding import AxisRules
    from repro.launch import steps as steps_lib
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}.{shape_name}.{mesh_name}" + (f".{tag}" if tag else "")
    rec: dict = {"cell": cell_id, "arch": arch, "shape": shape_name,
                 "mesh": mesh_name, "tag": tag or "baseline"}

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    runtime = RuntimeConfig(**(runtime_overrides or {}))
    rec["runtime"] = dataclasses.asdict(runtime)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = AxisRules.create(mesh)
    n_chips = mesh.size

    t0 = time.time()
    try:
        cell = steps_lib.build_cell(cfg, shape, rules, runtime)
        lowered = steps_lib.lower_cell(cell, mesh)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        return rec

    rec["status"] = "ok"
    rec["notes"] = cell.notes
    rec["n_chips"] = n_chips
    rec["t_lower_s"] = round(t_lower, 2)
    rec["t_compile_s"] = round(t_compile, 2)

    try:
        ma = compiled.memory_analysis()
        print(ma)  # required by spec: proves it fits
        rec["memory_analysis"] = {
            "argument_size_bytes": int(ma.argument_size_in_bytes),
            "output_size_bytes": int(ma.output_size_in_bytes),
            "temp_size_bytes": int(ma.temp_size_in_bytes),
            "alias_size_bytes": int(ma.alias_size_in_bytes),
            "generated_code_size_bytes": int(ma.generated_code_size_in_bytes),
        }
        live = (
            ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes
        )
        rec["memory_analysis"]["live_bytes_per_device"] = int(live)
    except Exception as e:  # noqa: BLE001
        rec["memory_analysis"] = {"error": str(e)}

    try:
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in ("flops", "transcendentals") if k in ca})
        rec["xla_cost_analysis"] = {
            k: float(v)
            for k, v in ca.items()
            if isinstance(v, (int, float)) and "{" not in k
        }
    except Exception as e:  # noqa: BLE001
        rec["xla_cost_analysis"] = {"error": str(e)}

    hlo = compiled.as_text()
    rec["hlo_chars"] = len(hlo)
    rec["hlo_analysis"] = analyze_hlo(hlo)
    rec["model_flops_total"] = model_flops(cfg, shape)
    rec["attn_model_flops_total"] = attn_model_flops(cfg, shape)
    rec["param_count"] = cfg.param_count()
    rec["active_param_count"] = cfg.active_param_count()

    if out_dir:
        os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
        with gzip.open(
            os.path.join(out_dir, "hlo", cell_id + ".hlo.gz"), "wt"
        ) as f:
            f.write(hlo)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--runtime-json", default=None,
                    help='RuntimeConfig overrides, e.g. \'{"decode_kv":"replicated"}\'')
    args = ap.parse_args()

    from repro.configs.base import SHAPES
    from repro.configs.registry import ASSIGNED

    overrides = json.loads(args.runtime_json) if args.runtime_json else None

    cells: list[tuple[str, str, bool]] = []
    archs = list(ASSIGNED) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or (args.all and not args.multi_pod)) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    summary = []
    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        cell_id = f"{arch}.{shape}.{mesh_name}" + (f".{args.tag}" if args.tag else "")
        path = os.path.join(args.out, cell_id + ".json")
        if os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            print(f"[cached] {cell_id}: {rec.get('status')}")
            summary.append(rec)
            continue
        print(f"[run] {cell_id}")
        rec = run_cell(arch, shape, mp, args.out, overrides, args.tag)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec.get("status")
        extra = ""
        if status == "ok":
            ha = rec["hlo_analysis"]
            extra = (
                f" flops/dev={ha['flops']:.3e} bytes/dev={ha['bytes_accessed']:.3e}"
                f" coll/dev={ha['collective_bytes']:.3e}"
                f" compile={rec['t_compile_s']}s"
            )
        print(f"[done] {cell_id}: {status}{extra}")
        summary.append(rec)

    n_ok = sum(1 for r in summary if r.get("status") == "ok")
    n_skip = sum(1 for r in summary if r.get("status") == "skipped")
    n_err = sum(1 for r in summary if r.get("status") == "error")
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    for r in summary:
        if r.get("status") == "error":
            print(f"  ERROR {r['cell']}: {r['error']}")


if __name__ == "__main__":
    main()

"""Roofline report from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell:
    compute term    = flops_per_dev / PEAK_FLOPS_BF16
    memory term     = bytes_per_dev / HBM_BW
    collective term = collective_bytes_per_dev / ICI_BW
(all per-chip — the SPMD HLO module analyzed is the per-device program).

Also: MODEL_FLOPS / HLO_FLOPS usefulness ratio, dominant bottleneck, and a
one-line "what would move the dominant term" note per cell.
"""

from __future__ import annotations

import glob
import json
import os

from repro.launch.mesh import HBM_PER_CHIP, HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def load_records(out_dir: str = "results/dryrun", tag: str | None = "baseline"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if tag is not None and r.get("tag", "baseline") != tag:
            continue
        recs.append(r)
    return recs


def useful_bytes_per_dev(rec: dict) -> float:
    """Minimal HBM traffic the step fundamentally requires, per chip.

    train:   read+write params (bf16) + read+write adam moments (fp32) +
             grads (bf16) — activation traffic excluded (optimizable).
    prefill: read params once + write the KV/SSM cache.
    decode:  read params once + read the full KV cache (+SSM states).
    """
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n = rec["n_chips"]
    n_params_loc = cfg.param_count() / n
    b, s = shape.global_batch, shape.seq_len
    kv_loc = cfg.kv_bytes_per_token() * b * s / n
    ssm_loc = cfg.ssm_state_bytes() * b / n
    if shape.kind == "train":
        return n_params_loc * (2 + 2 + 2 + 16)  # w r/w, grads, m+v r/w
    if shape.kind == "prefill":
        return n_params_loc * 2 + kv_loc + ssm_loc
    return n_params_loc * 2 + kv_loc + ssm_loc  # decode reads the cache


def roofline_terms(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    ha = rec["hlo_analysis"]
    n = rec["n_chips"]
    compute = ha["flops"] / PEAK_FLOPS_BF16
    # fusion-ideal bytes when present (TPU-faithful); raw as-compiled kept too
    mem_bytes = ha.get("bytes_fused", ha["bytes_accessed"])
    memory = mem_bytes / HBM_BW
    collective = ha["collective_bytes"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]
    model_flops_dev = rec["model_flops_total"] / n
    useful_ratio = model_flops_dev / max(ha["flops"], 1.0)
    # roofline fraction: time the step fundamentally needs (max of useful
    # compute and useful memory) / modeled bottleneck time — the score.
    useful_time = max(
        model_flops_dev / PEAK_FLOPS_BF16,
        useful_bytes_per_dev(rec) / HBM_BW,
    )
    frac = useful_time / max(bound, 1e-12)
    live = rec.get("memory_analysis", {}).get("live_bytes_per_device")
    return {
        "cell": rec["cell"],
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "model_flops_ratio": useful_ratio,
        "roofline_frac": frac,
        "bytes_per_dev": mem_bytes,
        "bytes_as_compiled_per_dev": ha["bytes_accessed"],
        "flops_per_dev": ha["flops"],
        "coll_bytes_per_dev": ha["collective_bytes"],
        "coll_by_type": ha.get("collectives_by_type", {}),
        "live_bytes_per_dev": live,
        "fits_hbm": (live is not None and live <= HBM_PER_CHIP),
        "top_flops": ha.get("top_flops", [])[:5],
        "top_bytes": ha.get("top_bytes", [])[:5],
    }


HINTS = {
    "compute": "shave non-model FLOPs: causal block-skip in attention "
    "(Pallas kernel), cheaper remat policy, leaner MoE dispatch",
    "memory": "shrink HBM traffic: fuse/flash attention tiles, narrower "
    "remat, KV in fp8, avoid staging copies of the cache",
    "collective": "re-shard to cut collective bytes: overlap DP all-reduce, "
    "reduce-scatter grads, keep activations model-sharded longer",
}


def render_markdown(rows: list[dict]) -> str:
    hdr = (
        "| cell | compute (s) | memory (s) | collective (s) | dominant | "
        "useful/HLO | roofline frac | live GiB/chip |\n"
        "|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        live = (
            f"{r['live_bytes_per_dev']/2**30:.2f}"
            if r["live_bytes_per_dev"] is not None
            else "?"
        )
        lines.append(
            f"| {r['cell']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | **{r['dominant']}** | "
            f"{r['model_flops_ratio']:.2f} | {r['roofline_frac']:.3f} | {live} |"
        )
    return hdr + "\n".join(lines) + "\n"


def pick_hillclimb_cells(rows: list[dict]) -> dict:
    """worst roofline fraction, most collective-bound, most paper-representative.

    Worst-fraction is restricted to >=90B-param cells: tiny archs at frac~0
    are bounded by fixed overheads, not by anything a sharding/kernel change
    can move, so hillclimbing them wastes the budget (see EXPERIMENTS.md).
    """
    from repro.configs.registry import get_config

    single = [r for r in rows if r["mesh"] == "pod16x16"]
    big = [r for r in single if get_config(r["arch"]).param_count() > 9e10]
    worst = min(big or single, key=lambda r: r["roofline_frac"])
    coll = max(
        single,
        key=lambda r: r["collective_s"]
        / max(r["compute_s"], r["memory_s"], 1e-12),
    )
    # paper-representative: decode with a big KV cache (the KVCache read path
    # Beluga optimizes) on the paper-scale dense GQA arch
    reps = [
        r
        for r in single
        if r["shape"] == "decode_32k" and r["arch"] in ("command-r-35b", "internvl2-26b")
    ]
    rep = reps[0] if reps else min(
        (r for r in single if r["shape"] == "decode_32k"),
        key=lambda r: r["roofline_frac"],
    )
    return {"worst_fraction": worst, "most_collective": coll, "paper_representative": rep}


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    rows = [t for r in load_records(args.out, args.tag) if (t := roofline_terms(r))]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    print(render_markdown(rows))
    picks = pick_hillclimb_cells(rows)
    print("hillclimb picks:")
    for k, v in picks.items():
        print(
            f"  {k}: {v['cell']} (dominant={v['dominant']}, frac={v['roofline_frac']:.3f})"
        )
        print(f"    hint: {HINTS[v['dominant']]}")


if __name__ == "__main__":
    main()

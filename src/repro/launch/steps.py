"""Cell builders: (arch × shape × mesh) -> jittable fn + input specs/shardings.

Shared by the multi-pod dry-run (lower+compile only) and the real launchers.
A "cell" lowers one of:

  train_4k     -> train_step  (loss + grad + AdamW update, remat, bf16 grads)
  prefill_32k  -> prefill_fn  (full prefill, emits populated KV/SSM cache)
  decode_32k   -> decode_fn   (one token, KV cache of seq_len)
  long_500k    -> decode_fn   (sub-quadratic archs only)
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax

from repro.configs.base import (
    ModelConfig,
    RuntimeConfig,
    ShapeConfig,
    shape_applicable,
)
from repro.distributed import sharding as shlib
from repro.distributed.sharding import AxisRules
from repro.models.model import Model
from repro.training import optimizer as opt_lib
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import make_train_step


@dataclass
class Cell:
    name: str
    fn: Callable
    args_sds: tuple  # ShapeDtypeStructs to lower against
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()
    notes: str = ""


def batch_shardings(model: Model, shape: ShapeConfig) -> dict:
    return model.input_shardings(shape)


def _decode_axes(rules: AxisRules, shape: ShapeConfig, runtime: RuntimeConfig):
    """(cache kv logical axes, shard_map kv axes, shard_map batch axes)."""
    multi_pod = "pod" in rules.mesh.axis_names
    if runtime.decode_kv == "replicated":
        return ("batch", None), (), ("pod", "data") if multi_pod else ("data",)
    if shape.name == "long_500k" or shape.global_batch < rules.dp:
        # batch unshardable: interleave KV seq across every mesh axis
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return (None, "kv_seq_long"), axes, ()
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return ("batch", "kv_seq"), ("model",), batch_axes


def build_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    rules: AxisRules,
    runtime: RuntimeConfig | None = None,
    opt_cfg: OptimizerConfig | None = None,
) -> Cell:
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"cell not applicable: {why}")
    runtime = runtime or RuntimeConfig()
    opt_cfg = opt_cfg or OptimizerConfig()
    if "pod" in rules.mesh.axis_names:
        # extend long-decode interleaving across the pod axis on multi-pod
        rules = dataclasses.replace(
            rules, rules={**rules.rules, "kv_seq_long": ("pod", "data", "model")}
        )
    if runtime.rowp_bf16_psum:
        rules = dataclasses.replace(rules, rowp_bf16=True)
    model = Model(cfg, runtime, rules)

    param_specs = model.param_specs()
    params_sds = shlib.tree_shape_dtype(param_specs)
    params_sh = shlib.tree_shardings(param_specs, rules)
    batch_sds = model.input_specs(shape)
    batch_sh = batch_shardings(model, shape)

    if shape.kind == "train":
        opt_specs = opt_lib.opt_state_specs(opt_cfg, param_specs)
        opt_sds = shlib.tree_shape_dtype(opt_specs)
        opt_sh = shlib.tree_shardings(opt_specs, rules)
        fn = make_train_step(model, opt_cfg)
        return Cell(
            name=f"{cfg.name}.{shape.name}",
            fn=fn,
            args_sds=(params_sds, opt_sds, batch_sds),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
            notes=f"train_step remat={runtime.remat} "
            f"grad_compression={opt_cfg.grad_compression}",
        )

    if shape.kind == "prefill":
        fn = functools.partial(model.prefill_fn, max_len=shape.seq_len)
        return Cell(
            name=f"{cfg.name}.{shape.name}",
            fn=fn,
            args_sds=(params_sds, batch_sds),
            in_shardings=(params_sh, batch_sh),
            out_shardings=None,
            notes="prefill_fn -> (last logits, populated cache)",
        )

    # decode
    kv_axes, shard_axes, b_axes = _decode_axes(rules, shape, runtime)
    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len, kv_axes)
    cache_sds = shlib.tree_shape_dtype(cache_specs)
    cache_sh = shlib.tree_shardings(cache_specs, rules)
    tok_sh = batch_sh["tokens"]
    pos_sh = batch_sh["pos"]
    fn = functools.partial(
        model.decode_fn, kv_shard_axes=shard_axes, kv_batch_axes=b_axes
    )
    return Cell(
        name=f"{cfg.name}.{shape.name}",
        fn=fn,
        args_sds=(params_sds, cache_sds, batch_sds["tokens"], batch_sds["pos"]),
        in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
        notes=f"decode_fn kv={runtime.decode_kv} shard_axes={shard_axes}",
    )


def lower_cell(cell: Cell, mesh) -> Any:
    with mesh:
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        return jitted.lower(*cell.args_sds)

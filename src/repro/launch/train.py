"""Training launcher: ``python -m repro.launch.train --arch olmo-1b --smoke``.

Single-process (CPU/dev) path runs for real; on a pod the same script is
launched per host after ``jax.distributed.initialize()`` (the mesh and
shardings are host-count agnostic). Supports checkpoint restart (resumes
params/opt/data state) and heartbeat-file liveness for the watchdog.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="1x1", help="DxM, e.g. 2x4 (fake devices)")
    ap.add_argument("--heartbeat-file", default=None)
    args = ap.parse_args()

    d, m = (int(x) for x in args.mesh.split("x"))
    if d * m > 1:
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={d*m}"
        )

    import jax

    from repro.configs.base import RuntimeConfig
    from repro.configs.registry import get_config, reduced_config
    from repro.data.pipeline import DataConfig, make_dataset
    from repro.distributed.sharding import AxisRules
    from repro.models import Model
    from repro.training import optimizer as opt_lib
    from repro.training.optimizer import OptimizerConfig
    from repro.training.train_loop import TrainLoopConfig, run_train_loop

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    rules = None
    if d * m > 1:
        from repro.launch.mesh import axis_types_kw

        mesh = jax.make_mesh((d, m), ("data", "model"), **axis_types_kw(2))
        rules = AxisRules.create(mesh)
    runtime = RuntimeConfig(
        remat="full", attn_chunk_q=64, attn_chunk_kv=64, moe_dispatch="einsum"
    )
    model = Model(cfg, runtime, rules)
    opt_cfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=5, total_steps=args.steps)

    data = make_dataset(
        DataConfig(
            seq_len=args.seq_len,
            global_batch=args.batch,
            vocab_size=cfg.vocab_size,
            dp_size=1,
        )
    )

    params = opt_state = None
    start_step = 0
    if args.resume and args.checkpoint_dir:
        from repro.checkpoint.checkpointer import Checkpointer

        ck = Checkpointer(args.checkpoint_dir)
        step = ck.latest_step()
        if step is not None:
            params0 = model.init(jax.random.key(0))
            opt0 = opt_lib.init_opt_state(opt_cfg, params0)
            tree = ck.restore(step, {"params": params0, "opt_state": opt0})
            params, opt_state = tree["params"], tree["opt_state"]
            data.load_state_dict(ck.load_extra(step).get("data_state", {}))
            start_step = step
            print(f"resumed from step {step}")

    hb = args.heartbeat_file

    def on_metrics(step, metrics):
        print(json.dumps({"step": step, **metrics}))
        if hb:
            with open(hb, "w") as f:
                f.write(f"{time.time()} {step}")

    ctx = rules.mesh if rules is not None else _nullcontext()
    with ctx:
        run_train_loop(
            model,
            opt_cfg,
            TrainLoopConfig(
                steps=args.steps,
                log_every=5,
                checkpoint_every=args.checkpoint_every,
                checkpoint_dir=args.checkpoint_dir,
            ),
            iter(data),
            params=params,
            opt_state=opt_state,
            start_step=start_step,
            on_metrics=on_metrics,
        )


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()

"""Post-optimization HLO text analyzer for the roofline report.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts while-loop
bodies ONCE, so any scan-over-layers model under-reports FLOPs by ~n_layers×
(verified empirically on this container: a 10-iteration scan of matmuls
reported 1/10th of the true flops).  This analyzer parses
``compiled.as_text()`` (the per-device SPMD module) and:

  * multiplies every while body by its ``backend_config.known_trip_count``;
  * counts dot FLOPs exactly from shapes + contracting dims;
  * approximates elementwise/reduce FLOPs and transcendentals;
  * attributes HBM traffic at fusion boundaries (operands + outputs of
    non-fused ops) — interior ops of a fusion don't touch HBM;
  * sums collective bytes (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute, incl. async -start forms) by type;
  * aggregates attribution by ``metadata op_name`` for the perf loop.

All numbers are **per device** (the SPMD module is the per-device program).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field


DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "compare",
    "select", "and", "or", "xor", "not", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "clamp", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "atan2",
    "is-finite", "popcnt", "stochastic-convert",
}
TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "power", "sine", "cosine", "tan",
    "erf", "real", "imag",
}
ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "reshape", "transpose", "broadcast", "iota", "copy",
    "convert", "slice", "dynamic-slice", "dynamic-update-slice", "pad",
    "concatenate", "reverse", "gather", "scatter", "after-all", "domain",
    "partition-id", "replica-id", "copy-start", "copy-done", "add-dependency",
    "optimization-barrier", "rng-get-and-update-state", "rng-bit-generator",
    "infeed", "outfeed", "send", "send-done", "recv", "recv-done",
}

# Ops that READ only what they produce (slices/gathers) or WRITE only their
# update region (in-place DUS/scatter): counting full operand bytes would
# overstate HBM traffic by the full-buffer/slice ratio (e.g. a chunked
# attention loop would appear to re-read the whole KV cache every chunk).
_SLICE_READS = {"slice", "dynamic-slice", "gather"}
_SLICE_WRITES = {"dynamic-update-slice", "scatter"}
# No data movement at all (metadata / layout-only). `convert` is free
# because XLA:CPU's float-normalization pass inserts bf16<->f32 converts of
# whole buffers that do not exist on TPU (native bf16) — counting them
# would charge the roofline for a CPU-backend artifact.
_FREE_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "reshape", "after-all", "domain", "partition-id",
    "replica-id", "add-dependency", "optimization-barrier", "iota",
    "convert",
}
_ALIAS_OPS = {"convert", "bitcast", "bitcast-convert", "reshape", "copy"}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^\)]*?\)?[\w\[\]\{\},\/ ]*?)\s+"
    r"([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+)\s*\{")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attributes (raw tail of line)
    operands: list[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    is_entry: bool
    params: dict[str, str]  # param name -> type str
    ops: list[Op] = field(default_factory=list)
    symbols: dict[str, str] = field(default_factory=dict)  # name -> type str


@dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0
    bytes_fused: float = 0.0  # fusion-ideal estimate (TPU-faithful)
    collective_bytes: float = 0.0
    coll_by_type: dict = field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = field(default_factory=lambda: defaultdict(float))
    attributed_flops: dict = field(default_factory=lambda: defaultdict(float))
    attributed_bytes: dict = field(default_factory=lambda: defaultdict(float))
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "Cost":
        c = Cost(
            self.flops * k, self.transcendentals * k, self.bytes_accessed * k,
            self.bytes_fused * k, self.collective_bytes * k,
        )
        c.coll_by_type = defaultdict(float, {t: v * k for t, v in self.coll_by_type.items()})
        c.coll_counts = defaultdict(float, {t: v * k for t, v in self.coll_counts.items()})
        c.attributed_flops = defaultdict(float, {t: v * k for t, v in self.attributed_flops.items()})
        c.attributed_bytes = defaultdict(float, {t: v * k for t, v in self.attributed_bytes.items()})
        c.unknown_trip_loops = self.unknown_trip_loops
        return c

    def add(self, o: "Cost") -> None:
        self.flops += o.flops
        self.transcendentals += o.transcendentals
        self.bytes_accessed += o.bytes_accessed
        self.bytes_fused += o.bytes_fused
        self.collective_bytes += o.collective_bytes
        for t, v in o.coll_by_type.items():
            self.coll_by_type[t] += v
        for t, v in o.coll_counts.items():
            self.coll_counts[t] += v
        for t, v in o.attributed_flops.items():
            self.attributed_flops[t] += v
        for t, v in o.attributed_bytes.items():
            self.attributed_bytes[t] += v
        self.unknown_trip_loops += o.unknown_trip_loops


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, Computation] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._fused: set[str] = set()
        self._applied: set[str] = set()
        self._classify()
        self._cache: dict[tuple[str, str], Cost] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Computation | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr and "=" not in line.split("(")[0]:
                params = {}
                for pm in re.finditer(r"([\w\.\-]+):\s*([^,\)]+(?:\)[^,]*)?)", hdr.group(3)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(
                    name=hdr.group(2), is_entry=bool(hdr.group(1)), params=params
                )
                cur.symbols.update(params)
                self.computations[cur.name] = cur
                if cur.is_entry:
                    self.entry = cur.name
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _OP_RE.match(line)
            if not m:
                # parameter lines look like ops; also tolerate unparsed lines
                pm = re.match(
                    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+parameter\(", line
                )
                if pm:
                    cur.symbols[pm.group(1)] = pm.group(2)
                continue
            name, type_str, opcode, rest = m.groups()
            op = Op(name=name, type_str=type_str, opcode=opcode, rest=rest)
            # operand names: inside the first balanced paren region
            depth, end = 1, 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_str = rest[:end]
            op.operands = _OPERAND_RE.findall(operand_str)
            cur.ops.append(op)
            cur.symbols[name] = type_str

    def _classify(self) -> None:
        for comp in self.computations.values():
            for op in comp.ops:
                if op.opcode == "fusion":
                    for cm in re.finditer(r"calls=%?([\w\.\-]+)", op.rest):
                        self._fused.add(cm.group(1))
                elif op.opcode in (
                    "reduce", "reduce-window", "scatter", "sort", "map",
                    "select-and-scatter", "all-reduce", "reduce-scatter",
                    "all-reduce-start",
                ):
                    for cm in re.finditer(r"(?:to_apply|called_computations)=\{?%?([\w\.\-]+)", op.rest):
                        self._applied.add(cm.group(1))

    # ------------------------------------------------------------------
    def analyze(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self._comp_cost(self.entry, traffic=True)

    def _comp_cost(self, comp_name: str, traffic: bool) -> Cost:
        key = (comp_name, "t" if traffic else "f")
        if key in self._cache:
            return self._cache[key]
        comp = self.computations.get(comp_name)
        cost = Cost()
        if comp is None:
            return cost
        for op in comp.ops:
            cost.add(self._op_cost(comp, op, traffic))
        self._cache[key] = cost
        return cost

    def _fusion_traffic(self, comp: Computation, op: Op, inner_name: str | None) -> float:
        """HBM traffic of one fusion op: per-parameter effective reads +
        effective output write (update-size for DUS-rooted fusions)."""
        out_b = _shape_bytes(op.type_str)
        inner = self.computations.get(inner_name) if inner_name else None
        if inner is not None and all(
            o.opcode == "parameter" or o.opcode in _ALIAS_OPS for o in inner.ops
        ):
            return 0.0  # pure dtype-normalization fusion (CPU bf16 artifact)
        if inner is None:
            total = out_b
            for o in op.operands:
                t = comp.symbols.get(o)
                if t:
                    total += _shape_bytes(t)
            return total
        key = ("fparams", inner_name)
        if key not in self._cache:
            self._cache[key] = _fusion_param_read_bytes(inner)
        reads, full = self._cache[key]
        total = 0.0
        for i, o in enumerate(op.operands):
            t = comp.symbols.get(o)
            if not t:
                continue
            if i in full:
                total += _shape_bytes(t)
            else:
                total += reads.get(i, 0.0)
        # DUS-rooted fusion writes only the update region; walk alias ops
        # (convert/bitcast/reshape) from the root to find the true producer
        # (XLA:CPU roots these fusions in a convert of the DUS).
        by_name = {o.name: o for o in inner.ops}
        root = inner.ops[-1] if inner.ops else None
        seen = set()
        while (
            root is not None
            and root.opcode in _ALIAS_OPS
            and root.operands
            and root.name not in seen
        ):
            seen.add(root.name)
            root = by_name.get(root.operands[0])
        if root is not None and root.opcode in _SLICE_WRITES:
            upd = inner.symbols.get(root.operands[1]) if len(root.operands) > 1 else None
            total += _shape_bytes(upd) if upd else out_b
        else:
            total += out_b
        return total

    def _collective_operand_bytes(self, comp: Computation, op: Op) -> float:
        """Collective bytes at the PRE-float-normalization dtype.

        XLA:CPU rewrites every bf16 reduction to f32 (convert -> all-reduce
        -> convert); TPU reduces native bf16. Counting the f32 operand would
        double-charge the roofline for a CPU-backend artifact, so when the
        operand is a convert (or convert-only fusion) of a narrower value we
        count the narrower width.
        """
        total = 0.0
        defs = {o.name: o for o in comp.ops}
        for name in op.operands:
            t = comp.symbols.get(name)
            if not t:
                continue
            b = _shape_bytes(t)
            producer = defs.get(name)
            if producer is not None:
                src = None
                if producer.opcode == "convert" and producer.operands:
                    src = comp.symbols.get(producer.operands[0])
                elif producer.opcode == "fusion":
                    cm = re.search(r"calls=%?([\w\.\-]+)", producer.rest)
                    inner = self.computations.get(cm.group(1)) if cm else None
                    if inner is not None and all(
                        o.opcode == "parameter" or o.opcode in _ALIAS_OPS
                        for o in inner.ops
                    ) and producer.operands:
                        src = comp.symbols.get(producer.operands[0])
                if src:
                    b = min(b, _shape_bytes(src))
            total += b
        # consumer side: dot accumulators are f32 on CPU with the convert
        # AFTER the reduce; if every consumer of this collective immediately
        # converts to a narrower dtype, the semantic width is the narrower one
        consumers = [
            o for o in comp.ops
            if op.name in o.operands and o.name != op.name
        ]
        gte = [o for o in consumers if o.opcode == "get-tuple-element"]
        if gte:
            names = {o.name for o in gte}
            consumers = [
                o for o in comp.ops if names & set(o.operands)
            ] or consumers
        conv_bytes = []
        for o in consumers:
            if o.opcode == "convert":
                conv_bytes.append(_shape_bytes(o.type_str))
            elif o.opcode == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", o.rest)
                inner = self.computations.get(cm.group(1)) if cm else None
                if inner is not None and all(
                    x.opcode == "parameter" or x.opcode in _ALIAS_OPS
                    for x in inner.ops
                ):
                    conv_bytes.append(_shape_bytes(o.type_str))
                else:
                    conv_bytes = []
                    break
            else:
                conv_bytes = []
                break
        if consumers and conv_bytes:
            total = min(total, float(sum(conv_bytes) / max(len(conv_bytes), 1)))
        return total

    def _op_cost(self, comp: Computation, op: Op, traffic: bool) -> Cost:
        c = Cost()
        oc = op.opcode
        meta = _op_label(op)

        def operand_bytes() -> int:
            total = 0
            for o in op.operands:
                t = comp.symbols.get(o)
                if t:
                    total += _shape_bytes(t)
            return total

        if oc == "while":
            body = re.search(r"body=%?([\w\.\-]+)", op.rest)
            cond = re.search(r"condition=%?([\w\.\-]+)", op.rest)
            trip_m = _TRIP_RE.search(op.rest)
            trip = int(trip_m.group(1)) if trip_m else 1
            if trip_m is None:
                c.unknown_trip_loops += 1
            if body:
                c.add(self._comp_cost(body.group(1), traffic=True).scaled(trip))
            if cond:
                c.add(self._comp_cost(cond.group(1), traffic=True).scaled(trip + 1))
            return c

        if oc == "conditional":
            branches = re.search(r"branch_computations=\{([^\}]*)\}", op.rest)
            names = []
            if branches:
                names = _OPERAND_RE.findall(branches.group(1))
            else:
                tb = re.search(r"true_computation=%?([\w\.\-]+)", op.rest)
                fb = re.search(r"false_computation=%?([\w\.\-]+)", op.rest)
                names = [x.group(1) for x in (tb, fb) if x]
            costs = [self._comp_cost(n, traffic=True) for n in names]
            if costs:
                # one branch executes; take the max-flops branch
                c.add(max(costs, key=lambda x: x.flops))
            return c

        if oc == "fusion":
            called = re.search(r"calls=%?([\w\.\-]+)", op.rest)
            inner_name = called.group(1) if called else None
            if inner_name:
                inner = self._comp_cost(inner_name, traffic=False)
                c.flops += inner.flops
                c.transcendentals += inner.transcendentals
                for t, v in inner.attributed_flops.items():
                    c.attributed_flops[t] += v
            if traffic:
                b = self._fusion_traffic(comp, op, inner_name)
                c.bytes_accessed += b
                c.bytes_fused += b
                c.attributed_bytes[meta] += b
            return c

        if oc == "call":
            called = re.search(r"to_apply=%?([\w\.\-]+)", op.rest)
            if called:
                c.add(self._comp_cost(called.group(1), traffic=traffic))
            return c

        base = oc.replace("-start", "") if oc.endswith("-start") else oc
        if base in COLLECTIVES:
            b = self._collective_operand_bytes(comp, op)
            c.collective_bytes += b
            c.coll_by_type[base] += b
            c.coll_counts[base] += 1
            if traffic:
                bb = b + _shape_bytes(op.type_str)
                c.bytes_accessed += bb
                c.bytes_fused += bb
                c.attributed_bytes[meta] += bb
            # all-reduce applies a reduction computation: count flops ~ elems
            if base in ("all-reduce", "reduce-scatter"):
                c.flops += _shape_elems(op.type_str)
            return c
        if oc.endswith("-done"):
            return c

        if oc == "dot":
            out_elems = _shape_elems(op.type_str)
            lhs = comp.symbols.get(op.operands[0]) if op.operands else None
            kdim = 1
            lcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
            if lhs and lcd and lcd.group(1):
                dims = _first_shape_dims(lhs)
                for d in lcd.group(1).split(","):
                    di = int(d)
                    if di < len(dims):
                        kdim *= dims[di]
            f = 2.0 * out_elems * kdim
            c.flops += f
            c.attributed_flops[meta] += f
        elif oc == "convolution":
            out_elems = _shape_elems(op.type_str)
            rhs = comp.symbols.get(op.operands[1]) if len(op.operands) > 1 else None
            k = 1
            if rhs:
                dims = _first_shape_dims(rhs)
                if dims:
                    k = 1
                    for d in dims:
                        k *= d
                    # divide by output features (last dim heuristic)
                    k = max(1, k // max(1, dims[-1]))
            f = 2.0 * out_elems * k
            c.flops += f
            c.attributed_flops[meta] += f
        elif oc in ("reduce", "reduce-window"):
            in_elems = 0
            for o in op.operands[: max(1, len(op.operands) // 2)]:
                t = comp.symbols.get(o)
                if t:
                    in_elems += _shape_elems(t)
            c.flops += in_elems
            c.attributed_flops[meta] += in_elems
        elif oc == "sort":
            import math as _math

            n = _shape_elems(op.type_str)
            c.flops += n * max(1.0, _math.log2(max(n, 2)))
        elif oc in TRANSCENDENTAL:
            c.transcendentals += _shape_elems(op.type_str)
        elif oc in ELEMENTWISE:
            c.flops += _shape_elems(op.type_str)
        elif oc in ZERO_COST or oc == "custom-call":
            pass
        # unknown opcodes: ignore (counted as zero) — keep analyzer robust

        if traffic and oc not in _FREE_BYTES:
            out_b = _shape_bytes(op.type_str)
            fusable = oc in ELEMENTWISE or oc in TRANSCENDENTAL or oc in (
                "broadcast", "transpose",
            )
            if oc in _SLICE_READS:
                b = 2 * out_b  # read slice + write result
            elif oc in _SLICE_WRITES:
                # in-place update: traffic ~ the update operand (2nd arg),
                # not the full buffer
                upd = 0
                if len(op.operands) > 1:
                    t = comp.symbols.get(op.operands[1])
                    upd = _shape_bytes(t) if t else 0
                b = 2 * max(upd, 1)
            else:
                b = operand_bytes() + out_b
            c.bytes_accessed += b
            # fusion-ideal: standalone elementwise chains fuse to zero
            # incremental HBM traffic on TPU; count everything else
            if not fusable:
                c.bytes_fused += b
            c.attributed_bytes[meta] += b
        return c


def _fusion_param_read_bytes(comp: Computation) -> dict[int, float]:
    """Effective read bytes per parameter index of a fused computation.

    A parameter consumed ONLY by slice-like ops contributes the slice
    output sizes (what the fusion actually reads), not its full extent —
    this is what makes chunked-attention loops and scan-carried caches
    cost what the hardware would pay, not |buffer| per iteration.
    """
    param_idx: dict[str, int] = {}
    for op in comp.ops:
        if op.opcode == "parameter":
            # _OP_RE leaves rest = "<idx>)..." after consuming "parameter("
            m = re.match(r"\s*(\d+)\)", op.rest)
            if m:
                param_idx[op.name] = int(m.group(1))
    # alias map: convert/bitcast/reshape/copy of a param is still the param
    alias: dict[str, str] = {}

    def resolve(name: str) -> str | None:
        seen = set()
        while name in alias and name not in seen:
            seen.add(name)
            name = alias[name]
        return name if name in param_idx else None

    for op in comp.ops:
        if op.opcode in _ALIAS_OPS and op.operands:
            alias[op.name] = op.operands[0]

    reads: dict[int, float] = {i: 0.0 for i in param_idx.values()}
    full: set[int] = set()
    for op in comp.ops:
        if op.opcode == "parameter" or op.opcode in _ALIAS_OPS:
            continue
        for pos, operand in enumerate(op.operands):
            root = resolve(operand)
            if root is None:
                continue
            i = param_idx[root]
            if op.opcode in _SLICE_READS and pos == 0:
                reads[i] += _shape_bytes(op.type_str)
            elif op.opcode in _SLICE_WRITES and pos == 0:
                # pass-through buffer being updated in place: reads ~ update
                upd = comp.symbols.get(op.operands[1]) if len(op.operands) > 1 else None
                reads[i] += _shape_bytes(upd) if upd else 0.0
            elif op.opcode in ("dynamic-slice", "dynamic-update-slice", "gather", "scatter"):
                pass  # index operands: negligible
            else:
                full.add(i)
    return reads, full


def _op_label(op: Op) -> str:
    m = re.search(r'op_name="([^"]*)"', op.rest)
    if m:
        label = m.group(1)
        # strip jit wrapper + trailing uniquifiers for aggregation
        label = re.sub(r"^jit\([^)]*\)/", "", label)
        parts = label.split("/")
        return "/".join(parts[:4])
    return op.opcode


# ---------------------------------------------------------------------------


def analyze_hlo(hlo_text: str) -> dict:
    a = HloAnalyzer(hlo_text)
    c = a.analyze()
    top_f = sorted(c.attributed_flops.items(), key=lambda kv: -kv[1])[:15]
    top_b = sorted(c.attributed_bytes.items(), key=lambda kv: -kv[1])[:15]
    return {
        "flops": c.flops,
        "transcendentals": c.transcendentals,
        "bytes_accessed": c.bytes_accessed,
        "bytes_fused": c.bytes_fused,
        "collective_bytes": c.collective_bytes,
        "collectives_by_type": dict(c.coll_by_type),
        "collective_counts": dict(c.coll_counts),
        "top_flops": top_f,
        "top_bytes": top_b,
        "unknown_trip_loops": c.unknown_trip_loops,
    }

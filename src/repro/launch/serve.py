"""Serving launcher: real tokens on CPU with the full Beluga KVCache stack.

``python -m repro.launch.serve --arch olmo-1b --requests 8``

Runs a reduced-config model end to end: prompts -> prefix-index lookup ->
pool fetch (kv_scatter_read) or prefill -> pool writeback (kv_gather_write)
-> batched greedy decode. Demonstrates real cross-request KV reuse through
the shared pool: the second batch of identical prompts skips prefill.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from repro.serving.real_runner import RealEngine

    eng = RealEngine.create(args.arch)
    import numpy as np

    rng = np.random.default_rng(0)
    shared_prefix = rng.integers(0, eng.cfg.vocab_size, size=32).tolist()
    prompts = [
        shared_prefix + rng.integers(0, eng.cfg.vocab_size,
                                     size=args.prompt_len - 32).tolist()
        for _ in range(args.requests)
    ]
    # duplicate a couple of prompts to exercise full-prefix hits
    prompts += prompts[:2]

    t0 = time.time()
    for i, p in enumerate(prompts):
        out, info = eng.generate(p, max_new=args.gen)
        print(
            f"req {i}: hit {info['hit_tokens']}/{len(p)} prompt tokens, "
            f"ttft {info['ttft_s']*1e3:.1f} ms, {len(out)} tokens -> {out[:8]}..."
        )
    print(f"total {time.time()-t0:.1f}s; index: {eng.index.stats()}")


if __name__ == "__main__":
    main()

"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init
while smoke tests/benches see 1 device.
"""

from __future__ import annotations

import jax


def axis_types_kw(n_axes: int) -> dict:
    """``axis_types=(Auto, ...)`` when this jax has AxisType (>= 0.4.38);
    older versions are implicitly Auto."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


def make_debug_mesh(*, multi_pod: bool = False, model: int = 4):
    """Small mesh with the same axis names (CI / 8-device tests)."""
    n = len(jax.devices())
    if multi_pod:
        shape = (2, max(1, n // (2 * model)), model)
        axes = ("pod", "data", "model")
    else:
        shape = (max(1, n // model), model)
        axes = ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


# TPU v5e hardware constants (roofline targets; the container runs CPU-only)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per-chip effective, conservative)
HBM_PER_CHIP = 16 * 2**30  # 16 GiB

"""Deterministic, sharded, checkpointable token pipeline.

Production contract:
  * every (host, dp-rank) reads a disjoint shard of the corpus;
  * iteration order is a pure function of (seed, epoch, step) — restart from
    a checkpoint reproduces the exact remaining stream (`state_dict` /
    `load_state_dict`);
  * two sources: ``SyntheticLM`` (deterministic PRNG tokens, for smoke /
    dry-runs) and ``PackedFileDataset`` (memory-mapped token file packed
    into fixed-length sequences).
"""

from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass

import numpy as np


@dataclass
class DataConfig:
    seq_len: int = 4096
    global_batch: int = 256
    dp_rank: int = 0
    dp_size: int = 1
    seed: int = 0
    vocab_size: int = 50304
    source: str = "synthetic"  # synthetic | file
    path: str | None = None


class SyntheticLM:
    """Deterministic synthetic LM batches (counter-based PRNG: O(1) state)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.dp_size == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.dp_size
        self.step = 0

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        cfg = self.cfg
        # counter-based: seed ^ step ^ rank -> independent of call history
        rng = np.random.default_rng(
            np.uint64(cfg.seed) * np.uint64(1_000_003)
            + np.uint64(self.step) * np.uint64(65_537)
            + np.uint64(cfg.dp_rank)
        )
        tokens = rng.integers(
            0, cfg.vocab_size, size=(self.local_batch, cfg.seq_len), dtype=np.int32
        )
        self.step += 1
        return {"tokens": tokens, "labels": tokens}

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state["step"])


class PackedFileDataset:
    """Memory-mapped int32 token file -> packed fixed-length sequences.

    Shuffling is a seeded permutation of sequence indices per epoch; each
    dp rank takes indices [rank::dp_size]. State = (epoch, cursor).
    """

    def __init__(self, cfg: DataConfig):
        assert cfg.path and os.path.exists(cfg.path), cfg.path
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self.n_seqs = len(self.tokens) // cfg.seq_len
        assert self.n_seqs >= cfg.global_batch, "corpus smaller than one batch"
        self.local_batch = cfg.global_batch // cfg.dp_size
        self.epoch = 0
        self.cursor = 0  # position within this rank's index stream

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(self.cfg.seed + epoch)
        return rng.permutation(self.n_seqs)

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        cfg = self.cfg
        perm = self._perm(self.epoch)
        mine = perm[cfg.dp_rank :: cfg.dp_size]
        if self.cursor + self.local_batch > len(mine):
            self.epoch += 1
            self.cursor = 0
            perm = self._perm(self.epoch)
            mine = perm[cfg.dp_rank :: cfg.dp_size]
        idx = mine[self.cursor : self.cursor + self.local_batch]
        self.cursor += self.local_batch
        batch = np.stack(
            [self.tokens[i * cfg.seq_len : (i + 1) * cfg.seq_len] for i in idx]
        ).astype(np.int32)
        return {"tokens": batch, "labels": batch}

    def state_dict(self) -> dict:
        return {"epoch": self.epoch, "cursor": self.cursor, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict) -> None:
        self.epoch = int(state["epoch"])
        self.cursor = int(state["cursor"])


def make_dataset(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticLM(cfg)
    if cfg.source == "file":
        return PackedFileDataset(cfg)
    raise ValueError(cfg.source)

"""Sharded checkpointing: per-process shard files + manifest, async save.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json          tree structure, shapes, dtypes, shardings
        extra.json             user metadata (data-iterator state, ...)
        proc_<k>.npz           addressable shards owned by process k
        _COMMITTED             atomic commit marker (written last)

Fault-tolerance contract:
  * a checkpoint without ``_COMMITTED`` is ignored by ``latest_step`` /
    ``restore`` (partial writes from a crashed host are harmless);
  * saves can run asynchronously (``async_save=True``) on a worker thread —
    the training loop keeps stepping while the previous step serializes;
  * each process writes only shards it owns (``addressable_shards``), so
    N-host saves scale without a coordinator; restore re-assembles arrays
    from any process count as long as the mesh can address all shards.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

from repro.core import diag


def _flatten_with_paths(tree: Any):
    # jax.tree.flatten_with_path only exists in jax >= 0.4.38; go through
    # tree_util for compatibility with the pinned 0.4.3x toolchain
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 2, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def latest_step(self) -> int | None:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "_COMMITTED")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        if self.async_save:
            self.wait()
            # device_get before handing to the thread
            host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree, extra, True)
            )
            self._thread.start()
        else:
            self._save_sync(step, tree, extra, False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_sync(self, step, tree, extra, already_host: bool) -> None:
        d = self.step_dir(step)
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)

        flat, _ = _flatten_with_paths(tree)
        proc = jax.process_index()
        manifest = {"leaves": {}, "nprocs": jax.process_count()}
        shard_payload: dict[str, np.ndarray] = {}
        for key, leaf in flat:
            arr = leaf
            manifest["leaves"][key] = {
                "shape": list(np.shape(arr)),
                "dtype": str(np.asarray(jax.tree.leaves(arr)[0]).dtype)
                if not hasattr(arr, "dtype")
                else str(arr.dtype),
            }
            if already_host or not isinstance(arr, jax.Array):
                shard_payload[f"{key}||full"] = _to_savable(np.asarray(arr))
            else:
                for sh in arr.addressable_shards:
                    if sh.replica_id == 0:
                        idx = _index_str(sh.index, arr.shape)
                        shard_payload[f"{key}||{idx}"] = _to_savable(
                            np.asarray(sh.data)
                        )

        np.savez(os.path.join(tmp, f"proc_{proc}.npz"), **shard_payload)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "extra.json"), "w") as f:
            json.dump(extra or {}, f)
        with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(d):
            shutil.rmtree(d)
        os.replace(tmp, d)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1))
            for name in os.listdir(self.dir)
            if (m := re.fullmatch(r"step_(\d+)", name))
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: int, target_tree: Any, shardings: Any | None = None):
        """Restore into the structure of ``target_tree`` (shapes/dtypes)."""
        d = self.step_dir(step)
        if not os.path.exists(os.path.join(d, "_COMMITTED")):
            raise FileNotFoundError(f"no committed checkpoint at {d}")
        payload: dict[str, np.ndarray] = {}
        for name in os.listdir(d):
            if name.startswith("proc_") and name.endswith(".npz"):
                with np.load(os.path.join(d, name)) as z:
                    for k in z.files:
                        payload[k] = z[k]

        flat, treedef = _flatten_with_paths(target_tree)
        sh_flat = (
            jax.tree.leaves(shardings) if shardings is not None else [None] * len(flat)
        )
        out = []
        for (key, leaf), sh in zip(flat, sh_flat):
            shape = tuple(np.shape(leaf))
            dt = _np_dtype(leaf)
            full_key = f"{key}||full"
            if full_key in payload:
                arr = _from_savable(payload[full_key], dt)
            else:
                arr = np.zeros(shape, dtype=dt)
                found = False
                for pk, val in payload.items():
                    if pk.startswith(key + "||"):
                        idx = _parse_index(pk.split("||")[1], shape)
                        arr[idx] = _from_savable(val, dt)
                        found = True
                if not found:
                    raise KeyError(f"checkpoint missing leaf {key}")
            if sh is not None:
                out.append(jax.device_put(arr.astype(dt), sh))
            else:
                out.append(arr.astype(dt))
        return jax.tree.unflatten(treedef, out)

    def load_extra(self, step: int) -> dict:
        with open(os.path.join(self.step_dir(step), "extra.json")) as f:
            return json.load(f)


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz can't serialize ml_dtypes (bf16/fp8): store as a uint view; the
    true dtype is restored from the target tree on load."""
    if arr.dtype.kind == "V" or str(arr.dtype) in (
        "bfloat16", "float8_e4m3fn", "float8_e5m2"
    ):
        return arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
    return arr


def _from_savable(arr: np.ndarray, target_dtype) -> np.ndarray:
    td = np.dtype(target_dtype)
    if arr.dtype != td and arr.dtype in (np.uint16, np.uint8) and td.itemsize == arr.dtype.itemsize:
        return arr.view(td)
    return arr


def _np_dtype(leaf) -> np.dtype:
    try:
        return np.dtype(leaf.dtype)
    except Exception:  # noqa: BLE001
        diag.note("checkpointer.np_dtype_fallback")
        return np.asarray(leaf).dtype


def _index_str(index, shape) -> str:
    parts = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else sl.start
        stop = dim if sl.stop is None else sl.stop
        parts.append(f"{start}:{stop}")
    return ",".join(parts)


def _parse_index(s: str, shape) -> tuple:
    if not s:
        return tuple(slice(None) for _ in shape)
    out = []
    for part in s.split(","):
        a, b = part.split(":")
        out.append(slice(int(a), int(b)))
    return tuple(out)

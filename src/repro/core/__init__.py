"""Beluga core: the paper's contribution as composable modules.

fabric     — CXL/RDMA memory-fabric cost model (paper-calibrated constants)
pool       — BelugaPool: interleaved, paged, shared KV block pool (O9)
index      — global prefix index: bytes->row hash table over flat
             structure-of-arrays metadata (chain-hash -> pool block,
             epoch-validated, array-intrusive LRU)
rpc        — CXL-RPC shared-memory ring (real) + modeled RDMA RPC baselines
wire       — binary metadata wire protocol (match/publish/lookup ops, op
             batching) + the engine-side RpcIndexClient proxy
coherence  — software single-writer/multi-reader publication protocol (O1-O3)
transfer   — gather-write / scatter-read engine: beluga vs rdma paths (§6.1)
"""

"""Beluga core: the paper's contribution as composable modules.

fabric     — CXL/RDMA memory-fabric cost model (paper-calibrated constants)
pool       — BelugaPool: interleaved, paged, shared KV block pool (O9)
index      — global prefix index (chain-hash -> pool block, epoch-validated)
rpc        — CXL-RPC shared-memory ring (real) + modeled RDMA RPC baselines
coherence  — software single-writer/multi-reader publication protocol (O1-O3)
transfer   — gather-write / scatter-read engine: beluga vs rdma paths (§6.1)
"""

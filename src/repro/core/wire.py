"""Binary wire protocol for the metadata plane (paper §6, Exp #11).

The centralized ``GlobalIndex`` is reached over the CXL-RPC shared-memory
ring (``repro.core.rpc``); this module defines what actually travels in a
slot: a compact variable-length binary codec for the index ops every
request hits, so ONE ring round-trip carries a whole request's key chain
instead of one RPC per key.

Message layout (little-endian, keys are fixed 16-byte blake2b digests):

    request  := op:u8  body
    MATCH    := n:u32  keys[n*16]
    PUBLISH  := n:u32  n_tokens:i32  keys[n*16]  block_ids[n*i64]  epochs[n*i64]
    LOOKUP   := n:u32  keys[n*16]
    FILTER   := n:u32  keys[n*16]          (writeback: lookup+validate fused)
    EVICT    := n:u32                      (evict up to n LRU blocks)
    BATCH    := k:u32  k * (len:u32 request)
    OWNERS   := n:u32  block_ids[n*i64]    (migrator pre-copy snapshot)
    REMAP    := n:u32  keys[n*16]  old_ids[n*i64]  old_epochs[n*i64]
                       new_ids[n*i64]  new_epochs[n*i64]
    EVICT_BLOCKS := n:u32  block_ids[n*i64]
    STATS    := n:u32 (ignored)            (occupancy/hit counters probe)

    responses:
    MATCH    -> n_ok:u32  block_ids[n_ok*i64]  epochs[n_ok*i64]
    PUBLISH  -> n:u32
    LOOKUP   -> n:u32  block_ids[n*i64]  epochs[n*i64]  n_tokens[n*i32]
                (block_id == -1 marks a missing key)
    FILTER   -> m:u32  positions[m*u32]
    EVICT    -> m:u32  freed_block_ids[m*i64]
    BATCH    -> k:u32  k * (len:u32 response)
    OWNERS   -> m:u32  keys[m*16]  block_ids[m*i64]  epochs[m*i64]
    REMAP    -> n:u32  ok[n*u8]
    EVICT_BLOCKS -> m:u32  freed_block_ids[m*i64]
    STATS    -> entries:u64  hits:u64  misses:u64

OWNERS / REMAP / EVICT_BLOCKS carry the tier-migration control plane, so
the ``MigrationEngine`` no longer has to be co-located with the index: its
metadata ops (pre-copy snapshot, compare-and-swap re-point, spill
eviction) travel the same ring as everything else, while the payload
copies stay on the shared pool.

``handle_request`` is the server-side dispatcher (wrap it with
``make_index_handler`` and hand it to ``CxlRpcServer``); ``RpcIndexClient``
is the engine-side proxy exposing the same API surface the
``KVCacheManager`` uses in-process (``keys_for`` hashes locally — it is
pure computation — and only the 16-byte keys cross the ring). Chains
longer than one slot are transparently split at the op level.
``ShardedRpcIndexClient`` is the multi-ring front: keys partition by
digest (``repro.core.index.shard_of_key``) across S rings, each serving
one ``GlobalIndex`` shard, and every fan-out POSTS to all shards before
collecting any reply — the S sub-requests are outstanding in parallel.
"""

from __future__ import annotations

import struct
import time

import numpy as np

from repro.core.index import (
    IndexEntry,
    PrefixHasher,
    evict_blocks_sharded,
    evict_lru_pressure,
    partition_keys,
    shard_of_key,
)
from repro.core import diag
from repro.core.pool import OutOfPoolMemory
from repro.core.rpc import (
    CTRL_BUSY_NS,
    CTRL_SERVED,
    RetryPolicy,
    RpcError,
    ServiceDiedError,
)

KEY_BYTES = 16

OP_MATCH = 1
OP_PUBLISH = 2
OP_LOOKUP = 3
OP_FILTER = 4
OP_EVICT = 5
OP_BATCH = 6
OP_OWNERS = 7
OP_REMAP = 8
OP_EVICT_BLOCKS = 9
OP_STATS = 10
OP_SNAPSHOT = 11
OP_RESTORE = 12
# pool allocator plane (engine workers -> pool-owning parent); these ops
# are served by a SEPARATE dispatcher (``make_pool_handler``) on its own
# ring — allocator state has exactly one owner, the index service never
# sees them
OP_POOL_ALLOC = 13
OP_POOL_RETAIN = 14
OP_POOL_RELEASE = 15
OP_POOL_FREE = 16
# journal proxy (engine workers -> pool-owning parent, selfheal mode):
# worker-side index clients must journal their confirmed mutations like
# every other client, but the ShardJournal segments are owned by the
# parent — these ops carry the append over the SAME allocator ring the
# worker already holds, tagged with the target shard
OP_JRNL_PUBLISH = 17
OP_JRNL_RETRACT = 18
OP_JRNL_REMAP = 19
# seed hit/miss counters into a freshly restarted shard (warm-snapshot
# restore path; served by the index dispatcher)
OP_SEED_STATS = 20
# tiered-pool extensions of the pool allocator plane (engine workers ->
# tiered-pool-owning parent): keyed allocation routes through the
# ghost-LRU admission filter, TOUCH ships the fetch-path demand signal
# so hotness/promotion state stays with the single pool owner
OP_POOL_ALLOC_KEYS = 21
OP_POOL_TOUCH = 22

_HDR = struct.Struct("<BI")  # op, count
_U32 = struct.Struct("<I")
_PUB_HDR = struct.Struct("<BIi")  # op, count, n_tokens
# entries, hits, misses + the service-side timer (ops served, busy-ns)
# measured IN the serving process — exp11 capacity is read from here
# instead of being inferred from an in-process replica
_STATS = struct.Struct("<QQQQQ")


class WireError(ValueError):
    pass


# ---------------------------------------------------------------------------
# encode (client side)
# ---------------------------------------------------------------------------
def _join_keys(keys) -> bytes:
    blob = b"".join(keys)
    if len(blob) != KEY_BYTES * len(keys):
        raise WireError("keys must be 16-byte digests")
    return blob


def encode_match(keys) -> bytes:
    return _HDR.pack(OP_MATCH, len(keys)) + _join_keys(keys)


def encode_publish(keys, block_ids, epochs, n_tokens: int) -> bytes:
    n = len(keys)
    if not (n == len(block_ids) == len(epochs)):
        raise WireError("publish arrays disagree on length")
    return (
        _PUB_HDR.pack(OP_PUBLISH, n, n_tokens)
        + _join_keys(keys)
        + np.asarray(block_ids, np.int64).tobytes()
        + np.asarray(epochs, np.int64).tobytes()
    )


def encode_lookup(keys) -> bytes:
    return _HDR.pack(OP_LOOKUP, len(keys)) + _join_keys(keys)


def encode_filter(keys) -> bytes:
    return _HDR.pack(OP_FILTER, len(keys)) + _join_keys(keys)


def encode_evict(n: int) -> bytes:
    return _HDR.pack(OP_EVICT, n)


def encode_batch(requests: list[bytes]) -> bytes:
    return _HDR.pack(OP_BATCH, len(requests)) + b"".join(
        _U32.pack(len(r)) + r for r in requests
    )


def encode_owners(block_ids) -> bytes:
    return _HDR.pack(OP_OWNERS, len(block_ids)) + np.asarray(
        block_ids, np.int64
    ).tobytes()


def encode_remap(keys, old_ids, old_epochs, new_ids, new_epochs) -> bytes:
    n = len(keys)
    if not (n == len(old_ids) == len(old_epochs) == len(new_ids) == len(new_epochs)):
        raise WireError("remap arrays disagree on length")
    return (
        _HDR.pack(OP_REMAP, n)
        + _join_keys(keys)
        + np.asarray(old_ids, np.int64).tobytes()
        + np.asarray(old_epochs, np.int64).tobytes()
        + np.asarray(new_ids, np.int64).tobytes()
        + np.asarray(new_epochs, np.int64).tobytes()
    )


def encode_evict_blocks(block_ids) -> bytes:
    return _HDR.pack(OP_EVICT_BLOCKS, len(block_ids)) + np.asarray(
        block_ids, np.int64
    ).tobytes()


def encode_stats() -> bytes:
    """Occupancy + hit/miss counters probe.  Serves two masters: the
    cluster's summary stats when the index lives in another process, and
    the per-shard occupancy signal of ``evict_lru_pressure``."""
    return _HDR.pack(OP_STATS, 0)


def encode_snapshot(start: int, max_items: int) -> bytes:
    """Page ``max_items`` index entries starting ``start`` rows in (LRU
    order) — the rebuild-verification op of the self-healing plane."""
    return _HDR.pack(OP_SNAPSHOT, max_items) + _U32.pack(start)


_SEED_STATS = struct.Struct("<QQ")


def encode_seed_stats(hits: int, misses: int) -> bytes:
    return _HDR.pack(OP_SEED_STATS, 0) + _SEED_STATS.pack(hits, misses)


def encode_restore(keys, block_ids, epochs, n_tokens) -> bytes:
    n = len(keys)
    if not (n == len(block_ids) == len(epochs) == len(n_tokens)):
        raise WireError("restore arrays disagree on length")
    return (
        _HDR.pack(OP_RESTORE, n)
        + _join_keys(keys)
        + np.asarray(block_ids, np.int64).tobytes()
        + np.asarray(epochs, np.int64).tobytes()
        + np.asarray(n_tokens, np.int32).tobytes()
    )


# ---------------------------------------------------------------------------
# decode helpers
# ---------------------------------------------------------------------------
def _need(buf: bytes, end: int) -> None:
    if len(buf) < end:
        raise WireError(f"truncated message: need {end} B, have {len(buf)} B")


def _split_keys(buf: bytes, off: int, n: int) -> tuple[list[bytes], int]:
    end = off + n * KEY_BYTES
    _need(buf, end)
    keys = [buf[i : i + KEY_BYTES] for i in range(off, end, KEY_BYTES)]
    return keys, end


def _split_i64(buf: bytes, off: int, n: int) -> tuple[np.ndarray, int]:
    end = off + 8 * n
    _need(buf, end)
    return np.frombuffer(buf, np.int64, n, off), end


def _split_i32(buf: bytes, off: int, n: int) -> tuple[np.ndarray, int]:
    end = off + 4 * n
    _need(buf, end)
    return np.frombuffer(buf, np.int32, n, off), end


def decode_match_resp(buf: bytes) -> tuple[np.ndarray, np.ndarray]:
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    ids, off = _split_i64(buf, 4, n)
    eps, _ = _split_i64(buf, off, n)
    return ids, eps


def decode_publish_resp(buf: bytes) -> int:
    _need(buf, 4)
    return _U32.unpack_from(buf)[0]


def decode_lookup_resp(buf: bytes) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    ids, off = _split_i64(buf, 4, n)
    eps, off = _split_i64(buf, off, n)
    ntk, _ = _split_i32(buf, off, n)
    return ids, eps, ntk


def decode_filter_resp(buf: bytes) -> list[int]:
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    pos, _ = _split_i32(buf, 4, n)
    return pos.tolist()


def decode_evict_resp(buf: bytes) -> list[int]:
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    ids, _ = _split_i64(buf, 4, n)
    return ids.tolist()


def decode_evict_resp_keys(buf: bytes) -> tuple[list[int], list[bytes]]:
    """Freed block ids + the destroyed keys the server-side ``on_evict``
    hook saw — the tiered client re-arms the ghost-LRU admission filter
    with them in the pool-owning process."""
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    ids, off = _split_i64(buf, 4, n)
    _need(buf, off + 4)
    (k,) = _U32.unpack_from(buf, off)
    keys, _ = _split_keys(buf, off + 4, k)
    return ids.tolist(), keys


def decode_owners_resp(buf: bytes) -> tuple[list[bytes], list[int], list[int]]:
    _need(buf, 4)
    (m,) = _U32.unpack_from(buf)
    keys, off = _split_keys(buf, 4, m)
    ids, off = _split_i64(buf, off, m)
    eps, _ = _split_i64(buf, off, m)
    return keys, ids.tolist(), eps.tolist()


def decode_stats_resp(buf: bytes) -> tuple[int, int, int, int, int]:
    """(entries, hits, misses, ops_served, busy_ns) — the last two are
    the service-side timer (zero when the handler has no ring ctrl)."""
    _need(buf, _STATS.size)
    return _STATS.unpack_from(buf)


def decode_snapshot_resp(
    buf: bytes,
) -> tuple[int, list[bytes], list[int], list[int], list[int]]:
    """(total_entries, keys, block_ids, epochs, n_tokens) for one page."""
    _need(buf, 8)
    total, m = _U32.unpack_from(buf)[0], _U32.unpack_from(buf, 4)[0]
    keys, off = _split_keys(buf, 8, m)
    ids, off = _split_i64(buf, off, m)
    eps, off = _split_i64(buf, off, m)
    ntk, _ = _split_i32(buf, off, m)
    return total, keys, ids.tolist(), eps.tolist(), ntk.tolist()


def decode_restore_resp(buf: bytes) -> int:
    _need(buf, 4)
    return _U32.unpack_from(buf)[0]


def decode_remap_resp(buf: bytes) -> list[bool]:
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    _need(buf, 4 + n)
    return [b != 0 for b in buf[4 : 4 + n]]


def _split_frames(buf: bytes, off: int, k: int) -> list[bytes]:
    """k length-prefixed frames starting at ``off`` (the BATCH body)."""
    out = []
    for _ in range(k):
        _need(buf, off + 4)
        (ln,) = _U32.unpack_from(buf, off)
        off += 4
        _need(buf, off + ln)
        out.append(buf[off : off + ln])
        off += ln
    return out


def decode_batch_resp(buf: bytes) -> list[bytes]:
    _need(buf, 4)
    (k,) = _U32.unpack_from(buf)
    return _split_frames(buf, 4, k)


# ---------------------------------------------------------------------------
# server-side dispatch
# ---------------------------------------------------------------------------
_MAX_BATCH_DEPTH = 4  # BATCH-in-BATCH nesting cap (keeps decode O(payload))


def reply_bound(buf: bytes, _depth: int = 0) -> int:
    """Worst-case reply size for a request, WITHOUT executing it.

    Lets a transport with fixed reply capacity reject an op whose answer
    could not be shipped BEFORE any index mutation runs — otherwise an
    oversized EVICT would free blocks server-side while the caller only
    ever sees a transport error. Walks (and therefore validates) the
    whole frame structure INCLUDING each op's declared body size, so a
    BATCH with a truncated sub-op anywhere also fails up front instead
    of after its leading sub-ops mutated the index."""
    _need(buf, _HDR.size)
    op, n = _HDR.unpack_from(buf)
    if op == OP_MATCH:
        _need(buf, _HDR.size + KEY_BYTES * n)
        return 4 + 16 * n
    if op == OP_PUBLISH:
        _need(buf, _PUB_HDR.size + (KEY_BYTES + 16) * n)
        return 4
    if op == OP_LOOKUP:
        _need(buf, _HDR.size + KEY_BYTES * n)
        return 4 + 20 * n
    if op == OP_FILTER:
        _need(buf, _HDR.size + KEY_BYTES * n)
        return 4 + 4 * n
    if op == OP_EVICT:
        # ids (8 B) + destroyed keys (16 B) + the two u32 counters
        return 8 + 24 * n
    if op == OP_OWNERS:
        _need(buf, _HDR.size + 8 * n)
        return 4 + 32 * n
    if op == OP_REMAP:
        _need(buf, _HDR.size + (KEY_BYTES + 32) * n)
        return 4 + n
    if op == OP_EVICT_BLOCKS:
        _need(buf, _HDR.size + 8 * n)
        # ids (8 B) + destroyed keys (16 B) + the two u32 counters
        return 8 + 24 * n
    if op == OP_STATS:
        return _STATS.size
    if op == OP_SNAPSHOT:
        _need(buf, _HDR.size + 4)
        return 8 + 36 * n  # total+m then 16+8+8+4 per entry
    if op == OP_RESTORE:
        _need(buf, _HDR.size + (KEY_BYTES + 20) * n)
        return 4
    if op == OP_SEED_STATS:
        _need(buf, _HDR.size + _SEED_STATS.size)
        return 4
    if op == OP_BATCH:
        if _depth >= _MAX_BATCH_DEPTH:
            raise WireError(f"BATCH nesting exceeds {_MAX_BATCH_DEPTH}")
        frames = _split_frames(buf, _HDR.size, n)
        return 4 + sum(4 + reply_bound(f, _depth + 1) for f in frames)
    raise WireError(f"unknown op {op}")


def prevalidate(index, buf: bytes, _depth: int = 0) -> None:
    """Semantic validation of a request WITHOUT executing it.

    ``reply_bound`` already walks the frame structure; this pass runs the
    op-level checks (duplicate MATCH keys, out-of-range PUBLISH ids) over
    every sub-op up front, so a BATCH whose later sub-op is invalid fails
    BEFORE its leading mutating sub-ops commit — the batch either starts
    clean or not at all. ``handle_request`` repeats the same checks
    inline as defense-in-depth for direct callers."""
    _need(buf, _HDR.size)
    op, n = _HDR.unpack_from(buf)
    if op == OP_MATCH:
        keys, _ = _split_keys(buf, _HDR.size, n)
        _check_match_keys(keys)
    elif op == OP_PUBLISH:
        _need(buf, _PUB_HDR.size)
        _, n, _ = _PUB_HDR.unpack_from(buf)
        _, off = _split_keys(buf, _PUB_HDR.size, n)
        ids, _ = _split_i64(buf, off, n)
        _check_block_ids(index, ids, "PUBLISH")
    elif op in (OP_OWNERS, OP_EVICT_BLOCKS):
        ids, _ = _split_i64(buf, _HDR.size, n)
        _check_block_ids(index, ids, "OWNERS" if op == OP_OWNERS else "EVICT_BLOCKS")
    elif op == OP_RESTORE:
        _, off = _split_keys(buf, _HDR.size, n)
        ids, _ = _split_i64(buf, off, n)
        _check_block_ids(index, ids, "RESTORE")
    elif op == OP_REMAP:
        _, off = _split_keys(buf, _HDR.size, n)
        old_ids, off = _split_i64(buf, off, n)
        _check_block_ids(index, old_ids, "REMAP old")
        new_ids, _ = _split_i64(buf, off + 8 * n, n)  # skip old_epochs
        _check_block_ids(index, new_ids, "REMAP new")
    elif op == OP_BATCH:
        if _depth >= _MAX_BATCH_DEPTH:
            raise WireError(f"BATCH nesting exceeds {_MAX_BATCH_DEPTH}")
        for f in _split_frames(buf, _HDR.size, n):
            prevalidate(index, f, _depth + 1)


def _check_match_keys(keys: list[bytes]) -> None:
    if len(set(keys)) != len(keys):
        # a chain-hashed prefix never repeats a key; a duplicate would
        # also corrupt the index's batch LRU splice, so reject it at
        # the trust boundary instead of walking it
        raise WireError("duplicate keys in MATCH chain")


def _check_block_ids(index, ids: np.ndarray, what: str) -> None:
    if len(ids) and (ids.min() < 0 or ids.max() >= index.pool.n_blocks):
        # untrusted ids would index block2row out of range (numpy
        # negative indexing would silently corrupt — or leak — another
        # block's owner pointer)
        raise WireError(f"{what} block id out of pool range")


def _evict_with_keys(index, fn) -> bytes:
    """Run one eviction with the index's ``on_evict`` hook wrapped so the
    destroyed keys ALSO travel back in the reply (ids, then keys).  A
    tiered cluster over the process transport needs them client-side: the
    ghost-LRU admission filter lives with the pool owner, not with the
    metadata service that performed the eviction."""
    collected: list[bytes] = []
    prev = getattr(index, "on_evict", None)

    def hook(keys):
        collected.extend(keys)
        if prev is not None:
            prev(keys)

    index.on_evict = hook
    try:
        freed = fn()
    finally:
        index.on_evict = prev
    return (
        _U32.pack(len(freed))
        + np.asarray(freed, np.int64).tobytes()
        + _U32.pack(len(collected))
        + b"".join(collected)
    )


def handle_request(
    index, buf: bytes, _depth: int = 0, _validated: bool = False, ctrl=None
) -> bytes:
    """Decode one wire message, run it against ``index``, encode the reply.

    ``_validated`` skips the inline semantic checks when the caller
    already ran ``prevalidate`` over the whole frame (the server path) —
    direct callers keep them as defense-in-depth.  ``ctrl`` is the
    serving ring's control array when running inside a ring service: it
    lets OP_STATS report the service-side timer (ops served, busy-ns)."""
    _need(buf, _HDR.size)
    op, n = _HDR.unpack_from(buf)
    if op == OP_MATCH:
        keys, _ = _split_keys(buf, _HDR.size, n)
        if not _validated:
            _check_match_keys(keys)
        hits = index.match_prefix_keys(keys)
        ids = np.fromiter((b for _, b, _ in hits), np.int64, len(hits))
        eps = np.fromiter((e for _, _, e in hits), np.int64, len(hits))
        return _U32.pack(len(hits)) + ids.tobytes() + eps.tobytes()
    if op == OP_PUBLISH:
        _need(buf, _PUB_HDR.size)
        _, n, n_tokens = _PUB_HDR.unpack_from(buf)
        keys, off = _split_keys(buf, _PUB_HDR.size, n)
        ids, off = _split_i64(buf, off, n)
        eps, _ = _split_i64(buf, off, n)
        if not _validated:
            _check_block_ids(index, ids, "PUBLISH")
        index.publish_many(keys, ids.tolist(), eps.tolist(), n_tokens)
        return _U32.pack(n)
    if op == OP_LOOKUP:
        keys, _ = _split_keys(buf, _HDR.size, n)
        entries = index.lookup_many(keys)
        ids = np.fromiter(
            (-1 if e is None else e.block_id for e in entries), np.int64, n
        )
        eps = np.fromiter(
            (0 if e is None else e.epoch for e in entries), np.int64, n
        )
        ntk = np.fromiter(
            (0 if e is None else e.n_tokens for e in entries), np.int32, n
        )
        return _U32.pack(n) + ids.tobytes() + eps.tobytes() + ntk.tobytes()
    if op == OP_FILTER:
        keys, _ = _split_keys(buf, _HDR.size, n)
        missing = index.filter_unpublished(keys)
        return _U32.pack(len(missing)) + np.asarray(missing, np.int32).tobytes()
    if op == OP_EVICT:
        return _evict_with_keys(index, lambda: index.evict_lru(n))
    if op == OP_OWNERS:
        ids, _ = _split_i64(buf, _HDR.size, n)
        if not _validated:
            _check_block_ids(index, ids, "OWNERS")
        keys, bids, eps = index.owners_of(ids.tolist())
        return (
            _U32.pack(len(keys))
            + b"".join(keys)
            + np.asarray(bids, np.int64).tobytes()
            + np.asarray(eps, np.int64).tobytes()
        )
    if op == OP_REMAP:
        keys, off = _split_keys(buf, _HDR.size, n)
        old_ids, off = _split_i64(buf, off, n)
        old_eps, off = _split_i64(buf, off, n)
        new_ids, off = _split_i64(buf, off, n)
        new_eps, _ = _split_i64(buf, off, n)
        if not _validated:
            _check_block_ids(index, old_ids, "REMAP old")
            _check_block_ids(index, new_ids, "REMAP new")
        ok = index.remap_many(
            keys, old_ids.tolist(), old_eps.tolist(),
            new_ids.tolist(), new_eps.tolist(),
        )
        return _U32.pack(n) + bytes(bytearray(int(o) for o in ok))
    if op == OP_EVICT_BLOCKS:
        ids, _ = _split_i64(buf, _HDR.size, n)
        if not _validated:
            _check_block_ids(index, ids, "EVICT_BLOCKS")
        return _evict_with_keys(
            index, lambda: index.evict_blocks(ids.tolist())
        )
    if op == OP_STATS:
        s = index.stats()
        served = int(ctrl[CTRL_SERVED]) if ctrl is not None else 0
        busy = int(ctrl[CTRL_BUSY_NS]) if ctrl is not None else 0
        return _STATS.pack(s["entries"], s["hits"], s["misses"], served, busy)
    if op == OP_SNAPSHOT:
        _need(buf, _HDR.size + 4)
        (start,) = _U32.unpack_from(buf, _HDR.size)
        total, keys, ids, eps, ntk = index.snapshot_entries(start, n)
        return (
            _U32.pack(total)
            + _U32.pack(len(keys))
            + b"".join(keys)
            + np.asarray(ids, np.int64).tobytes()
            + np.asarray(eps, np.int64).tobytes()
            + np.asarray(ntk, np.int32).tobytes()
        )
    if op == OP_RESTORE:
        keys, off = _split_keys(buf, _HDR.size, n)
        ids, off = _split_i64(buf, off, n)
        eps, off = _split_i64(buf, off, n)
        ntk, _ = _split_i32(buf, off, n)
        if not _validated:
            _check_block_ids(index, ids, "RESTORE")
        index.restore_entries(keys, ids.tolist(), eps.tolist(), ntk.tolist())
        return _U32.pack(n)
    if op == OP_SEED_STATS:
        hits, misses = _SEED_STATS.unpack_from(buf, _HDR.size)
        index.seed_stats(hits, misses)
        return _U32.pack(0)
    if op == OP_BATCH:
        if _depth >= _MAX_BATCH_DEPTH:
            raise WireError(f"BATCH nesting exceeds {_MAX_BATCH_DEPTH}")
        out = [
            handle_request(index, f, _depth + 1, _validated, ctrl)
            for f in _split_frames(buf, _HDR.size, n)
        ]
        return _U32.pack(n) + b"".join(_U32.pack(len(r)) + r for r in out)
    raise WireError(f"unknown op {op}")


def make_index_handler(index, max_reply: int | None = None, ctrl=None):
    """Handler for ``CxlRpcServer``: the metadata service poll thread.

    ``max_reply`` (usually the ring's ``payload_bytes``) makes the handler
    verify — via ``reply_bound``, before executing anything — that the
    reply can be shipped, so a request whose answer cannot fit never
    half-runs a mutating op.  ``ctrl`` (the serving ring's control array)
    exposes the service timer to OP_STATS."""

    def handler(payload: bytes) -> bytes:
        if max_reply is not None and reply_bound(payload) > max_reply:
            raise WireError(f"reply would exceed {max_reply} B slot")
        prevalidate(index, payload)  # batch starts clean or not at all
        return handle_request(index, payload, _validated=True, ctrl=ctrl)

    return handler


# ---------------------------------------------------------------------------
# client-side proxy
# ---------------------------------------------------------------------------
class RpcIndexClient:
    """``GlobalIndex`` API surface over an RPC transport.

    Drop-in for the manager/engine side of the index: hashing
    (``keys_for``) runs locally, every metadata op is one batched
    round-trip. Ops whose chain exceeds one ring slot are split
    transparently (match splits stop early on a short chunk, so the
    prefix property is preserved).

    ``on_freed`` is the cross-process pool-reclaim hook: a service
    living in ANOTHER process must not mutate allocator state, so its
    evictions only drop index rows and ship the freed block ids back —
    this client then applies the real ``pool.release`` in the
    pool-owning process (None for in-process/thread transports, whose
    server releases directly).

    ``journal`` (a ``repro.core.shm.ShardJournal``) is the self-healing
    hook: confirmed publishes/evictions/remaps are appended so a
    supervisor-respawned service can replay the shard's observable state.
    ``retry`` (a ``repro.core.rpc.RetryPolicy``) turns a dying/restarting
    service into bounded backoff instead of an exception: a
    ``ServiceDiedError`` retries for every op (crash-safe — see the
    journal contract), a ``TimeoutError`` retries only ops that are
    idempotent under an applied-but-unacknowledged first attempt."""

    def __init__(self, rpc, block_tokens: int, max_payload: int | None = None,
                 hasher: PrefixHasher | None = None, on_freed=None,
                 journal=None, retry: RetryPolicy | None = None,
                 on_evict=None):
        self.rpc = rpc
        self.on_freed = on_freed
        # tiered clusters: destroyed keys from server-side evictions are
        # replayed into this hook (ghost-LRU arming in the pool owner)
        self.on_evict = on_evict
        self.journal = journal
        self.retry = retry
        # hashing is pure computation, so clients on one host can share a
        # hasher (and its request memo) instead of re-deriving the same
        # chain once per engine
        self.hasher = hasher if hasher is not None else PrefixHasher(block_tokens)
        self.block_tokens = block_tokens
        if max_payload is None:
            max_payload = getattr(
                getattr(rpc, "ring", None), "payload_bytes", 1 << 20
            )
        # per-op chain capacity of one slot (headers are <= 16 B),
        # bounding BOTH the request and its response
        self._max_match = max(1, (max_payload - 16) // KEY_BYTES)
        self._max_publish = max(1, (max_payload - 16) // (KEY_BYTES + 16))
        self._max_lookup = max(1, (max_payload - 16) // max(KEY_BYTES, 20))
        # evict replies carry 8 B id + 16 B destroyed key per block
        self._max_evict = max(1, (max_payload - 24) // 24)
        self._max_owners = max(1, (max_payload - 16) // 32)  # reply-bound
        self._max_remap = max(1, (max_payload - 16) // (KEY_BYTES + 32))
        self._max_snapshot = max(1, (max_payload - 24) // 36)  # reply-bound

    # -- hashing is local ------------------------------------------------
    def keys_for(self, tokens: list[int]) -> tuple[bytes, ...]:
        return self.hasher.keys_for(tokens)

    # -- transport with bounded retry -----------------------------------
    def _call(self, payload: bytes, idempotent: bool = True) -> bytes:
        """One round-trip under the retry policy (if any).

        ``ServiceDiedError`` (crash / supervisor ring swap) retries every
        op: the journal contract makes an applied-but-unacknowledged
        mutation safe to replay.  ``TimeoutError`` (service alive but
        slow) retries only ``idempotent`` ops — a timed-out EVICT may
        have freed blocks whose reply now sits in a quarantined slot, and
        a timed-out REMAP may have applied, so both surface the timeout
        to the caller instead."""
        pol = self.retry
        if pol is None:
            return self.rpc.call(payload)
        attempt = 0
        while True:
            try:
                return self.rpc.call(payload)
            except ServiceDiedError:
                attempt += 1
                if attempt > pol.max_retries:
                    raise
            except TimeoutError:
                if not idempotent:
                    raise
                attempt += 1
                if attempt > pol.max_retries:
                    raise
            stats = getattr(self.rpc, "stats", None)
            if stats is not None:
                stats.retries += 1
            time.sleep(pol.backoff(attempt))

    def _pipelined_rounds(self, msgs: list[bytes]) -> list[bytes]:
        """Ship independent chunk requests with the post/collect split:
        keep up to the ring's free-slot budget outstanding instead of one
        round-trip per chunk. ONLY for ops whose chunks commute (pure
        reads): the service drains slots in slot order, not post order,
        so pipelined mutations would apply out of order.  A transient
        transport failure (service died / timed out) re-runs every round
        serially under the retry policy — safe precisely because the
        callers are idempotent reads."""
        rpc = self.rpc
        if len(msgs) <= 1 or not hasattr(rpc, "post"):
            return [self._call(m) for m in msgs]
        out: list[bytes | None] = [None] * len(msgs)
        slots: list[tuple[int, int]] = []  # (msg index, slot)
        i = 0
        try:
            window = max(1, min(len(msgs), rpc.free_slots() - 1, 8))
            while i < len(msgs) or slots:
                while i < len(msgs) and len(slots) < window:
                    slots.append((i, rpc.post(msgs[i])))
                    i += 1
                j, slot = slots.pop(0)
                out[j] = rpc.collect(slot)
        except BaseException as e:
            for _, slot in slots:  # drain what was posted (or quarantine)
                try:
                    rpc.collect(slot)
                except Exception:  # noqa: BLE001
                    diag.note("wire.pipelined_drain.collect_failed")
            if self.retry is None or not isinstance(
                e, (ServiceDiedError, TimeoutError)
            ):
                raise
            return [self._call(m) for m in msgs]
        return out

    # -- one round-trip per op ------------------------------------------
    def match_prefix(self, tokens: list[int]) -> list[tuple[bytes, int, int]]:
        return self.match_prefix_keys(self.keys_for(tokens))

    def match_prefix_keys(self, keys) -> list[tuple[bytes, int, int]]:
        # chunk rounds stay SERIAL on purpose: a chunk is only sent after
        # the previous one matched in full, so the service LRU-touches
        # exactly the global all-hit prefix — pipelining would
        # speculatively touch keys past the first hole and break the
        # bit-identical differential equivalence with the in-process index
        out: list[tuple[bytes, int, int]] = []
        for off in range(0, len(keys), self._max_match):
            chunk = keys[off : off + self._max_match]
            ids, eps = decode_match_resp(self._call(encode_match(chunk)))
            out.extend(zip(chunk, ids.tolist(), eps.tolist()))
            if len(ids) < len(chunk):
                break  # prefix ended inside this chunk
        return out

    def publish_many(self, keys, block_ids, epochs, n_tokens: int) -> None:
        # serial rounds on purpose: the service drains slots in slot
        # order, so pipelined publish chunks could insert rows out of
        # chain order and scramble the LRU against the in-process index
        for off in range(0, len(keys), self._max_publish):
            end = off + self._max_publish
            self._call(
                encode_publish(
                    keys[off:end], block_ids[off:end], epochs[off:end], n_tokens
                )
            )
            if self.journal is not None:
                self.journal.append_publish(
                    keys[off:end], block_ids[off:end], epochs[off:end], n_tokens
                )

    def lookup_many(self, keys) -> list[IndexEntry | None]:
        msgs = [
            encode_lookup(keys[off : off + self._max_lookup])
            for off in range(0, len(keys), self._max_lookup)
        ]
        out: list[IndexEntry | None] = []
        for resp in self._pipelined_rounds(msgs):
            ids, eps, ntk = decode_lookup_resp(resp)
            out.extend(
                None if b < 0 else IndexEntry(int(b), int(e), int(t), 0.0)
                for b, e, t in zip(ids.tolist(), eps.tolist(), ntk.tolist())
            )
        return out

    def lookup(self, key: bytes) -> IndexEntry | None:
        return self.lookup_many([key])[0]

    def filter_unpublished(self, keys) -> list[int]:
        offs = list(range(0, len(keys), self._max_lookup))
        msgs = [encode_filter(keys[off : off + self._max_lookup]) for off in offs]
        out: list[int] = []
        for off, resp in zip(offs, self._pipelined_rounds(msgs)):
            out.extend(off + p for p in decode_filter_resp(resp))
        return out

    def evict_lru(self, n: int) -> list[int]:
        # chunked: the RESPONSE carries 8 B per freed block, so an
        # unbounded n could overflow the slot even though the request
        # always fits; a short chunk means the index ran out of victims
        freed: list[int] = []
        while n > 0:
            k = min(n, self._max_evict)
            got, gone = decode_evict_resp_keys(
                self._call(encode_evict(k), idempotent=False)
            )
            if got:
                if self.journal is not None:
                    self.journal.append_retract(got)
                if self.on_freed is not None:
                    self.on_freed(got)  # cross-process: reclaim pool blocks
            if gone and self.on_evict is not None:
                self.on_evict(gone)  # tiered: arm the admission filter
            freed.extend(got)
            if len(got) < k:
                break
            n -= k
        return freed

    # -- tier-migration control plane (the migrator over the wire) ------
    def owners_of(
        self, block_ids
    ) -> tuple[list[bytes], list[int], list[int]]:
        """One-round-trip (chunked) pre-copy snapshot; same contract as
        ``GlobalIndex.owners_of`` (indexed blocks only, input order)."""
        keys: list[bytes] = []
        ids: list[int] = []
        eps: list[int] = []
        M = self._max_owners
        msgs = [
            encode_owners(block_ids[off : off + M])
            for off in range(0, len(block_ids), M)
        ]
        for resp in self._pipelined_rounds(msgs):
            k, b, e = decode_owners_resp(resp)
            keys.extend(k)
            ids.extend(b)
            eps.extend(e)
        return keys, ids, eps

    def remap_many(
        self, keys, old_ids, old_epochs, new_ids, new_epochs
    ) -> list[bool]:
        ok: list[bool] = []
        M = self._max_remap
        for off in range(0, len(keys), M):
            end = off + M
            sub = decode_remap_resp(
                # NOT timeout-idempotent: a timed-out remap may have
                # applied, and a retry would then misreport ok=False
                self._call(
                    encode_remap(
                        keys[off:end], old_ids[off:end], old_epochs[off:end],
                        new_ids[off:end], new_epochs[off:end],
                    ),
                    idempotent=False,
                )
            )
            if self.journal is not None and any(sub):
                sel = [i for i, o in enumerate(sub) if o]
                self.journal.append_remap(
                    [keys[off:end][i] for i in sel],
                    [new_ids[off:end][i] for i in sel],
                    [new_epochs[off:end][i] for i in sel],
                )
            ok.extend(sub)
        return ok

    def evict_blocks(self, block_ids) -> list[int]:
        freed: list[int] = []
        M = self._max_evict  # 24 B per id in the reply: EVICT sizing applies
        for off in range(0, len(block_ids), M):
            got, gone = decode_evict_resp_keys(
                self._call(
                    encode_evict_blocks(block_ids[off : off + M]),
                    idempotent=False,
                )
            )
            if got:
                if self.journal is not None:
                    self.journal.append_retract(got)
                if self.on_freed is not None:
                    self.on_freed(got)  # cross-process: reclaim pool blocks
            if gone and self.on_evict is not None:
                self.on_evict(gone)  # tiered: arm the admission filter
            freed.extend(got)
        return freed

    # -- occupancy / counters -------------------------------------------
    def stats(self) -> dict:
        """Same shape as ``GlobalIndex.stats`` — lets the cluster report
        index stats when the index lives in another process.  The wire's
        service-timer fields are deliberately NOT in this dict (the
        differential harness bit-compares it against the in-process
        index); read them via ``service_stats``."""
        entries, hits, misses, _, _ = decode_stats_resp(
            self._call(encode_stats())
        )
        return {
            "entries": entries,
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / max(1, hits + misses),
        }

    def service_stats(self) -> dict:
        """Service-side timer: requests served + ns spent in handlers,
        measured IN the serving thread/process (exp11's direct capacity
        signal — no in-process replica needed)."""
        _, _, _, served, busy = decode_stats_resp(self._call(encode_stats()))
        return {"ops_served": served, "busy_ns": busy}

    def n_entries(self) -> int:
        """Occupancy probe (the ``evict_lru_pressure`` signal)."""
        return self.stats()["entries"]

    # -- crash-restart support ------------------------------------------
    def snapshot_entries(
        self, start: int = 0, max_items: int | None = None
    ) -> tuple[int, list[bytes], list[int], list[int], list[int]]:
        """One OP_SNAPSHOT page (defaults to the slot-capacity page size)."""
        if max_items is None:
            max_items = self._max_snapshot
        return decode_snapshot_resp(
            self._call(encode_snapshot(start, max_items))
        )

    def snapshot_all(self) -> list[tuple[bytes, int, int, int]]:
        """Page the WHOLE index in LRU order: [(key, id, epoch, n_tokens)].
        Rebuild-verification helper — call against a quiesced shard."""
        out: list[tuple[bytes, int, int, int]] = []
        start = 0
        while True:
            total, keys, ids, eps, ntk = self.snapshot_entries(start)
            out.extend(zip(keys, ids, eps, ntk))
            start += len(keys)
            if start >= total or not keys:
                return out

    def restore_entries(self, keys, block_ids, epochs, n_tokens) -> int:
        """Push entries into the (freshly restarted) shard: OP_RESTORE,
        chunked at 36 B/entry (same geometry as snapshot pages)."""
        done = 0
        M = self._max_snapshot
        for off in range(0, len(keys), M):
            end = off + M
            done += decode_restore_resp(
                self._call(
                    encode_restore(
                        keys[off:end], block_ids[off:end],
                        epochs[off:end], n_tokens[off:end],
                    )
                )
            )
        return done

    def seed_stats(self, hits: int, misses: int) -> None:
        """Seed the shard's hit/miss counters (warm-restore path)."""
        self._call(encode_seed_stats(hits, misses))

    def call_batch(self, requests: list[bytes]) -> list[bytes]:
        """Ship k already-encoded ops in ONE ring round-trip."""
        return decode_batch_resp(self._call(encode_batch(requests)))


# ---------------------------------------------------------------------------
# sharded client: one ring per index shard, parallel outstanding RPCs
# ---------------------------------------------------------------------------
class ShardedRpcIndexClient:
    """``GlobalIndex`` API over S metadata rings (one ``GlobalIndex``
    shard behind each), keys partitioned by digest hash.

    The partition/merge semantics are identical to the in-process
    ``repro.core.index.ShardedIndex`` (same ``shard_of_key`` routing, same
    longest-all-hit-prefix merge) — the only difference is the transport:
    every fan-out POSTS the per-shard requests to all rings BEFORE
    collecting any reply, so one op keeps S RPCs outstanding in parallel
    instead of visiting the shards one round-trip at a time. Chains longer
    than a slot run in chunk rounds, still posting each round to every
    still-active shard first.

    S=1 degenerates to a plain ``RpcIndexClient`` over the single ring
    (bit-identical message sequence to the unsharded ``index_rpc`` mode).
    """

    def __init__(self, rpcs, block_tokens: int, max_payload: int | None = None,
                 hasher: PrefixHasher | None = None, on_freed=None,
                 journals=None, retry: RetryPolicy | None = None,
                 degrade: bool = False, on_evict=None):
        if not rpcs:
            raise ValueError("need at least one rpc transport")
        self.rpcs = list(rpcs)
        self.n_shards = len(self.rpcs)
        self.block_tokens = block_tokens
        self.hasher = hasher if hasher is not None else PrefixHasher(block_tokens)
        self.retry = retry
        # degraded mode: a shard that stays unreachable through its
        # retries fails SOFT on the match path — its positions become
        # holes, the merged prefix cuts there, and serving recomputes
        # instead of erroring (worse TTFT, no failure)
        self.degrade = degrade
        self.degraded_ops = 0
        if journals is None:
            journals = [None] * self.n_shards
        self.journals = list(journals)
        # per-shard proxies share the hasher (hash once per front); they
        # also carry the per-op slot-capacity maths, the cross-process
        # pool-reclaim hook (see RpcIndexClient.on_freed), that shard's
        # publish journal, and the retry policy
        self.shards = [
            RpcIndexClient(
                r, block_tokens, max_payload, hasher=self.hasher,
                on_freed=on_freed, journal=self.journals[i], retry=retry,
                on_evict=on_evict,
            )
            for i, r in enumerate(self.rpcs)
        ]
        # rings may differ in slot size: fan-out chunks use the tightest
        self._max_match = min(s._max_match for s in self.shards)
        self._max_publish = min(s._max_publish for s in self.shards)
        self._max_lookup = min(s._max_lookup for s in self.shards)
        self._max_evict = min(s._max_evict for s in self.shards)
        self._max_owners = min(s._max_owners for s in self.shards)
        self._max_remap = min(s._max_remap for s in self.shards)

    # -- transport: post-all, then collect-all ---------------------------
    def _call_shard(
        self, s: int, msg: bytes, timeout: float, idempotent: bool
    ) -> bytes:
        """Single-shard call with the bounded-retry semantics of
        ``RpcIndexClient._call`` (see there for the idempotency rules)."""
        pol = self.retry
        attempt = 0
        while True:
            try:
                return self.rpcs[s].call(msg, timeout)
            except ServiceDiedError:
                attempt += 1
                if pol is None or attempt > pol.max_retries:
                    raise
            except TimeoutError:
                if pol is None or not idempotent:
                    raise
                attempt += 1
                if attempt > pol.max_retries:
                    raise
            st = getattr(self.rpcs[s], "stats", None)
            if st is not None:
                st.retries += 1
            time.sleep(pol.backoff(attempt))

    def _fanout(
        self, msgs: dict[int, bytes], timeout: float = 5.0,
        idempotent: bool = True, failed: set[int] | None = None,
    ) -> dict[int, bytes]:
        """One parallel round: post every shard's request, then collect.

        A failed post stops posting (nothing else enters the rings); every
        slot that WAS posted is still collected (or quarantined by its own
        collect).  Shards that failed transiently (service died/restarted,
        idempotent timeout) — or never got posted because an earlier
        shard's post raised — then get a bounded-backoff second chance via
        ``_call_shard``.  A shard still missing after that either raises
        the first recorded failure, or (``failed`` not None — degraded
        mode) is recorded in ``failed`` and simply omitted from the
        result, the caller treating its positions as holes."""
        slots: dict[int, int] = {}
        errs: dict[int, BaseException] = {}
        for s, m in msgs.items():
            try:
                slots[s] = self.rpcs[s].post(m)
            except BaseException as e:  # noqa: BLE001
                errs[s] = e
                break
        out: dict[int, bytes] = {}
        for s, slot in slots.items():
            try:
                out[s] = self.rpcs[s].collect(slot, timeout)
            except BaseException as e:  # noqa: BLE001
                errs[s] = e
        for s in msgs:
            if s in out:
                continue
            e = errs.get(s)
            if e is not None and not isinstance(
                e, (ServiceDiedError, TimeoutError)
            ):
                continue  # handler/protocol error: never retried
            if isinstance(e, TimeoutError) and not idempotent:
                continue  # may have applied server-side: surface it
            if self.retry is None and failed is None:
                continue  # no second chance configured
            try:
                out[s] = self._call_shard(s, msgs[s], timeout, idempotent)
                errs.pop(s, None)
            except BaseException as e2:  # noqa: BLE001
                errs[s] = e2
        missing = [s for s in msgs if s not in out]
        if missing:
            # a hard error (handler/protocol failure) is a caller bug and
            # raises even in degraded mode — only transient transport
            # failures degrade to holes
            degradable = failed is not None and all(
                isinstance(errs[s], (ServiceDiedError, TimeoutError))
                for s in missing
                if s in errs
            )
            if not degradable:
                for s in msgs:
                    if s in errs:
                        raise errs[s]
                raise RuntimeError("fan-out incomplete without an error")
            for s in missing:
                failed.add(s)
                st = getattr(self.rpcs[s], "stats", None)
                if st is not None:
                    st.degraded_ops += 1
            self.degraded_ops += len(missing)
        return out

    # -- hashing is local ------------------------------------------------
    def keys_for(self, tokens: list[int]) -> tuple[bytes, ...]:
        return self.hasher.keys_for(tokens)

    # -- chain ops: partition, parallel rounds, merge by position --------
    def match_prefix(self, tokens: list[int]) -> list[tuple[bytes, int, int]]:
        return self.match_prefix_keys(self.keys_for(tokens))

    def match_prefix_keys(self, keys) -> list[tuple[bytes, int, int]]:
        if self.n_shards == 1:
            if not self.degrade:
                return self.shards[0].match_prefix_keys(keys)
            try:
                return self.shards[0].match_prefix_keys(keys)
            except (ServiceDiedError, TimeoutError):
                # the single shard is down: every position is a hole —
                # serving recomputes the whole prefix instead of erroring
                self.degraded_ops += 1
                st = getattr(self.rpcs[0], "stats", None)
                if st is not None:
                    st.degraded_ops += 1
                return []
        key_lists, pos_lists = partition_keys(keys, self.n_shards)
        found: list[tuple[int, int] | None] = [None] * len(keys)
        offs = [0] * self.n_shards
        active = {s for s in range(self.n_shards) if key_lists[s]}
        failed: set[int] | None = set() if self.degrade else None
        M = self._max_match
        while active:
            msgs = {
                s: encode_match(key_lists[s][offs[s] : offs[s] + M])
                for s in active
            }
            resp = self._fanout(msgs, failed=failed)
            for s in list(active):
                if s not in resp:
                    # degraded: shard down — its unanswered positions
                    # stay None and the merge cuts at the first hole
                    active.discard(s)
                    continue
                ids, eps = decode_match_resp(resp[s])
                kl, pl = key_lists[s], pos_lists[s]
                o = offs[s]
                for j, (b, e) in enumerate(zip(ids.tolist(), eps.tolist())):
                    found[pl[o + j]] = (b, e)
                chunk = min(M, len(kl) - o)
                offs[s] = o + chunk
                if len(ids) < chunk or offs[s] >= len(kl):
                    active.discard(s)  # shard prefix ended (or exhausted)
        out: list[tuple[bytes, int, int]] = []
        for i, k in enumerate(keys):
            f = found[i]
            if f is None:
                break  # first hole ends the global all-hit prefix
            out.append((k, f[0], f[1]))
        return out

    def publish_many(self, keys, block_ids, epochs, n_tokens: int) -> None:
        if self.n_shards == 1:
            return self.shards[0].publish_many(keys, block_ids, epochs, n_tokens)
        key_lists, pos_lists = partition_keys(keys, self.n_shards)
        parts = {
            s: (
                key_lists[s],
                [block_ids[i] for i in pos_lists[s]],
                [epochs[i] for i in pos_lists[s]],
            )
            for s in range(self.n_shards)
            if key_lists[s]
        }
        offs = dict.fromkeys(parts, 0)
        M = self._max_publish
        while parts:
            msgs = {}
            for s, (kl, bl, el) in parts.items():
                o = offs[s]
                msgs[s] = encode_publish(
                    kl[o : o + M], bl[o : o + M], el[o : o + M], n_tokens
                )
            self._fanout(msgs)
            for s in list(parts):
                kl, bl, el = parts[s]
                o = offs[s]
                if self.journals[s] is not None:
                    self.journals[s].append_publish(
                        kl[o : o + M], bl[o : o + M], el[o : o + M], n_tokens
                    )
                offs[s] += M
                if offs[s] >= len(kl):
                    del parts[s], offs[s]

    def lookup_many(self, keys) -> list[IndexEntry | None]:
        if self.n_shards == 1:
            return self.shards[0].lookup_many(keys)
        key_lists, pos_lists = partition_keys(keys, self.n_shards)
        out: list[IndexEntry | None] = [None] * len(keys)
        offs = [0] * self.n_shards
        active = {s for s in range(self.n_shards) if key_lists[s]}
        M = self._max_lookup
        while active:
            msgs = {
                s: encode_lookup(key_lists[s][offs[s] : offs[s] + M])
                for s in active
            }
            resp = self._fanout(msgs)
            for s in list(active):
                ids, eps, ntk = decode_lookup_resp(resp[s])
                pl = pos_lists[s]
                o = offs[s]
                for j, (b, e, t) in enumerate(
                    zip(ids.tolist(), eps.tolist(), ntk.tolist())
                ):
                    if b >= 0:
                        out[pl[o + j]] = IndexEntry(b, e, t, 0.0)
                offs[s] = o + len(ids)
                if offs[s] >= len(key_lists[s]):
                    active.discard(s)
        return out

    def lookup(self, key: bytes) -> IndexEntry | None:
        return self.shards[shard_of_key(key, self.n_shards)].lookup(key)

    def filter_unpublished(self, keys) -> list[int]:
        if self.n_shards == 1:
            return self.shards[0].filter_unpublished(keys)
        key_lists, pos_lists = partition_keys(keys, self.n_shards)
        out: list[int] = []
        offs = [0] * self.n_shards
        active = {s for s in range(self.n_shards) if key_lists[s]}
        M = self._max_lookup
        while active:
            msgs = {
                s: encode_filter(key_lists[s][offs[s] : offs[s] + M])
                for s in active
            }
            resp = self._fanout(msgs)
            for s in list(active):
                kl, pl = key_lists[s], pos_lists[s]
                o = offs[s]
                out.extend(pl[o + p] for p in decode_filter_resp(resp[s]))
                offs[s] = o + min(M, len(kl) - o)
                if offs[s] >= len(kl):
                    active.discard(s)
        out.sort()
        return out

    # -- eviction + migration control plane ------------------------------
    def evict_lru(self, n: int) -> list[int]:
        """Occupancy-weighted eviction — the EXACT policy function the
        in-process ``ShardedIndex`` runs (``evict_lru_pressure``), with
        each per-shard probe/evict going over that shard's ring.  Shared
        code is what keeps the two planes in lockstep: the differential
        harness asserts identical freed lists transport-for-transport.
        Eviction is pressure-relief (not request-path) traffic, so the
        sequential rounds are fine."""
        if self.n_shards == 1:
            return self.shards[0].evict_lru(n)
        return evict_lru_pressure(self.shards, n)

    def owners_of(
        self, block_ids
    ) -> tuple[list[bytes], list[int], list[int]]:
        if self.n_shards == 1:
            return self.shards[0].owners_of(block_ids)
        owner: dict[int, tuple[bytes, int]] = {}
        M = self._max_owners
        for off in range(0, len(block_ids), M):
            chunk = block_ids[off : off + M]
            resp = self._fanout(
                {s: encode_owners(chunk) for s in range(self.n_shards)}
            )
            for r in resp.values():
                k, b, e = decode_owners_resp(r)
                for kk, bb, ee in zip(k, b, e):
                    owner[bb] = (kk, ee)
        keys_o: list[bytes] = []
        ids_o: list[int] = []
        eps_o: list[int] = []
        for b in block_ids:
            f = owner.get(int(b))
            if f is not None:
                keys_o.append(f[0])
                ids_o.append(int(b))
                eps_o.append(f[1])
        return keys_o, ids_o, eps_o

    def remap_many(
        self, keys, old_ids, old_epochs, new_ids, new_epochs
    ) -> list[bool]:
        if self.n_shards == 1:
            return self.shards[0].remap_many(
                keys, old_ids, old_epochs, new_ids, new_epochs
            )
        key_lists, pos_lists = partition_keys(keys, self.n_shards)
        ok = [False] * len(keys)
        offs = [0] * self.n_shards
        active = {s for s in range(self.n_shards) if key_lists[s]}
        M = self._max_remap
        while active:
            msgs = {}
            for s in active:
                kl, pl = key_lists[s], pos_lists[s]
                o = offs[s]
                sel = pl[o : o + M]
                msgs[s] = encode_remap(
                    kl[o : o + M],
                    [old_ids[i] for i in sel],
                    [old_epochs[i] for i in sel],
                    [new_ids[i] for i in sel],
                    [new_epochs[i] for i in sel],
                )
            resp = self._fanout(msgs, idempotent=False)
            for s in list(active):
                kl, pl = key_lists[s], pos_lists[s]
                o = offs[s]
                sub = decode_remap_resp(resp[s])
                for v, i in zip(sub, pl[o : o + M]):
                    ok[i] = v
                if self.journals[s] is not None and any(sub):
                    done = [i for v, i in zip(sub, pl[o : o + M]) if v]
                    self.journals[s].append_remap(
                        [keys[i] for i in done],
                        [new_ids[i] for i in done],
                        [new_epochs[i] for i in done],
                    )
                offs[s] = o + min(M, len(kl) - o)
                if offs[s] >= len(kl):
                    active.discard(s)
        return ok

    def evict_blocks(self, block_ids) -> list[int]:
        if self.n_shards == 1:
            return self.shards[0].evict_blocks(block_ids)
        # sequential per shard (each self.shards[s] chunks its own wire
        # round-trips); this op is background-migrator traffic, so the
        # lost parallelism is not on the request path
        return evict_blocks_sharded(self.shards, block_ids)

    def stats(self) -> dict:
        """Aggregate per-shard counters — same shape as
        ``ShardedIndex.stats`` (``shards`` occupancy list for S>1)."""
        if self.n_shards == 1:
            return self.shards[0].stats()
        per = [s.stats() for s in self.shards]
        hits = sum(p["hits"] for p in per)
        misses = sum(p["misses"] for p in per)
        return {
            "entries": sum(p["entries"] for p in per),
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / max(1, hits + misses),
            "shards": [p["entries"] for p in per],
        }

    def service_stats(self) -> dict:
        """Aggregate service-side timers (per-shard breakdown included)."""
        per = [s.service_stats() for s in self.shards]
        return {
            "ops_served": sum(p["ops_served"] for p in per),
            "busy_ns": sum(p["busy_ns"] for p in per),
            "shards": per,
        }


# ---------------------------------------------------------------------------
# pool allocator over the wire (the zero-copy data plane's control half)
# ---------------------------------------------------------------------------
# With the block payloads in one shared segment (``BelugaPool.share_data``
# / ``repro.core.shmpool``), engine worker processes load/store KV bytes
# directly — but the allocator's free stacks are ordinary Python state
# with exactly one owner, the pool-owning parent.  These four ops carry
# allocate/retain/release/free-count over a ring:
#
#     POOL_ALLOC   := n:u32            -> n:u32  block_ids[n*i64]
#     POOL_RETAIN  := n:u32  ids[n*i64] -> n:u32
#     POOL_RELEASE := n:u32  ids[n*i64] -> n:u32
#     POOL_FREE    := n:u32 (ignored)  -> free:u64  alloc_count:u64
#
# An allocator failure (``OutOfPoolMemory``) travels in-band as the ring's
# RESP_ERROR frame and is re-raised type-faithfully client-side, so the
# manager's evict-and-retry path works unchanged across the boundary.

_POOL_FREE_RESP = struct.Struct("<QQ")


def encode_pool_alloc(n: int) -> bytes:
    return _HDR.pack(OP_POOL_ALLOC, n)


# tiered extensions of the same plane:
#     POOL_ALLOC_KEYS := n:u32  keys[n*16]          -> n:u32  ids[n*i64]
#     POOL_TOUCH      := n:u32  now:f64  ids[n*i64] -> k:u32  counts[k*i32]
# (keys feed the ghost-LRU admission filter; TOUCH returns the per-tier
# block counts of the touched set so the worker can price the fetch)
_POOL_TOUCH_HDR = struct.Struct("<BId")  # op, count, virtual now


def encode_pool_alloc_keys(keys) -> bytes:
    return _HDR.pack(OP_POOL_ALLOC_KEYS, len(keys)) + _join_keys(keys)


def encode_pool_touch(block_ids, now: float) -> bytes:
    return _POOL_TOUCH_HDR.pack(
        OP_POOL_TOUCH, len(block_ids), now
    ) + np.asarray(block_ids, np.int64).tobytes()


def decode_pool_touch_resp(buf: bytes) -> tuple[int, ...]:
    _need(buf, 4)
    (k,) = _U32.unpack_from(buf)
    counts, _ = _split_i32(buf, 4, k)
    return tuple(int(c) for c in counts)


def encode_pool_retain(block_ids) -> bytes:
    return _HDR.pack(OP_POOL_RETAIN, len(block_ids)) + np.asarray(
        block_ids, np.int64
    ).tobytes()


def encode_pool_release(block_ids) -> bytes:
    return _HDR.pack(OP_POOL_RELEASE, len(block_ids)) + np.asarray(
        block_ids, np.int64
    ).tobytes()


def encode_pool_free() -> bytes:
    return _HDR.pack(OP_POOL_FREE, 0)


def decode_pool_alloc_resp(buf: bytes) -> list[int]:
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    ids, _ = _split_i64(buf, 4, n)
    return ids.tolist()


def decode_pool_free_resp(buf: bytes) -> tuple[int, int]:
    _need(buf, _POOL_FREE_RESP.size)
    return _POOL_FREE_RESP.unpack_from(buf)


# journal proxy frames (shard:u32 right after the op header):
#     JRNL_PUBLISH := op:u8 n:u32 shard:u32 n_tokens:i32
#                     keys[n*16] ids[n*i64] epochs[n*i64]       -> n:u32
#     JRNL_RETRACT := op:u8 n:u32 shard:u32 ids[n*i64]          -> n:u32
#     JRNL_REMAP   := op:u8 n:u32 shard:u32
#                     keys[n*16] ids[n*i64] epochs[n*i64]       -> n:u32
_JRNL_PUB_HDR = struct.Struct("<BIIi")  # op, count, shard, n_tokens
_JRNL_HDR = struct.Struct("<BII")  # op, count, shard


def encode_jrnl_publish(shard, keys, block_ids, epochs, n_tokens) -> bytes:
    n = len(keys)
    if not (n == len(block_ids) == len(epochs)):
        raise WireError("journal publish arrays disagree on length")
    return (
        _JRNL_PUB_HDR.pack(OP_JRNL_PUBLISH, n, shard, n_tokens)
        + _join_keys(keys)
        + np.asarray(block_ids, np.int64).tobytes()
        + np.asarray(epochs, np.int64).tobytes()
    )


def encode_jrnl_retract(shard, block_ids) -> bytes:
    return _JRNL_HDR.pack(
        OP_JRNL_RETRACT, len(block_ids), shard
    ) + np.asarray(block_ids, np.int64).tobytes()


def encode_jrnl_remap(shard, keys, new_ids, new_epochs) -> bytes:
    n = len(keys)
    if not (n == len(new_ids) == len(new_epochs)):
        raise WireError("journal remap arrays disagree on length")
    return (
        _JRNL_HDR.pack(OP_JRNL_REMAP, n, shard)
        + _join_keys(keys)
        + np.asarray(new_ids, np.int64).tobytes()
        + np.asarray(new_epochs, np.int64).tobytes()
    )


def pool_reply_bound(buf: bytes) -> int:
    """Worst-case reply size WITHOUT executing (see ``reply_bound``):
    an ALLOC whose id list could not ship must fail before any blocks
    leave the free stacks."""
    _need(buf, _HDR.size)
    op, n = _HDR.unpack_from(buf)
    if op == OP_POOL_ALLOC:
        return 4 + 8 * n
    if op == OP_POOL_ALLOC_KEYS:
        _need(buf, _HDR.size + KEY_BYTES * n)
        return 4 + 8 * n
    if op == OP_POOL_TOUCH:
        _need(buf, _POOL_TOUCH_HDR.size + 8 * n)
        return 4 + 4 * 16  # k:u32 + per-tier i32 counts (chain cap 16)
    if op in (OP_POOL_RETAIN, OP_POOL_RELEASE):
        _need(buf, _HDR.size + 8 * n)
        return 4
    if op == OP_POOL_FREE:
        return _POOL_FREE_RESP.size
    if op == OP_JRNL_PUBLISH:
        _need(buf, _JRNL_PUB_HDR.size + (KEY_BYTES + 16) * n)
        return 4
    if op == OP_JRNL_RETRACT:
        _need(buf, _JRNL_HDR.size + 8 * n)
        return 4
    if op == OP_JRNL_REMAP:
        _need(buf, _JRNL_HDR.size + (KEY_BYTES + 16) * n)
        return 4
    raise WireError(f"unknown pool op {op}")


def handle_journal_request(buf: bytes, journals, ledger=None, worker=None) -> bytes:
    """Dispatch one journal-proxy op against the parent-held journals.

    ``ShardJournal._append`` is thread-locked, so this handler (running
    on the allocator service thread) appends safely alongside the parent
    main thread's own index clients.  A JRNL_PUBLISH additionally clears
    the posting worker's lease on the published blocks: the alloc-ref's
    ownership transfers to the index (eviction releases it via
    ``on_freed``), so those blocks must NOT be reclaimed if the worker
    later dies."""
    _need(buf, _HDR.size)
    op, n = _HDR.unpack_from(buf)
    if op == OP_JRNL_PUBLISH:
        _need(buf, _JRNL_PUB_HDR.size)
        _, n, shard, n_tokens = _JRNL_PUB_HDR.unpack_from(buf)
        if shard >= len(journals):
            raise WireError(f"journal shard {shard} out of range")
        keys, off = _split_keys(buf, _JRNL_PUB_HDR.size, n)
        ids, off = _split_i64(buf, off, n)
        eps, _ = _split_i64(buf, off, n)
        journals[shard].append_publish(keys, ids.tolist(), eps.tolist(), n_tokens)
        if ledger is not None and worker is not None:
            # the lease mirror is shared with the supervisor's reconcile
            # (parent main thread): every mutation goes under the mutex
            with ledger.mutex:
                ledger.on_publish(worker, ids.tolist())
        return _U32.pack(n)
    if op in (OP_JRNL_RETRACT, OP_JRNL_REMAP):
        _need(buf, _JRNL_HDR.size)
        _, n, shard = _JRNL_HDR.unpack_from(buf)
        if shard >= len(journals):
            raise WireError(f"journal shard {shard} out of range")
        if op == OP_JRNL_RETRACT:
            ids, _ = _split_i64(buf, _JRNL_HDR.size, n)
            journals[shard].append_retract(ids.tolist())
        else:
            keys, off = _split_keys(buf, _JRNL_HDR.size, n)
            ids, off = _split_i64(buf, off, n)
            eps, _ = _split_i64(buf, off, n)
            journals[shard].append_remap(keys, ids.tolist(), eps.tolist())
        return _U32.pack(n)
    raise WireError(f"unknown journal op {op}")


def handle_pool_request(pool: "BelugaPool", buf: bytes) -> bytes:  # noqa: F821
    """Dispatch one pool-allocator op against the OWNING pool."""
    _need(buf, _HDR.size)
    op, n = _HDR.unpack_from(buf)
    if op == OP_POOL_ALLOC:
        ids = pool.allocate(n)  # OutOfPoolMemory -> in-band RESP_ERROR
        return _U32.pack(len(ids)) + np.asarray(ids, np.int64).tobytes()
    if op == OP_POOL_ALLOC_KEYS:
        keys, _ = _split_keys(buf, _HDR.size, n)
        # tiered parent: keys route through the ghost-LRU admission
        # filter exactly as an in-process writeback allocation would
        ids = pool.allocate(n, keys=keys)
        return _U32.pack(len(ids)) + np.asarray(ids, np.int64).tobytes()
    if op == OP_POOL_TOUCH:
        _need(buf, _POOL_TOUCH_HDR.size)
        _, n, now = _POOL_TOUCH_HDR.unpack_from(buf)
        ids, _ = _split_i64(buf, _POOL_TOUCH_HDR.size, n)
        _check_block_ids(pool_index_shim(pool), ids, "POOL_TOUCH")
        counts = pool.touch_demand(ids.tolist(), now)
        return _U32.pack(len(counts)) + np.asarray(
            counts, np.int32
        ).tobytes()
    if op in (OP_POOL_RETAIN, OP_POOL_RELEASE):
        ids, _ = _split_i64(buf, _HDR.size, n)
        what = "POOL_RETAIN" if op == OP_POOL_RETAIN else "POOL_RELEASE"
        _check_block_ids(pool_index_shim(pool), ids, what)
        if op == OP_POOL_RETAIN:
            pool.retain(ids.tolist())
        else:
            pool.release(ids.tolist())
        return _U32.pack(n)
    if op == OP_POOL_FREE:
        return _POOL_FREE_RESP.pack(pool.free_blocks(), pool.alloc_count)
    raise WireError(f"unknown pool op {op}")


class pool_index_shim:
    """Adapter so ``_check_block_ids`` (written against an index) can
    range-check untrusted ids against a bare pool."""

    def __init__(self, pool):
        self.pool = pool


def make_pool_handler(pool, max_reply: int | None = None, *, ledger=None,
                      slot_owner=None, journals=None):
    """Handler for the parent-side pool-allocator ring service.

    Plain mode (all keyword hooks None) is the PR-7 hot path, unchanged.
    With ``ledger`` (a ``repro.core.shmpool.WorkerLeaseLedger``) the
    handler declares ``wants_slot`` so ``drain_ready`` also passes the
    posting slot: ``slot_owner(slot)`` maps it to the worker index (the
    pool ring is partitioned per worker) and every ALLOC/RETAIN/RELEASE
    is mirrored into the ledger — the raw material of lease
    reconciliation when that worker dies.  ``journals`` additionally
    enables the journal-proxy ops (selfheal mode), serving worker-side
    journal appends against the parent-held ``ShardJournal``s.  Ledger
    mode serializes pool mutation against ``ledger.mutex`` so the
    supervisor's reconcile path (parent main thread) cannot race the
    allocator thread on the pool's free stacks."""
    if ledger is None and journals is None:

        def handler(payload: bytes) -> bytes:
            if max_reply is not None and pool_reply_bound(payload) > max_reply:
                raise WireError(f"reply would exceed {max_reply} B slot")
            return handle_pool_request(pool, payload)

        return handler

    jrnls = list(journals) if journals is not None else []

    def handler(payload: bytes, slot: int) -> bytes:  # noqa: F811
        if max_reply is not None and pool_reply_bound(payload) > max_reply:
            raise WireError(f"reply would exceed {max_reply} B slot")
        op, n = _HDR.unpack_from(payload)
        worker = slot_owner(slot) if slot_owner is not None else None
        if op in (OP_JRNL_PUBLISH, OP_JRNL_RETRACT, OP_JRNL_REMAP):
            return handle_journal_request(payload, jrnls, ledger, worker)
        if ledger is None or worker is None:
            return handle_pool_request(pool, payload)
        with ledger.mutex:
            reply = handle_pool_request(pool, payload)
            if op in (OP_POOL_ALLOC, OP_POOL_ALLOC_KEYS):
                ledger.on_alloc(worker, decode_pool_alloc_resp(reply), pool)
            elif op == OP_POOL_RETAIN:
                ids, _ = _split_i64(payload, _HDR.size, n)
                ledger.on_retain(worker, ids.tolist(), pool)
            elif op == OP_POOL_RELEASE:
                ids, _ = _split_i64(payload, _HDR.size, n)
                ledger.on_release(worker, ids.tolist())
        return reply

    handler.wants_slot = True
    return handler


class RemoteJournal:
    """Worker-side proxy for a parent-held ``ShardJournal``.

    Exposes the exact append surface the index clients call after a
    confirmed reply (``append_publish`` / ``append_retract`` /
    ``append_remap``), but ships each append over the worker's pool
    allocator ring tagged with the target shard — the journal segments
    themselves have exactly one writer side, the parent.  Appends are
    idempotent under ``live_entries`` folding (a duplicated publish or
    retract folds to the same live state), so transient transport
    failures retry under the same policy as the data ops."""

    def __init__(self, rpc, shard: int, max_payload: int | None = None,
                 retry: RetryPolicy | None = None):
        self.rpc = rpc
        self.shard = shard
        self.retry = retry
        if max_payload is None:
            max_payload = getattr(
                getattr(rpc, "ring", None), "payload_bytes", 1 << 20
            )
        self._max_pub = max(1, (max_payload - 24) // (KEY_BYTES + 16))
        self._max_ids = max(1, (max_payload - 24) // 8)

    def _call(self, payload: bytes) -> bytes:
        pol = self.retry
        if pol is None:
            return self.rpc.call(payload)
        attempt = 0
        while True:
            try:
                return self.rpc.call(payload)
            except (ServiceDiedError, TimeoutError):
                attempt += 1
                if attempt > pol.max_retries:
                    raise
            stats = getattr(self.rpc, "stats", None)
            if stats is not None:
                stats.retries += 1
            time.sleep(pol.backoff(attempt))

    def append_publish(self, keys, block_ids, epochs, n_tokens: int) -> None:
        M = self._max_pub
        for off in range(0, len(keys), M):
            end = off + M
            self._call(encode_jrnl_publish(
                self.shard, keys[off:end], block_ids[off:end],
                epochs[off:end], n_tokens,
            ))

    def append_retract(self, block_ids) -> None:
        M = self._max_ids
        for off in range(0, len(block_ids), M):
            self._call(encode_jrnl_retract(self.shard, block_ids[off : off + M]))

    def append_remap(self, keys, new_ids, new_epochs) -> None:
        M = self._max_pub
        for off in range(0, len(keys), M):
            end = off + M
            self._call(encode_jrnl_remap(
                self.shard, keys[off:end], new_ids[off:end], new_epochs[off:end]
            ))


class PoolRpcClient:
    """Worker-side proxy for the pool allocator (one ring round-trip per
    op, chunked at slot capacity).

    Allocation is ATOMIC across chunks: if a later chunk hits
    ``OutOfPoolMemory``, every block the earlier chunks handed out is
    released before the error re-raises — the caller never leaks a
    partial allocation.  The error itself is recognized in the in-band
    ``RpcError`` frame ("OutOfPoolMemory: ...") and re-raised with its
    real type so ``KVCacheManager``'s evict-and-retry path is oblivious
    to the process boundary.
    """

    def __init__(self, rpc, n_blocks: int, max_payload: int | None = None):
        self.rpc = rpc
        self.n_blocks = n_blocks
        if max_payload is None:
            max_payload = getattr(
                getattr(rpc, "ring", None), "payload_bytes", 1 << 20
            )
        self._max_ids = max(1, (max_payload - 16) // 8)
        # keyed allocation ships 16 B per key in the request
        self._max_keyed = max(1, (max_payload - 16) // KEY_BYTES)

    def _call(self, payload: bytes) -> bytes:
        try:
            return self.rpc.call(payload)
        except ServiceDiedError:
            raise
        except RpcError as e:
            msg = str(e)
            if msg.startswith("OutOfPoolMemory"):
                _, _, detail = msg.partition(": ")
                raise OutOfPoolMemory(detail or msg) from e
            raise

    def allocate(self, n: int, keys=None) -> list[int]:
        out: list[int] = []
        M = self._max_ids if keys is None else self._max_keyed
        try:
            while len(out) < n:
                k = min(n - len(out), M)
                if keys is None:
                    msg = encode_pool_alloc(k)
                else:  # tiered parent: ghost-LRU admission sees the keys
                    msg = encode_pool_alloc_keys(
                        keys[len(out) : len(out) + k]
                    )
                out.extend(decode_pool_alloc_resp(self._call(msg)))
        except OutOfPoolMemory:
            if out:
                self.release(out)  # atomic: no partial allocation leaks
            raise
        return out

    def touch_demand(self, block_ids, now: float) -> tuple[int, ...]:
        """Ship the fetch-path demand signal to the tiered pool owner;
        returns the summed per-tier counts of the touched blocks."""
        totals: list[int] = []
        M = self._max_ids
        for off in range(0, len(block_ids), M):
            counts = decode_pool_touch_resp(
                self._call(encode_pool_touch(block_ids[off : off + M], now))
            )
            if len(counts) > len(totals):
                totals.extend([0] * (len(counts) - len(totals)))
            for i, c in enumerate(counts):
                totals[i] += c
        return tuple(totals) if totals else (0, 0)

    def retain(self, block_ids) -> None:
        for off in range(0, len(block_ids), self._max_ids):
            self._call(encode_pool_retain(block_ids[off : off + self._max_ids]))

    def release(self, block_ids) -> None:
        for off in range(0, len(block_ids), self._max_ids):
            self._call(encode_pool_release(block_ids[off : off + self._max_ids]))

    def free_blocks(self) -> int:
        return decode_pool_free_resp(self._call(encode_pool_free()))[0]

    def alloc_count(self) -> int:
        return decode_pool_free_resp(self._call(encode_pool_free()))[1]

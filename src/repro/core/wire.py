"""Binary wire protocol for the metadata plane (paper §6, Exp #11).

The centralized ``GlobalIndex`` is reached over the CXL-RPC shared-memory
ring (``repro.core.rpc``); this module defines what actually travels in a
slot: a compact variable-length binary codec for the index ops every
request hits, so ONE ring round-trip carries a whole request's key chain
instead of one RPC per key.

Message layout (little-endian, keys are fixed 16-byte blake2b digests):

    request  := op:u8  body
    MATCH    := n:u32  keys[n*16]
    PUBLISH  := n:u32  n_tokens:i32  keys[n*16]  block_ids[n*i64]  epochs[n*i64]
    LOOKUP   := n:u32  keys[n*16]
    FILTER   := n:u32  keys[n*16]          (writeback: lookup+validate fused)
    EVICT    := n:u32                      (evict up to n LRU blocks)
    BATCH    := k:u32  k * (len:u32 request)

    responses:
    MATCH    -> n_ok:u32  block_ids[n_ok*i64]  epochs[n_ok*i64]
    PUBLISH  -> n:u32
    LOOKUP   -> n:u32  block_ids[n*i64]  epochs[n*i64]  n_tokens[n*i32]
                (block_id == -1 marks a missing key)
    FILTER   -> m:u32  positions[m*u32]
    EVICT    -> m:u32  freed_block_ids[m*i64]
    BATCH    -> k:u32  k * (len:u32 response)

``handle_request`` is the server-side dispatcher (wrap it with
``make_index_handler`` and hand it to ``CxlRpcServer``); ``RpcIndexClient``
is the engine-side proxy exposing the same API surface the
``KVCacheManager`` uses in-process (``keys_for`` hashes locally — it is
pure computation — and only the 16-byte keys cross the ring). Chains
longer than one slot are transparently split at the op level.
"""

from __future__ import annotations

import struct

import numpy as np

from repro.core.index import IndexEntry, PrefixHasher

KEY_BYTES = 16

OP_MATCH = 1
OP_PUBLISH = 2
OP_LOOKUP = 3
OP_FILTER = 4
OP_EVICT = 5
OP_BATCH = 6

_HDR = struct.Struct("<BI")  # op, count
_U32 = struct.Struct("<I")
_PUB_HDR = struct.Struct("<BIi")  # op, count, n_tokens


class WireError(ValueError):
    pass


# ---------------------------------------------------------------------------
# encode (client side)
# ---------------------------------------------------------------------------
def _join_keys(keys) -> bytes:
    blob = b"".join(keys)
    if len(blob) != KEY_BYTES * len(keys):
        raise WireError("keys must be 16-byte digests")
    return blob


def encode_match(keys) -> bytes:
    return _HDR.pack(OP_MATCH, len(keys)) + _join_keys(keys)


def encode_publish(keys, block_ids, epochs, n_tokens: int) -> bytes:
    n = len(keys)
    if not (n == len(block_ids) == len(epochs)):
        raise WireError("publish arrays disagree on length")
    return (
        _PUB_HDR.pack(OP_PUBLISH, n, n_tokens)
        + _join_keys(keys)
        + np.asarray(block_ids, np.int64).tobytes()
        + np.asarray(epochs, np.int64).tobytes()
    )


def encode_lookup(keys) -> bytes:
    return _HDR.pack(OP_LOOKUP, len(keys)) + _join_keys(keys)


def encode_filter(keys) -> bytes:
    return _HDR.pack(OP_FILTER, len(keys)) + _join_keys(keys)


def encode_evict(n: int) -> bytes:
    return _HDR.pack(OP_EVICT, n)


def encode_batch(requests: list[bytes]) -> bytes:
    return _HDR.pack(OP_BATCH, len(requests)) + b"".join(
        _U32.pack(len(r)) + r for r in requests
    )


# ---------------------------------------------------------------------------
# decode helpers
# ---------------------------------------------------------------------------
def _need(buf: bytes, end: int) -> None:
    if len(buf) < end:
        raise WireError(f"truncated message: need {end} B, have {len(buf)} B")


def _split_keys(buf: bytes, off: int, n: int) -> tuple[list[bytes], int]:
    end = off + n * KEY_BYTES
    _need(buf, end)
    keys = [buf[i : i + KEY_BYTES] for i in range(off, end, KEY_BYTES)]
    return keys, end


def _split_i64(buf: bytes, off: int, n: int) -> tuple[np.ndarray, int]:
    end = off + 8 * n
    _need(buf, end)
    return np.frombuffer(buf, np.int64, n, off), end


def _split_i32(buf: bytes, off: int, n: int) -> tuple[np.ndarray, int]:
    end = off + 4 * n
    _need(buf, end)
    return np.frombuffer(buf, np.int32, n, off), end


def decode_match_resp(buf: bytes) -> tuple[np.ndarray, np.ndarray]:
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    ids, off = _split_i64(buf, 4, n)
    eps, _ = _split_i64(buf, off, n)
    return ids, eps


def decode_publish_resp(buf: bytes) -> int:
    _need(buf, 4)
    return _U32.unpack_from(buf)[0]


def decode_lookup_resp(buf: bytes) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    ids, off = _split_i64(buf, 4, n)
    eps, off = _split_i64(buf, off, n)
    ntk, _ = _split_i32(buf, off, n)
    return ids, eps, ntk


def decode_filter_resp(buf: bytes) -> list[int]:
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    pos, _ = _split_i32(buf, 4, n)
    return pos.tolist()


def decode_evict_resp(buf: bytes) -> list[int]:
    _need(buf, 4)
    (n,) = _U32.unpack_from(buf)
    ids, _ = _split_i64(buf, 4, n)
    return ids.tolist()


def _split_frames(buf: bytes, off: int, k: int) -> list[bytes]:
    """k length-prefixed frames starting at ``off`` (the BATCH body)."""
    out = []
    for _ in range(k):
        _need(buf, off + 4)
        (ln,) = _U32.unpack_from(buf, off)
        off += 4
        _need(buf, off + ln)
        out.append(buf[off : off + ln])
        off += ln
    return out


def decode_batch_resp(buf: bytes) -> list[bytes]:
    _need(buf, 4)
    (k,) = _U32.unpack_from(buf)
    return _split_frames(buf, 4, k)


# ---------------------------------------------------------------------------
# server-side dispatch
# ---------------------------------------------------------------------------
_MAX_BATCH_DEPTH = 4  # BATCH-in-BATCH nesting cap (keeps decode O(payload))


def reply_bound(buf: bytes, _depth: int = 0) -> int:
    """Worst-case reply size for a request, WITHOUT executing it.

    Lets a transport with fixed reply capacity reject an op whose answer
    could not be shipped BEFORE any index mutation runs — otherwise an
    oversized EVICT would free blocks server-side while the caller only
    ever sees a transport error. Walks (and therefore validates) the
    whole frame structure INCLUDING each op's declared body size, so a
    BATCH with a truncated sub-op anywhere also fails up front instead
    of after its leading sub-ops mutated the index."""
    _need(buf, _HDR.size)
    op, n = _HDR.unpack_from(buf)
    if op == OP_MATCH:
        _need(buf, _HDR.size + KEY_BYTES * n)
        return 4 + 16 * n
    if op == OP_PUBLISH:
        _need(buf, _PUB_HDR.size + (KEY_BYTES + 16) * n)
        return 4
    if op == OP_LOOKUP:
        _need(buf, _HDR.size + KEY_BYTES * n)
        return 4 + 20 * n
    if op == OP_FILTER:
        _need(buf, _HDR.size + KEY_BYTES * n)
        return 4 + 4 * n
    if op == OP_EVICT:
        return 4 + 8 * n
    if op == OP_BATCH:
        if _depth >= _MAX_BATCH_DEPTH:
            raise WireError(f"BATCH nesting exceeds {_MAX_BATCH_DEPTH}")
        frames = _split_frames(buf, _HDR.size, n)
        return 4 + sum(4 + reply_bound(f, _depth + 1) for f in frames)
    raise WireError(f"unknown op {op}")


def prevalidate(index, buf: bytes, _depth: int = 0) -> None:
    """Semantic validation of a request WITHOUT executing it.

    ``reply_bound`` already walks the frame structure; this pass runs the
    op-level checks (duplicate MATCH keys, out-of-range PUBLISH ids) over
    every sub-op up front, so a BATCH whose later sub-op is invalid fails
    BEFORE its leading mutating sub-ops commit — the batch either starts
    clean or not at all. ``handle_request`` repeats the same checks
    inline as defense-in-depth for direct callers."""
    _need(buf, _HDR.size)
    op, n = _HDR.unpack_from(buf)
    if op == OP_MATCH:
        keys, _ = _split_keys(buf, _HDR.size, n)
        _check_match_keys(keys)
    elif op == OP_PUBLISH:
        _need(buf, _PUB_HDR.size)
        _, n, _ = _PUB_HDR.unpack_from(buf)
        _, off = _split_keys(buf, _PUB_HDR.size, n)
        ids, _ = _split_i64(buf, off, n)
        _check_publish_ids(index, ids)
    elif op == OP_BATCH:
        if _depth >= _MAX_BATCH_DEPTH:
            raise WireError(f"BATCH nesting exceeds {_MAX_BATCH_DEPTH}")
        for f in _split_frames(buf, _HDR.size, n):
            prevalidate(index, f, _depth + 1)


def _check_match_keys(keys: list[bytes]) -> None:
    if len(set(keys)) != len(keys):
        # a chain-hashed prefix never repeats a key; a duplicate would
        # also corrupt the index's batch LRU splice, so reject it at
        # the trust boundary instead of walking it
        raise WireError("duplicate keys in MATCH chain")


def _check_publish_ids(index, ids: np.ndarray) -> None:
    if len(ids) and (ids.min() < 0 or ids.max() >= index.pool.n_blocks):
        # untrusted ids would scatter into block2row out of range
        # (numpy negative indexing would silently corrupt another
        # block's owner pointer)
        raise WireError("PUBLISH block id out of pool range")


def handle_request(
    index, buf: bytes, _depth: int = 0, _validated: bool = False
) -> bytes:
    """Decode one wire message, run it against ``index``, encode the reply.

    ``_validated`` skips the inline semantic checks when the caller
    already ran ``prevalidate`` over the whole frame (the server path) —
    direct callers keep them as defense-in-depth."""
    _need(buf, _HDR.size)
    op, n = _HDR.unpack_from(buf)
    if op == OP_MATCH:
        keys, _ = _split_keys(buf, _HDR.size, n)
        if not _validated:
            _check_match_keys(keys)
        hits = index.match_prefix_keys(keys)
        ids = np.fromiter((b for _, b, _ in hits), np.int64, len(hits))
        eps = np.fromiter((e for _, _, e in hits), np.int64, len(hits))
        return _U32.pack(len(hits)) + ids.tobytes() + eps.tobytes()
    if op == OP_PUBLISH:
        _need(buf, _PUB_HDR.size)
        _, n, n_tokens = _PUB_HDR.unpack_from(buf)
        keys, off = _split_keys(buf, _PUB_HDR.size, n)
        ids, off = _split_i64(buf, off, n)
        eps, _ = _split_i64(buf, off, n)
        if not _validated:
            _check_publish_ids(index, ids)
        index.publish_many(keys, ids.tolist(), eps.tolist(), n_tokens)
        return _U32.pack(n)
    if op == OP_LOOKUP:
        keys, _ = _split_keys(buf, _HDR.size, n)
        entries = index.lookup_many(keys)
        ids = np.fromiter(
            (-1 if e is None else e.block_id for e in entries), np.int64, n
        )
        eps = np.fromiter(
            (0 if e is None else e.epoch for e in entries), np.int64, n
        )
        ntk = np.fromiter(
            (0 if e is None else e.n_tokens for e in entries), np.int32, n
        )
        return _U32.pack(n) + ids.tobytes() + eps.tobytes() + ntk.tobytes()
    if op == OP_FILTER:
        keys, _ = _split_keys(buf, _HDR.size, n)
        missing = index.filter_unpublished(keys)
        return _U32.pack(len(missing)) + np.asarray(missing, np.int32).tobytes()
    if op == OP_EVICT:
        freed = index.evict_lru(n)
        return _U32.pack(len(freed)) + np.asarray(freed, np.int64).tobytes()
    if op == OP_BATCH:
        if _depth >= _MAX_BATCH_DEPTH:
            raise WireError(f"BATCH nesting exceeds {_MAX_BATCH_DEPTH}")
        out = [
            handle_request(index, f, _depth + 1, _validated)
            for f in _split_frames(buf, _HDR.size, n)
        ]
        return _U32.pack(n) + b"".join(_U32.pack(len(r)) + r for r in out)
    raise WireError(f"unknown op {op}")


def make_index_handler(index, max_reply: int | None = None):
    """Handler for ``CxlRpcServer``: the metadata service poll thread.

    ``max_reply`` (usually the ring's ``payload_bytes``) makes the handler
    verify — via ``reply_bound``, before executing anything — that the
    reply can be shipped, so a request whose answer cannot fit never
    half-runs a mutating op."""

    def handler(payload: bytes) -> bytes:
        if max_reply is not None and reply_bound(payload) > max_reply:
            raise WireError(f"reply would exceed {max_reply} B slot")
        prevalidate(index, payload)  # batch starts clean or not at all
        return handle_request(index, payload, _validated=True)

    return handler


# ---------------------------------------------------------------------------
# client-side proxy
# ---------------------------------------------------------------------------
class RpcIndexClient:
    """``GlobalIndex`` API surface over an RPC transport.

    Drop-in for the manager/engine side of the index: hashing
    (``keys_for``) runs locally, every metadata op is one batched
    round-trip. Ops whose chain exceeds one ring slot are split
    transparently (match splits stop early on a short chunk, so the
    prefix property is preserved)."""

    def __init__(self, rpc, block_tokens: int, max_payload: int | None = None,
                 hasher: PrefixHasher | None = None):
        self.rpc = rpc
        # hashing is pure computation, so clients on one host can share a
        # hasher (and its request memo) instead of re-deriving the same
        # chain once per engine
        self.hasher = hasher if hasher is not None else PrefixHasher(block_tokens)
        self.block_tokens = block_tokens
        if max_payload is None:
            max_payload = getattr(
                getattr(rpc, "ring", None), "payload_bytes", 1 << 20
            )
        # per-op chain capacity of one slot (headers are <= 16 B),
        # bounding BOTH the request and its response
        self._max_match = max(1, (max_payload - 16) // KEY_BYTES)
        self._max_publish = max(1, (max_payload - 16) // (KEY_BYTES + 16))
        self._max_lookup = max(1, (max_payload - 16) // max(KEY_BYTES, 20))
        self._max_evict = max(1, (max_payload - 16) // 8)

    # -- hashing is local ------------------------------------------------
    def keys_for(self, tokens: list[int]) -> tuple[bytes, ...]:
        return self.hasher.keys_for(tokens)

    # -- one round-trip per op ------------------------------------------
    def match_prefix(self, tokens: list[int]) -> list[tuple[bytes, int, int]]:
        return self.match_prefix_keys(self.keys_for(tokens))

    def match_prefix_keys(self, keys) -> list[tuple[bytes, int, int]]:
        out: list[tuple[bytes, int, int]] = []
        for off in range(0, len(keys), self._max_match):
            chunk = keys[off : off + self._max_match]
            ids, eps = decode_match_resp(self.rpc.call(encode_match(chunk)))
            out.extend(zip(chunk, ids.tolist(), eps.tolist()))
            if len(ids) < len(chunk):
                break  # prefix ended inside this chunk
        return out

    def publish_many(self, keys, block_ids, epochs, n_tokens: int) -> None:
        for off in range(0, len(keys), self._max_publish):
            end = off + self._max_publish
            self.rpc.call(
                encode_publish(
                    keys[off:end], block_ids[off:end], epochs[off:end], n_tokens
                )
            )

    def lookup_many(self, keys) -> list[IndexEntry | None]:
        out: list[IndexEntry | None] = []
        for off in range(0, len(keys), self._max_lookup):
            chunk = keys[off : off + self._max_lookup]
            ids, eps, ntk = decode_lookup_resp(self.rpc.call(encode_lookup(chunk)))
            out.extend(
                None if b < 0 else IndexEntry(int(b), int(e), int(t), 0.0)
                for b, e, t in zip(ids.tolist(), eps.tolist(), ntk.tolist())
            )
        return out

    def lookup(self, key: bytes) -> IndexEntry | None:
        return self.lookup_many([key])[0]

    def filter_unpublished(self, keys) -> list[int]:
        out: list[int] = []
        for off in range(0, len(keys), self._max_lookup):
            chunk = keys[off : off + self._max_lookup]
            out.extend(
                off + p for p in decode_filter_resp(self.rpc.call(encode_filter(chunk)))
            )
        return out

    def evict_lru(self, n: int) -> list[int]:
        # chunked: the RESPONSE carries 8 B per freed block, so an
        # unbounded n could overflow the slot even though the request
        # always fits; a short chunk means the index ran out of victims
        freed: list[int] = []
        while n > 0:
            k = min(n, self._max_evict)
            got = decode_evict_resp(self.rpc.call(encode_evict(k)))
            freed.extend(got)
            if len(got) < k:
                break
            n -= k
        return freed

    def call_batch(self, requests: list[bytes]) -> list[bytes]:
        """Ship k already-encoded ops in ONE ring round-trip."""
        return decode_batch_resp(self.rpc.call(encode_batch(requests)))

"""CXL-RPC: lock-free shared-memory ring RPC (paper §6.2, Exp #11).

Producer/consumer protocol exactly as the paper describes:
  * fixed-size request/response slots pre-allocated in the shared pool;
  * client writes payload then flips a status word to REQ_READY
    (paper: ntstore + batched mfence, cache-line aligned);
  * server spin-polls status words, processes, writes reply, flips to
    RESP_READY (paper: server CLFLUSHes before reading client data);
  * everything stays in user space — no kernel transitions.

This implementation is REAL (numpy shared buffer + threads) so Exp #11 can
measure genuine RTT/throughput on this host; the fabric model adds the
CXL-vs-RDMA constants for the paper-calibrated comparison.

Wire-level details carried by the ring (see ``repro.core.wire`` for the
metadata-op codec layered on top):
  * payloads are VARIABLE length: each slot stores ``u32 length`` + bytes
    (the paper's variable SGL descriptor), so one round-trip carries a
    whole request's key chain instead of a fixed 64 B token;
  * the server drains the ring with one vectorized status scan
    (``np.nonzero(status == REQ_READY)``) per pass — O(ready slots) of
    Python work per batch, not O(n_slots) interpreter steps per poll;
  * a client whose wait times out QUARANTINES the slot instead of
    recycling it: the server may still write a stale response into it,
    and a freed-then-reused slot would hand that stale payload to an
    unrelated caller. Quarantined slots return to the free list only
    after the server has answered them (observed at the next acquire),
    closing the reuse race;
  * a handler failure (malformed frame, oversized reply) is relayed
    in-band as a RESP_ERROR frame and raised client-side as ``RpcError``
    — the service thread itself never dies to a bad request;
  * ``post``/``collect`` split the round-trip so a sharded metadata
    client (``repro.core.wire.ShardedRpcIndexClient``) can keep requests
    to SEVERAL rings outstanding at once: post to every shard's ring,
    then collect the replies — true parallel outstanding RPCs over the
    same slot protocol (``call`` is just post+collect on one ring);
  * FAILED round-trips are visible in ``RpcStats``: an in-band
    RESP_ERROR bumps ``errors``, a timeout bumps ``timeouts``, and both
    account their wait into ``total_wait`` BEFORE raising — so an
    error-heavy run can't report a rosy average RTT over successes only;
  * the ring optionally lives in a NAMED ``multiprocessing.shared_memory``
    segment (``ShmRing.create_shared`` / ``ShmRing.attach``): status/req/
    resp become numpy views over one buffer two OS processes map, so a
    metadata service can run as its own process (one per shard — see
    ``repro.core.procserver``) with nothing but load/stores crossing the
    boundary.  A ``liveness`` probe on the client turns a crashed service
    into a fast in-band ``RpcError`` (counted in ``RpcStats.errors``)
    instead of a full-timeout hang per outstanding call.
"""

from __future__ import annotations

import struct
import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core.fabric import DEFAULT, FabricConstants
from repro.core.locks import make_lock
from repro.core.shm import attach_segment, close_segment, create_segment

IDLE, REQ_READY, RESP_READY, RESP_ERROR = 0, 1, 2, 3
CACHE_LINE = 64
_LEN = struct.Struct("<I")

# control words at the head of every ring (shared-memory rings expose them
# cross-process; private rings keep the same layout for uniformity):
#   CTRL_STOP    — the ring owner flips it to 1 to ask an out-of-process
#                  service to drain and exit (no signal/pipe: the stop
#                  request travels the same load/store plane as the data);
#   CTRL_SERVED  — served-request counter maintained by the service, the
#                  cross-process replacement for ``CxlRpcServer.served``;
#   CTRL_READY   — the service flips it to 1 once boot (including any
#                  journal replay) is done and the serve loop is entered:
#                  the supervisor gates client cut-over on it;
#   CTRL_BUSY_NS — cumulative wall-ns the service spent inside handlers
#                  (the OP_STATS service timer: capacity = served/busy);
#   CTRL_DOORBELL — armed flag for the doorbell wakeup protocol: the
#                  consumer sets it before blocking on its Doorbell FIFO,
#                  producers ring after posting iff it is set (see
#                  ``repro.core.shm.Doorbell`` for the lost-wakeup
#                  argument).  Rings without a doorbell leave it 0.
CTRL_STOP, CTRL_SERVED, CTRL_READY, CTRL_BUSY_NS, CTRL_DOORBELL = 0, 1, 2, 3, 4
_N_CTRL = 5


class RpcError(RuntimeError):
    """Server-side handler failure, relayed in-band (RESP_ERROR frame)."""


class ServiceDiedError(RpcError):
    """The service process died (or its ring was swapped by a supervisor
    restart) while a call was outstanding.  Distinct from ``RpcError``
    proper — a handler failure is the CALLER's bug and must not be
    retried, while this is transient by construction (a supervisor is
    respawning the shard) and safe to retry for every op: the journal
    replay restores any mutation whose reply the crash swallowed."""


@dataclass
class RpcStats:
    requests: int = 0  # completed OK
    total_wait: float = 0.0  # includes the wait of errored/timed-out calls
    timeouts: int = 0
    errors: int = 0  # in-band RESP_ERROR frames (handler failures)
    retries: int = 0  # failed attempts retried under a RetryPolicy
    degraded_ops: int = 0  # ops served degraded (shard down, holes/refusal)
    restarts: int = 0  # shard service restarts observed (ring swaps)

    @property
    def round_trips(self) -> int:
        """Every round-trip that consumed ring time, failed or not."""
        return self.requests + self.errors + self.timeouts

    def avg_wait(self) -> float:
        return self.total_wait / max(1, self.round_trips)


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff against a restarting shard service.

    ``backoff(attempt)`` is the sleep BEFORE retry number ``attempt``
    (1-based): base * 2^(attempt-1), capped.  The total budget across
    ``max_retries`` attempts bounds how long a caller blocks on a shard
    the supervisor is still rebuilding — with the defaults ~2.5 s, a
    comfortable multiple of kill→respawn→replay on this host."""

    max_retries: int = 8
    base_backoff: float = 0.02
    max_backoff: float = 1.0

    def backoff(self, attempt: int) -> float:
        return min(self.max_backoff, self.base_backoff * (2 ** (attempt - 1)))


def _truncate_utf8(raw: bytes, cap: int) -> bytes:
    """Truncate to ``cap`` bytes WITHOUT splitting a multi-byte UTF-8
    character: back the cut up while it lands on a continuation byte, so
    the shipped frame always decodes cleanly."""
    if len(raw) <= cap:
        return raw
    cut = cap
    while cut > 0 and (raw[cut] & 0xC0) == 0x80:
        cut -= 1
    return raw[:cut]


class ShmRing:
    """One ring: n_slots request/response slot pairs in a flat buffer.

    Two backings behind one layout:

      * private (default) — numpy arrays in this process, served by a
        ``CxlRpcServer`` thread (the PR-3/PR-4 shape, bit-identical);
      * shared — the SAME arrays carved as views over one named
        ``multiprocessing.shared_memory`` segment, attachable BY NAME from
        another process (``create_shared`` / ``attach``).  Status flips
        and payload bytes then really are plain load/stores on memory two
        OS processes map — the paper's CXL-RPC slots, not a pickle pipe.

    Layout of the shared segment (all offsets 8-byte aligned):
        ctrl[2] int64 | status[n_slots] int64 | req | resp
    """

    def __init__(self, n_slots: int = 128, payload_bytes: int = 64, *,
                 _segment=None, _owner: bool = True):
        # slot = u32 length header + payload, padded to cache-line
        # multiples (paper: cache-line alignment)
        self.payload_bytes = payload_bytes
        slot = 4 + payload_bytes
        self.slot_bytes = ((slot + CACHE_LINE - 1) // CACHE_LINE) * CACHE_LINE
        self.n_slots = n_slots
        self._segment = _segment
        self._owner = _owner
        self.shm_name = None if _segment is None else _segment.name
        if _segment is None:
            self.ctrl = np.zeros(_N_CTRL, np.int64)
            self.status = np.zeros(n_slots, np.int64)
            self.req = np.zeros((n_slots, self.slot_bytes), np.uint8)
            self.resp = np.zeros((n_slots, self.slot_bytes), np.uint8)
        else:
            buf = _segment.buf
            off = 0
            self.ctrl = np.frombuffer(buf, np.int64, _N_CTRL, off)
            off += 8 * _N_CTRL
            self.status = np.frombuffer(buf, np.int64, n_slots, off)
            off += 8 * n_slots
            nbytes = n_slots * self.slot_bytes
            self.req = np.frombuffer(buf, np.uint8, nbytes, off).reshape(
                n_slots, self.slot_bytes
            )
            off += nbytes
            self.resp = np.frombuffer(buf, np.uint8, nbytes, off).reshape(
                n_slots, self.slot_bytes
            )

    # -- shared-memory backing ------------------------------------------
    @staticmethod
    def shared_size(n_slots: int, payload_bytes: int) -> int:
        slot = 4 + payload_bytes
        slot_bytes = ((slot + CACHE_LINE - 1) // CACHE_LINE) * CACHE_LINE
        return 8 * _N_CTRL + 8 * n_slots + 2 * n_slots * slot_bytes

    @classmethod
    def create_shared(cls, n_slots: int = 128, payload_bytes: int = 64) -> "ShmRing":
        """Ring in a fresh named segment; the creator owns the unlink."""
        seg = create_segment(cls.shared_size(n_slots, payload_bytes))
        return cls(n_slots, payload_bytes, _segment=seg, _owner=True)

    @classmethod
    def attach(cls, name: str, n_slots: int, payload_bytes: int) -> "ShmRing":
        """Map an existing ring by segment name (the service-process side).

        Geometry travels out-of-band (the spawn spec): the segment holds
        only slot state, never pickled objects."""
        seg = attach_segment(name)
        return cls(n_slots, payload_bytes, _segment=seg, _owner=False)

    def close(self) -> None:
        """Drop this process's mapping (owner also unlinks the name)."""
        if self._segment is None:
            return
        self.ctrl = self.status = self.req = self.resp = None
        close_segment(self._segment, unlink=self._owner)
        self._segment = None

    # -- framed slot I/O ------------------------------------------------
    def write_req(self, slot: int, payload: bytes) -> None:
        self._write(self.req, slot, payload)

    def write_resp(self, slot: int, payload: bytes) -> None:
        self._write(self.resp, slot, payload)

    def _write(self, buf: np.ndarray, slot: int, payload: bytes) -> None:
        n = len(payload)
        if n > self.payload_bytes:
            raise ValueError(
                f"payload {n} B exceeds slot capacity {self.payload_bytes} B"
            )
        buf[slot, : 4 + n] = np.frombuffer(_LEN.pack(n) + payload, np.uint8)

    def read_req(self, slot: int) -> bytes:
        return self._read(self.req, slot)

    def read_resp(self, slot: int) -> bytes:
        return self._read(self.resp, slot)

    def _read(self, buf: np.ndarray, slot: int) -> bytes:
        (n,) = _LEN.unpack(buf[slot, :4].tobytes())
        return buf[slot, 4 : 4 + n].tobytes()


def drain_ready(ring: ShmRing, handler, delay: float = 0.0) -> int:
    """One vectorized pass over a ring: serve every REQ_READY slot.

    Shared by the in-process ``CxlRpcServer`` poll thread and the
    out-of-process service loop (``repro.core.procserver``) so the two
    transports run the EXACT same slot protocol.  Returns the number of
    slots served.  ``delay`` is a test hook: a per-request service stall
    used to exercise client timeout quarantine against a slow service.
    """
    status = ring.status
    # one vectorized scan finds every posted request; the Python loop
    # below only touches slots that actually have work
    ready = np.nonzero(status == REQ_READY)[0]
    if not len(ready):
        return 0
    # a handler that declares ``wants_slot`` also receives the slot index
    # that posted the request: on a partitioned ring (several worker
    # processes sharing disjoint slot ranges) the slot identifies the
    # POSTER, which a lease-tracking pool handler needs to attribute
    # allocator traffic per worker
    wants_slot = getattr(handler, "wants_slot", False)
    t_ns = time.perf_counter_ns()
    for i in ready.tolist():
        if delay:
            time.sleep(delay)
        # paper: CLFLUSH before reading client-written data
        payload = ring.read_req(i)
        # a failing handler (malformed frame, index error, reply larger
        # than the slot) must never kill the service: the error is
        # relayed in-band as a RESP_ERROR frame and draining continues
        try:
            reply = handler(payload, i) if wants_slot else handler(payload)
            ring.write_resp(i, reply)
            status[i] = RESP_READY  # publish (ntstore semantics)
        except Exception as e:  # noqa: BLE001
            # truncate on a CHARACTER boundary: a byte-slice could
            # split a multi-byte UTF-8 char and ship mojibake
            msg = _truncate_utf8(
                f"{type(e).__name__}: {e}".encode(), ring.payload_bytes
            )
            ring.write_resp(i, msg)
            status[i] = RESP_ERROR
    # service-side timer, measured IN the serving process: both served
    # count and busy-ns live in the ring's ctrl words, so the client can
    # read capacity (served/busy) without an in-process replica and both
    # transports (thread + process) account identically.
    ring.ctrl[CTRL_SERVED] += len(ready)
    ring.ctrl[CTRL_BUSY_NS] += time.perf_counter_ns() - t_ns
    return len(ready)


class CxlRpcServer:
    """Spin-polling consumer (the metadata service thread).

    ``doorbell`` (a ``repro.core.shm.Doorbell``) replaces the pure
    GIL-yield spin once the ring has been empty for ``idle_spin_passes``
    scans: the thread arms ``CTRL_DOORBELL``, re-scans, and blocks in the
    FIFO wait (bounded by ``doorbell_wait_s`` — a lost wakeup costs one
    period, never a hang).  Without a doorbell the loop keeps the
    configurable spin/backoff fallback (``idle_backoff_s``); the defaults
    reproduce the original always-yield behavior exactly."""

    def __init__(self, ring: ShmRing, handler, doorbell=None,
                 idle_spin_passes: int = 0, idle_backoff_s: float = 0.0,
                 doorbell_wait_s: float = 0.05):
        self.ring = ring
        self.handler = handler
        self.doorbell = doorbell
        self.idle_spin_passes = idle_spin_passes
        self.idle_backoff_s = idle_backoff_s
        self.doorbell_wait_s = doorbell_wait_s
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._poll_loop, daemon=True)

    @property
    def served(self) -> int:
        """Requests served, read from the ring's ctrl word (the service
        timer maintained by ``drain_ready`` — identical across the thread
        and process transports)."""
        return int(self.ring.ctrl[CTRL_SERVED])

    @property
    def busy_ns(self) -> int:
        """Cumulative ns spent inside handlers (service-side timer)."""
        return int(self.ring.ctrl[CTRL_BUSY_NS])

    def start(self):
        self.ring.ctrl[CTRL_READY] = 1  # no boot work on the thread path
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self.doorbell is not None:
            self.doorbell.ring()  # wake a thread parked in the FIFO wait
        self._thread.join(timeout=5)

    def close(self):
        """Lifecycle alias (uniform with ``ProcessRpcServer.close``)."""
        self.stop()

    def _poll_loop(self):
        ring = self.ring
        doorbell = self.doorbell
        if doorbell is not None:
            doorbell.open_read()  # reader must exist before the first arm
        idle = 0
        while not self._stop.is_set():
            if drain_ready(ring, self.handler):
                idle = 0
                continue
            idle += 1
            if idle < self.idle_spin_passes or (
                doorbell is None and not self.idle_backoff_s
            ):
                time.sleep(0)  # yield GIL; real impl spins
            elif doorbell is None:
                time.sleep(self.idle_backoff_s)
            else:
                # arm -> re-scan -> block: the doorbell wakeup protocol
                ring.ctrl[CTRL_DOORBELL] = 1
                try:
                    if drain_ready(ring, self.handler):
                        idle = 0
                        continue
                    doorbell.wait(self.doorbell_wait_s)
                finally:
                    ring.ctrl[CTRL_DOORBELL] = 0


class CxlRpcClient:
    def __init__(self, ring: ShmRing, model_fabric: bool = False,
                 constants: FabricConstants = DEFAULT, liveness=None,
                 doorbell=None, slot_range: tuple[int, int] | None = None):
        self.ring = ring
        self.model_fabric = model_fabric
        self.c = constants
        # optional service-liveness probe (``ProcessRpcServer.alive``): a
        # ring served by a CRASHED process never flips a status word, so
        # without the probe every outstanding call burns its full timeout.
        # With it, collect() fails fast as an ERROR (the service died) —
        # distinct from a timeout (the service is slow).
        self.liveness = liveness
        # optional producer-side doorbell handle: post() rings it when the
        # service has armed CTRL_DOORBELL (idle consumer parked in its
        # FIFO wait) so a cold ring wakes without burning the wait period
        self.doorbell = doorbell
        # slot ownership: by default a client owns EVERY slot of its ring.
        # ``slot_range=(lo, hi)`` restricts it to [lo, hi) so SEVERAL
        # client processes (engine workers + the pool owner) can share one
        # ring without colliding on the free list — the slot protocol
        # itself is single-producer per slot either way.
        self._slot_range = (0, ring.n_slots) if slot_range is None else slot_range
        lo, hi = self._slot_range
        if not (0 <= lo < hi <= ring.n_slots):
            raise ValueError(f"slot_range {self._slot_range} outside ring "
                             f"of {ring.n_slots} slots")
        self.stats = RpcStats()
        self._slot_lock = make_lock("rpc.CxlRpcClient._slot_lock")
        self._free = list(range(lo, hi))
        # slots whose caller timed out while the server still owed a
        # response; unsafe to reuse until the server flips them
        self._quarantined: set[int] = set()
        # per-slot post timestamp: collect() accounts wait from the post,
        # not from whenever the caller got around to collecting
        self._t_posted = np.zeros(ring.n_slots, np.float64)

    def free_slots(self) -> int:
        with self._slot_lock:
            return len(self._free)

    def adopt_ring(self, ring: ShmRing, liveness=None, doorbell=None) -> None:
        """Cut this client over to a FRESH ring (supervisor restart path).

        The old ring is abandoned, not closed here — in-flight collects
        still hold references to it and fail fast via the identity check
        in ``collect``; the supervisor owns the old segment's teardown.
        All slot state resets: the new ring starts empty by construction
        (a fresh zero-filled segment), so the free list is full and no
        quarantine carries over.  The client keeps its slot-range share
        (same geometry by construction: restarts reuse the spec)."""
        with self._slot_lock:
            old_db = self.doorbell
            if old_db is not None and old_db is not doorbell:
                old_db.close()  # attach-side: drops fds, never unlinks
            self.ring = ring
            self.liveness = liveness
            self.doorbell = doorbell
            lo, hi = self._slot_range
            hi = min(hi, ring.n_slots)
            self._free = list(range(lo, hi))
            self._quarantined = set()
            self._t_posted = np.zeros(ring.n_slots, np.float64)
            self.stats.restarts += 1

    def _acquire_slot(self) -> int:
        with self._slot_lock:
            if self._quarantined:
                # reclaim quarantined slots the server has since answered
                done = [
                    s for s in self._quarantined
                    if self.ring.status[s] in (RESP_READY, RESP_ERROR)
                ]
                for s in done:
                    self.ring.status[s] = IDLE
                    self._quarantined.discard(s)
                    self._free.append(s)
                # a DEAD service will never answer the rest: once the
                # liveness probe fails (killed child / retired ring with
                # CTRL_STOP set) no writer remains for those slots, so
                # they are safe to reuse.  Without this, fail-fast
                # retries against a dead ring burn one slot each and a
                # narrow slot partition (engine workers share rings by
                # disjoint ranges) exhausts into "QD exceeded" before
                # the cutover to the new generation can reach it.
                if (
                    self._quarantined
                    and self.liveness is not None
                    and not self.liveness()
                ):
                    for s in list(self._quarantined):
                        self.ring.status[s] = IDLE
                        self._quarantined.discard(s)
                        self._free.append(s)
            if not self._free:
                raise RuntimeError("no free RPC slots (QD exceeded)")
            return self._free.pop()

    def post(self, payload: bytes) -> int:
        """Write a request and flip its slot to REQ_READY; returns the
        slot for a later ``collect``. Splitting the round-trip lets a
        sharded client keep RPCs to several rings outstanding at once."""
        slot = self._acquire_slot()
        try:
            self.ring.write_req(slot, payload)
        except BaseException:
            with self._slot_lock:  # nothing posted: plain recycle
                self._free.append(slot)
            raise
        self._t_posted[slot] = time.perf_counter()
        self.ring.status[slot] = REQ_READY  # ntstore + fence
        # status is published FIRST, then the armed word is checked: if
        # the consumer armed before our store it sees the ring; if it
        # scans between our store and this check it serves us directly
        # and the extra ring is a drained no-op (see Doorbell docstring)
        db = self.doorbell
        if db is not None and self.ring.ctrl[CTRL_DOORBELL]:
            db.ring()
        return slot

    def collect(self, slot: int, timeout: float = 5.0) -> bytes:
        """Wait for the reply posted in ``slot``; recycle or quarantine it.

        Failed round-trips are ACCOUNTED, not invisible: a timeout or an
        in-band RESP_ERROR bumps its counter and contributes its wait to
        ``total_wait`` before raising (the old path raised first, so
        error-heavy runs reported averages over successes only)."""
        ring = self.ring
        stats = self.stats
        t0 = float(self._t_posted[slot])
        if t0 == 0.0:
            # the ring was swapped (adopt_ring zeroes post timestamps)
            # between this slot's post and its collect: the reply will
            # never arrive on the ring we now hold
            stats.errors += 1
            raise ServiceDiedError("ring swapped mid-call (service restarted)")
        deadline = t0 + timeout
        completed = False
        spins = 0
        try:
            while (st := int(ring.status[slot])) not in (RESP_READY, RESP_ERROR):
                if time.perf_counter() > deadline:
                    stats.timeouts += 1
                    stats.total_wait += time.perf_counter() - t0
                    raise TimeoutError("RPC timeout")
                spins += 1
                if not (spins & 0xFF):
                    # ring-swap detection: a supervisor restart adopted a
                    # fresh ring under this client while we waited on the
                    # OLD one — our slot will never be served. Fail fast
                    # as an RpcError so the retry layer re-posts on the
                    # new ring.
                    if self.ring is not ring:
                        stats.errors += 1
                        stats.total_wait += time.perf_counter() - t0
                        raise ServiceDiedError(
                            "ring swapped mid-call (service restarted)"
                        )
                    # crashed-service detection (throttled: is_alive is a
                    # syscall): a dead service will never flip this slot,
                    # so fail NOW as an in-band error instead of burning
                    # the timeout — unless the reply landed before death
                    if (
                        self.liveness is not None
                        and not self.liveness()
                        and int(ring.status[slot])
                        not in (RESP_READY, RESP_ERROR)
                    ):
                        stats.errors += 1
                        stats.total_wait += time.perf_counter() - t0
                        raise ServiceDiedError(
                            "metadata service process died (ring abandoned)"
                        )
                time.sleep(0)
            out = ring.read_resp(slot)
            ring.status[slot] = IDLE
            completed = True  # server answered: safe to recycle
            stats.total_wait += time.perf_counter() - t0
            if st == RESP_ERROR:
                stats.errors += 1
                raise RpcError(out.decode("utf-8", errors="replace"))
            stats.requests += 1
            return out
        finally:
            with self._slot_lock:
                if self.ring is not ring:
                    pass  # swapped ring: adopt_ring already rebuilt state
                elif completed:
                    self._free.append(slot)
                else:
                    # the server may still write here — quarantine until
                    # it flips the slot to RESP_READY (checked at acquire)
                    self._quarantined.add(slot)

    def call(self, payload: bytes, timeout: float = 5.0) -> bytes:
        return self.collect(self.post(payload), timeout)

    def modeled_rtt(self) -> float:
        """Paper-calibrated RTT floor for this transport (Exp #11)."""
        return self.c.cxl_rpc_rtt


class ModeledRdmaRpc:
    """RDMA RPC baseline: same handler, latency from paper constants."""

    def __init__(self, handler, transport: str = "rc",
                 constants: FabricConstants = DEFAULT):
        self.handler = handler
        self.rtt = constants.rdma_rc_rpc_rtt if transport == "rc" else constants.rdma_ud_rpc_rtt
        self.stats = RpcStats()

    def call(self, payload: bytes) -> bytes:
        out = self.handler(payload)
        self.stats.requests += 1
        self.stats.total_wait += self.rtt
        return out

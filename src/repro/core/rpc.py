"""CXL-RPC: lock-free shared-memory ring RPC (paper §6.2, Exp #11).

Producer/consumer protocol exactly as the paper describes:
  * fixed-size request/response slots pre-allocated in the shared pool;
  * client writes payload then flips a status word to REQ_READY
    (paper: ntstore + batched mfence, cache-line aligned);
  * server spin-polls status words, processes, writes reply, flips to
    RESP_READY (paper: server CLFLUSHes before reading client data);
  * everything stays in user space — no kernel transitions.

This implementation is REAL (numpy shared buffer + threads) so Exp #11 can
measure genuine RTT/throughput on this host; the fabric model adds the
CXL-vs-RDMA constants for the paper-calibrated comparison.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core.fabric import DEFAULT, FabricConstants

IDLE, REQ_READY, RESP_READY = 0, 1, 2
CACHE_LINE = 64


@dataclass
class RpcStats:
    requests: int = 0
    total_wait: float = 0.0


class ShmRing:
    """One ring: n_slots request/response slot pairs in a flat buffer."""

    def __init__(self, n_slots: int = 128, payload_bytes: int = 64):
        # pad payload to cache-line multiple (paper: cache-line alignment)
        self.payload_bytes = ((payload_bytes + CACHE_LINE - 1) // CACHE_LINE) * CACHE_LINE
        self.n_slots = n_slots
        self.status = np.zeros(n_slots, np.int64)
        self.req = np.zeros((n_slots, self.payload_bytes), np.uint8)
        self.resp = np.zeros((n_slots, self.payload_bytes), np.uint8)


class CxlRpcServer:
    """Spin-polling consumer (the metadata service thread)."""

    def __init__(self, ring: ShmRing, handler):
        self.ring = ring
        self.handler = handler
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._poll_loop, daemon=True)
        self.served = 0

    def start(self):
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=5)

    def _poll_loop(self):
        ring = self.ring
        n = ring.n_slots
        while not self._stop.is_set():
            progressed = False
            status = ring.status
            for i in range(n):
                if status[i] == REQ_READY:
                    # paper: CLFLUSH before reading client-written data
                    payload = ring.req[i].tobytes()
                    reply = self.handler(payload)
                    out = np.frombuffer(
                        reply[: ring.payload_bytes].ljust(ring.payload_bytes, b"\0"),
                        np.uint8,
                    )
                    ring.resp[i] = out
                    status[i] = RESP_READY  # publish (ntstore semantics)
                    self.served += 1
                    progressed = True
            if not progressed:
                time.sleep(0)  # yield GIL; real impl spins


class CxlRpcClient:
    def __init__(self, ring: ShmRing, model_fabric: bool = False,
                 constants: FabricConstants = DEFAULT):
        self.ring = ring
        self.model_fabric = model_fabric
        self.c = constants
        self.stats = RpcStats()
        self._slot_lock = threading.Lock()
        self._free = list(range(ring.n_slots))

    def call(self, payload: bytes, timeout: float = 5.0) -> bytes:
        with self._slot_lock:
            if not self._free:
                raise RuntimeError("no free RPC slots (QD exceeded)")
            slot = self._free.pop()
        ring = self.ring
        try:
            buf = payload[: ring.payload_bytes].ljust(ring.payload_bytes, b"\0")
            ring.req[slot] = np.frombuffer(buf, np.uint8)
            t0 = time.perf_counter()
            ring.status[slot] = REQ_READY  # ntstore + fence
            deadline = t0 + timeout
            while ring.status[slot] != RESP_READY:
                if time.perf_counter() > deadline:
                    raise TimeoutError("RPC timeout")
                time.sleep(0)
            out = ring.resp[slot].tobytes()
            ring.status[slot] = IDLE
            dt = time.perf_counter() - t0
            self.stats.requests += 1
            self.stats.total_wait += dt
            return out
        finally:
            with self._slot_lock:
                self._free.append(slot)

    def modeled_rtt(self) -> float:
        """Paper-calibrated RTT floor for this transport (Exp #11)."""
        return self.c.cxl_rpc_rtt


class ModeledRdmaRpc:
    """RDMA RPC baseline: same handler, latency from paper constants."""

    def __init__(self, handler, transport: str = "rc",
                 constants: FabricConstants = DEFAULT):
        self.handler = handler
        self.rtt = constants.rdma_rc_rpc_rtt if transport == "rc" else constants.rdma_ud_rpc_rtt
        self.stats = RpcStats()

    def call(self, payload: bytes) -> bytes:
        out = self.handler(payload)
        self.stats.requests += 1
        self.stats.total_wait += self.rtt
        return out

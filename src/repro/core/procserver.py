"""Process-per-shard metadata service over shared-memory rings (paper §6).

Beluga's deployment shape is a metadata service that OWNS ITS OWN CORES
and serves clients over plain load/store slots in the shared pool — not a
thread inside the client interpreter.  This module is that shape:

  * ``ProcessRpcServer`` boots ONE OS process per metadata shard.  The
    child never receives a pickled handler, index, or lock: it gets a
    ``ShardServiceSpec`` of plain names/numbers and CONSTRUCTS its own
    ``GlobalIndex`` shard behind the ring — all state it serves lives
    behind the same trust boundary ``prevalidate``/``reply_bound``
    (repro.core.wire) already police, so nothing crosses except framed
    bytes in shared memory;
  * ``SharedPoolMeta`` attaches the pool's epoch/refcount/committed
    arrays exported by ``BelugaPool.share_meta`` — the service validates
    epochs and refcounts against the SAME memory the engines mutate
    (loads on the shared CXL pool state, per the paper), and never
    mutates pool state itself: its ``release`` is deferred — freed block
    ids travel back in the wire reply and the pool-owning process applies
    the real release (``RpcIndexClient(on_freed=pool.release)``);
  * shutdown is in-band too: the parent flips the ring's ``CTRL_STOP``
    word, the child drains and exits; ``atexit`` unlinking plus
    idempotent ``close()`` guarantee no leaked ``/dev/shm`` segments even
    when construction dies half-way;
  * a crashed child is DETECTED, not waited out: clients built with
    ``liveness=server.alive`` turn an abandoned ring into a fast
    ``RpcError`` counted in ``RpcStats.errors``.
"""

from __future__ import annotations

import atexit
import multiprocessing
import time
from dataclasses import dataclass

import numpy as np

from repro.core.pool import PoolLayout
from repro.core.rpc import CTRL_SERVED, CTRL_STOP, ShmRing, drain_ready
from repro.core.shm import attach_segment, close_segment


class SharedPoolMeta:
    """Attach-side, read-only view of a ``BelugaPool``'s metadata arrays.

    Quacks like the pool surface ``GlobalIndex`` needs — ``n_blocks``,
    ``layout.block_tokens``, ``refcounts``, ``validate_epochs`` — over the
    segment ``BelugaPool.share_meta`` exported.  ``release`` is a no-op by
    design: the service process must never mutate allocator state it does
    not own; freed ids are shipped back over the wire instead (see module
    docstring).
    """

    def __init__(self, shm_name: str, n_blocks: int, block_tokens: int):
        self._segment = attach_segment(shm_name)
        self.n_blocks = n_blocks
        # only block_tokens is meaningful service-side (keys arrive
        # pre-hashed over the wire); the rest is filler
        self.layout = PoolLayout(
            block_tokens=block_tokens, n_layers_kv=1, n_kv_heads=1, head_dim=1
        )
        buf = self._segment.buf
        self.epochs = np.frombuffer(buf, np.int64, n_blocks, 0)
        self.refcounts = np.frombuffer(buf, np.int32, n_blocks, 8 * n_blocks)
        self.committed = np.frombuffer(buf, np.bool_, n_blocks, 12 * n_blocks)
        self.data = None  # metadata-only view: payloads never cross here

    def validate_epochs(self, block_ids, epochs) -> np.ndarray:
        ids = np.asarray(block_ids, np.intp)
        return self.committed[ids] & (self.epochs[ids] == np.asarray(epochs))

    def validate_epoch(self, block_id: int, epoch: int) -> bool:
        return bool(self.validate_epochs([block_id], [epoch])[0])

    def release(self, block_ids) -> None:  # noqa: ARG002
        """Deferred: the pool-owning process releases the freed ids when
        the wire reply delivers them (``RpcIndexClient.on_freed``)."""

    def close(self) -> None:
        if self._segment is None:
            return
        self.epochs = self.refcounts = self.committed = None
        close_segment(self._segment, unlink=False)
        self._segment = None


@dataclass(frozen=True)
class ShardServiceSpec:
    """Everything a service child needs to build its shard — plain data.

    No handlers, locks, pools or index objects cross the process
    boundary: the child attaches the named segments and constructs its
    own ``GlobalIndex`` (the Beluga trust-boundary discipline).
    """

    ring_name: str
    n_slots: int
    payload_bytes: int
    pool_shm_name: str
    n_blocks: int
    block_tokens: int
    max_reply: int | None = None
    handler_delay: float = 0.0  # test hook: slow-service torture


def _service_main(spec: ShardServiceSpec) -> None:
    """Child entry: attach, build the shard, spin until CTRL_STOP."""
    from repro.core.index import GlobalIndex
    from repro.core.wire import make_index_handler

    ring = ShmRing.attach(spec.ring_name, spec.n_slots, spec.payload_bytes)
    pool = SharedPoolMeta(spec.pool_shm_name, spec.n_blocks, spec.block_tokens)
    index = GlobalIndex(pool)
    handler = make_index_handler(index, max_reply=spec.max_reply)
    idle = 0
    try:
        # NOTE: no local aliases of ring views here — a surviving view
        # would keep the mapping exported past ring.close() below
        while not ring.ctrl[CTRL_STOP]:
            n = drain_ready(ring, handler, delay=spec.handler_delay)
            if n:
                ring.ctrl[CTRL_SERVED] += n
                idle = 0
            else:
                # the paper's service spins on its OWN core; on an
                # oversubscribed host S pure-spin processes would thrash
                # the scheduler instead, so back off once the ring has
                # been empty for a while (hot-path latency unaffected:
                # the first 200 empty passes still pure-yield)
                idle += 1
                time.sleep(0 if idle < 200 else 100e-6)
    finally:
        ring.close()
        pool.close()


def _mp_context():
    """fork where safe (fast, no re-import); spawn otherwise.

    The child touches only the spec plus objects it constructs itself —
    no inherited locks or threads are ever used — so fork is fine on a
    bare interpreter.  Once jax is loaded, though, its runtime threads
    make fork() formally hazardous (jax warns about deadlocks), so we
    pay the spawn re-import instead: the service import chain
    (rpc/pool/index/wire) is jax-free on purpose, ~0.4 s."""
    import sys

    if "jax" in sys.modules:
        return multiprocessing.get_context("spawn")
    try:
        return multiprocessing.get_context("fork")
    except ValueError:
        return multiprocessing.get_context("spawn")


class ProcessRpcServer:
    """One metadata service OS process behind one shared-memory ring.

    Lifecycle: ``start`` spawns the child; ``stop`` flips the in-band
    CTRL_STOP word and joins (escalating to terminate/kill only if the
    child ignores it); ``close`` additionally releases + unlinks the ring
    segment.  ``atexit`` holds a cleanup hook from construction until
    ``close`` so an interrupted run cannot leak ``/dev/shm`` entries.
    """

    def __init__(
        self,
        pool_spec: dict,
        n_slots: int = 64,
        payload_bytes: int = 1 << 16,
        max_reply: int | None = None,
        handler_delay: float = 0.0,
    ):
        self.ring = ShmRing.create_shared(n_slots, payload_bytes)
        if max_reply is None:
            max_reply = payload_bytes
        self.spec = ShardServiceSpec(
            ring_name=self.ring.shm_name,
            n_slots=n_slots,
            payload_bytes=payload_bytes,
            pool_shm_name=pool_spec["shm_name"],
            n_blocks=pool_spec["n_blocks"],
            block_tokens=pool_spec["block_tokens"],
            max_reply=max_reply,
            handler_delay=handler_delay,
        )
        self.proc = _mp_context().Process(
            target=_service_main, args=(self.spec,), daemon=True
        )
        self._closed = False
        atexit.register(self.close)

    def start(self) -> "ProcessRpcServer":
        self.proc.start()
        return self

    @property
    def served(self) -> int:
        """Requests served, read from the ring's shared control word."""
        ctrl = self.ring.ctrl
        return 0 if ctrl is None else int(ctrl[CTRL_SERVED])

    def alive(self) -> bool:
        """Liveness probe for ``CxlRpcClient(liveness=...)``."""
        proc = self.proc
        return proc is not None and proc.is_alive()

    def kill(self) -> None:
        """Crash the service ungracefully (failure-injection hook)."""
        if self.proc is not None and self.proc.pid is not None:
            self.proc.kill()
            self.proc.join(timeout=5)

    def stop(self, timeout: float = 5.0) -> None:
        proc = self.proc
        if proc is None or proc.pid is None:
            return
        if proc.is_alive() and self.ring.ctrl is not None:
            self.ring.ctrl[CTRL_STOP] = 1  # in-band shutdown request
            proc.join(timeout)
        if proc.is_alive():  # unresponsive child must not stall teardown
            proc.terminate()
            proc.join(1.0)
        if proc.is_alive():
            proc.kill()
            proc.join(1.0)

    def close(self) -> None:
        """Stop the child and release + unlink the ring segment."""
        if self._closed:
            return
        self._closed = True
        try:
            self.stop()
        finally:
            self.ring.close()
            try:
                atexit.unregister(self.close)
            except Exception:  # noqa: BLE001
                pass

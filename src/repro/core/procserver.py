"""Process-per-shard metadata service over shared-memory rings (paper §6).

Beluga's deployment shape is a metadata service that OWNS ITS OWN CORES
and serves clients over plain load/store slots in the shared pool — not a
thread inside the client interpreter.  This module is that shape:

  * ``ProcessRpcServer`` boots ONE OS process per metadata shard.  The
    child never receives a pickled handler, index, or lock: it gets a
    ``ShardServiceSpec`` of plain names/numbers and CONSTRUCTS its own
    ``GlobalIndex`` shard behind the ring — all state it serves lives
    behind the same trust boundary ``prevalidate``/``reply_bound``
    (repro.core.wire) already police, so nothing crosses except framed
    bytes in shared memory;
  * ``SharedPoolMeta`` attaches the pool's epoch/refcount/committed
    arrays exported by ``BelugaPool.share_meta`` — the service validates
    epochs and refcounts against the SAME memory the engines mutate
    (loads on the shared CXL pool state, per the paper), and never
    mutates pool state itself: its ``release`` is deferred — freed block
    ids travel back in the wire reply and the pool-owning process applies
    the real release (``RpcIndexClient(on_freed=pool.release)``);
  * shutdown is in-band too: the parent flips the ring's ``CTRL_STOP``
    word, the child drains and exits; ``atexit`` unlinking plus
    idempotent ``close()`` guarantee no leaked ``/dev/shm`` segments even
    when construction dies half-way;
  * a crashed child is DETECTED, not waited out: clients built with
    ``liveness=server.alive`` turn an abandoned ring into a fast
    ``RpcError`` counted in ``RpcStats.errors``.
"""

from __future__ import annotations

import atexit
import multiprocessing
import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core import diag
from repro.core.locks import make_lock
from repro.core.pool import PoolLayout
from repro.core.rpc import (
    CTRL_BUSY_NS,
    CTRL_DOORBELL,
    CTRL_READY,
    CTRL_SERVED,
    CTRL_STOP,
    ShmRing,
    drain_ready,
)
from repro.core.shm import Doorbell, ShardJournal, attach_segment, close_segment
from repro.distributed.fault_tolerance import HeartbeatMonitor


class SharedPoolMeta:
    """Attach-side, read-only view of a ``BelugaPool``'s metadata arrays.

    Quacks like the pool surface ``GlobalIndex`` needs — ``n_blocks``,
    ``layout.block_tokens``, ``refcounts``, ``validate_epochs`` — over the
    segment ``BelugaPool.share_meta`` exported.  ``release`` is a no-op by
    design: the service process must never mutate allocator state it does
    not own; freed ids are shipped back over the wire instead (see module
    docstring).
    """

    def __init__(self, shm_name: str, n_blocks: int, block_tokens: int):
        self._segment = attach_segment(shm_name)
        self.n_blocks = n_blocks
        # only block_tokens is meaningful service-side (keys arrive
        # pre-hashed over the wire); the rest is filler
        self.layout = PoolLayout(
            block_tokens=block_tokens, n_layers_kv=1, n_kv_heads=1, head_dim=1
        )
        buf = self._segment.buf
        self.epochs = np.frombuffer(buf, np.int64, n_blocks, 0)
        self.refcounts = np.frombuffer(buf, np.int32, n_blocks, 8 * n_blocks)
        self.committed = np.frombuffer(buf, np.bool_, n_blocks, 12 * n_blocks)
        self.data = None  # metadata-only view: payloads never cross here

    def validate_epochs(self, block_ids, epochs) -> np.ndarray:
        ids = np.asarray(block_ids, np.intp)
        return self.committed[ids] & (self.epochs[ids] == np.asarray(epochs))

    def validate_epoch(self, block_id: int, epoch: int) -> bool:
        return bool(self.validate_epochs([block_id], [epoch])[0])

    def release(self, block_ids) -> None:  # noqa: ARG002
        """Deferred: the pool-owning process releases the freed ids when
        the wire reply delivers them (``RpcIndexClient.on_freed``)."""

    def close(self) -> None:
        if self._segment is None:
            return
        self.epochs = self.refcounts = self.committed = None
        close_segment(self._segment, unlink=False)
        self._segment = None


@dataclass(frozen=True)
class ShardServiceSpec:
    """Everything a service child needs to build its shard — plain data.

    No handlers, locks, pools or index objects cross the process
    boundary: the child attaches the named segments and constructs its
    own ``GlobalIndex`` (the Beluga trust-boundary discipline).
    """

    ring_name: str
    n_slots: int
    payload_bytes: int
    pool_shm_name: str
    n_blocks: int
    block_tokens: int
    max_reply: int | None = None
    handler_delay: float = 0.0  # test hook: slow-service torture
    journal_name: str | None = None  # replay source for crash-restart
    journal_capacity: int = 0
    idle_spin_passes: int = 200  # empty passes before sleeping at all
    idle_backoff_s: float = 100e-6  # ceiling once the ring has gone cold
    doorbell_name: str | None = None  # FIFO path: park instead of backoff
    doorbell_wait_s: float = 0.05  # bounded park (lost-wakeup ceiling)


def _service_main(spec: ShardServiceSpec) -> None:
    """Child entry: attach, replay the journal, spin until CTRL_STOP."""
    from repro.core.index import GlobalIndex
    from repro.core.wire import make_index_handler

    ring = ShmRing.attach(spec.ring_name, spec.n_slots, spec.payload_bytes)
    pool = SharedPoolMeta(spec.pool_shm_name, spec.n_blocks, spec.block_tokens)
    index = GlobalIndex(pool)
    if spec.journal_name is not None:
        # crash-restart rebuild: replay the pool owner's publish journal
        # BEFORE advertising readiness, so the first request a client
        # lands after adopt_ring already sees the pre-crash entries
        journal = ShardJournal.attach(spec.journal_name, spec.journal_capacity)
        try:
            index.rebuild_from_journal(journal.records())
        finally:
            journal.close()
    handler = make_index_handler(index, max_reply=spec.max_reply, ctrl=ring.ctrl)
    doorbell = None
    if spec.doorbell_name is not None:
        doorbell = Doorbell.attach(spec.doorbell_name)
        doorbell.open_read()  # producers must always find a live reader
    ring.ctrl[CTRL_READY] = 1  # supervisor gates adopt_ring on this word
    idle = 0
    try:
        # NOTE: no ring-view aliases beyond `handler`'s ctrl capture —
        # `handler` is dropped below before ring.close() so no surviving
        # view keeps the mapping exported
        while not ring.ctrl[CTRL_STOP]:
            # drain_ready accounts CTRL_SERVED / CTRL_BUSY_NS itself
            if drain_ready(ring, handler, delay=spec.handler_delay):
                idle = 0
                continue
            # the paper's service spins on its OWN core; on an
            # oversubscribed host S pure-spin processes would thrash the
            # scheduler instead.  Hot-path latency is unaffected either
            # way: the first idle_spin_passes empty passes pure-yield.
            # Past that, a doorbell PARKS the child (arm the ctrl word,
            # close the arm/post race with one re-scan, bounded wait);
            # without one, fall back to the configurable backoff sleep.
            idle += 1
            if idle < spec.idle_spin_passes:
                time.sleep(0)
            elif doorbell is None:
                time.sleep(spec.idle_backoff_s)
            else:
                ring.ctrl[CTRL_DOORBELL] = 1
                try:
                    if drain_ready(ring, handler, delay=spec.handler_delay):
                        idle = 0
                        continue
                    doorbell.wait(spec.doorbell_wait_s)
                finally:
                    ring.ctrl[CTRL_DOORBELL] = 0
    finally:
        handler = None  # noqa: F841 — drop the ctrl view before close
        if doorbell is not None:
            doorbell.close()  # attach-side: drops fds, never unlinks
        ring.close()
        pool.close()


def _mp_context():
    """fork where safe (fast, no re-import); spawn otherwise.

    The child touches only the spec plus objects it constructs itself —
    no inherited locks or threads are ever used — so fork is fine on a
    bare interpreter.  Once jax is loaded, though, its runtime threads
    make fork() formally hazardous (jax warns about deadlocks), so we
    pay the spawn re-import instead: the service import chain
    (rpc/pool/index/wire) is jax-free on purpose, ~0.4 s."""
    import sys

    if "jax" in sys.modules:
        return multiprocessing.get_context("spawn")
    try:
        return multiprocessing.get_context("fork")
    except ValueError:
        return multiprocessing.get_context("spawn")


class ProcessRpcServer:
    """One metadata service OS process behind one shared-memory ring.

    Lifecycle: ``start`` spawns the child; ``stop`` flips the in-band
    CTRL_STOP word and joins (escalating to terminate/kill only if the
    child ignores it); ``close`` additionally releases + unlinks the ring
    segment.  ``atexit`` holds a cleanup hook from construction until
    ``close`` so an interrupted run cannot leak ``/dev/shm`` entries.
    """

    def __init__(
        self,
        pool_spec: dict,
        n_slots: int = 64,
        payload_bytes: int = 1 << 16,
        max_reply: int | None = None,
        handler_delay: float = 0.0,
        journal: ShardJournal | None = None,
        idle_spin_passes: int = 200,
        idle_backoff_s: float = 100e-6,
        use_doorbell: bool = True,
        doorbell_wait_s: float = 0.05,
    ):
        self.ring = ShmRing.create_shared(n_slots, payload_bytes)
        # parked child instead of backoff-sleeping child; Doorbell.create
        # returning None (no mkfifo on this platform) falls back to the
        # spin/backoff loop transparently
        self.doorbell = Doorbell.create() if use_doorbell else None
        if max_reply is None:
            max_reply = payload_bytes
        self.spec = ShardServiceSpec(
            ring_name=self.ring.shm_name,
            n_slots=n_slots,
            payload_bytes=payload_bytes,
            pool_shm_name=pool_spec["shm_name"],
            n_blocks=pool_spec["n_blocks"],
            block_tokens=pool_spec["block_tokens"],
            max_reply=max_reply,
            handler_delay=handler_delay,
            journal_name=None if journal is None else journal.name,
            journal_capacity=0 if journal is None else journal.capacity,
            idle_spin_passes=idle_spin_passes,
            idle_backoff_s=idle_backoff_s,
            doorbell_name=None if self.doorbell is None else self.doorbell.path,
            doorbell_wait_s=doorbell_wait_s,
        )
        self.proc = _mp_context().Process(
            target=_service_main, args=(self.spec,), daemon=True
        )
        self._closed = False
        atexit.register(self.close)

    def start(self) -> "ProcessRpcServer":
        self.proc.start()
        return self

    @property
    def served(self) -> int:
        """Requests served, read from the ring's shared control word."""
        ctrl = self.ring.ctrl
        return 0 if ctrl is None else int(ctrl[CTRL_SERVED])

    @property
    def busy_ns(self) -> int:
        """Nanoseconds the child spent inside handlers (service-side timer)."""
        ctrl = self.ring.ctrl
        return 0 if ctrl is None else int(ctrl[CTRL_BUSY_NS])

    @property
    def ready(self) -> bool:
        """True once the child finished journal replay and is serving."""
        ctrl = self.ring.ctrl
        return ctrl is not None and bool(ctrl[CTRL_READY])

    def wait_ready(self, timeout: float = 10.0) -> bool:
        """Block until the child advertises CTRL_READY (or it dies)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.ready:
                return True
            if self.proc is not None and self.proc.pid is not None \
                    and not self.proc.is_alive():
                return False
            time.sleep(1e-3)
        return self.ready

    def alive(self) -> bool:
        """Liveness probe for ``CxlRpcClient(liveness=...)``."""
        proc = self.proc
        return proc is not None and proc.is_alive()

    def client_doorbell(self) -> Doorbell | None:
        """Producer-side handle for clients of this ring (None when the
        service falls back to spin/backoff)."""
        return None if self.doorbell is None else Doorbell.attach(
            self.doorbell.path
        )

    def kill(self) -> None:
        """Crash the service ungracefully (failure-injection hook)."""
        if self.proc is not None and self.proc.pid is not None:
            self.proc.kill()
            self.proc.join(timeout=5)

    def stop(self, timeout: float = 5.0) -> None:
        proc = self.proc
        if proc is None or proc.pid is None:
            return
        if proc.is_alive() and self.ring.ctrl is not None:
            self.ring.ctrl[CTRL_STOP] = 1  # in-band shutdown request
            if self.doorbell is not None:
                self.doorbell.ring()  # wake a parked child immediately
            proc.join(timeout)
        if proc.is_alive():  # unresponsive child must not stall teardown
            proc.terminate()
            proc.join(1.0)
        if proc.is_alive():
            proc.kill()
            proc.join(1.0)

    def close(self) -> None:
        """Stop the child and release + unlink the ring segment."""
        if self._closed:
            return
        self._closed = True
        try:
            self.stop()
        finally:
            self.ring.close()
            if self.doorbell is not None:
                self.doorbell.close()  # owner: unlinks the FIFO path
            try:
                atexit.unregister(self.close)
            except Exception:  # noqa: BLE001
                diag.note("procserver.server_close.unregister_failed")


class ShardSupervisor:
    """Keep one metadata shard alive across crashes (self-healing plane).

    Owns the shard's ``ShardJournal`` and a succession of
    ``ProcessRpcServer`` generations.  A probe thread feeds a
    ``HeartbeatMonitor`` (the shared liveness policy from
    ``repro.distributed.fault_tolerance``) with ``proc.is_alive()``
    beats; once the grace window expires without one, the supervisor

      1. reaps the corpse (``stop`` — join, never unlink yet),
      2. boots a FRESH ring + child from the same spec (the old ring may
         hold slots a request died in; a fresh ring needs no slot-state
         forensics),
      3. waits for ``CTRL_READY`` — the child replays the journal
         BEFORE advertising it, so the rebuilt index already holds every
         confirmed pre-crash publish,
      4. cuts every registered client over via ``adopt_ring`` (which
         resets slot bookkeeping and bumps ``RpcStats.restarts``).

    In-flight ``collect`` calls on the old ring notice the swap (ring
    identity check) and raise ``ServiceDiedError`` — a retryable verdict,
    so the client's retry loop re-posts onto the new ring.  Retired rings
    are only closed at ``close()``: another thread may still be spinning
    on old-ring views, and unmapping under it would turn a clean
    ``ServiceDiedError`` into a segfault-shaped surprise.

    Detection latency is bounded by ``probe_interval + grace`` and is
    DECOUPLED from the child's idle backoff (spec knobs) — see
    tests/test_selfheal.py.
    """

    def __init__(
        self,
        pool_spec: dict,
        *,
        journal_capacity: int = 4096,
        probe_interval: float = 0.02,
        grace: float | None = None,
        max_restarts: int = 16,
        snapshot_interval: float | None = None,
        **server_kwargs,
    ):
        self._pool_spec = pool_spec
        self._server_kwargs = dict(server_kwargs)
        self.journal = ShardJournal.create(journal_capacity)
        self.probe_interval = probe_interval
        self.grace = 2 * probe_interval if grace is None else grace
        self.max_restarts = max_restarts
        # warm-snapshot cadence (None = journal-only rebuild): the probe
        # thread periodically pages the live shard's LRU order + hit/miss
        # counters so a respawn can restore recency, not just entries
        self.snapshot_interval = snapshot_interval
        self._snapshot: tuple[list, int, int] | None = None
        self.restarts = 0
        self.server = ProcessRpcServer(
            pool_spec, journal=self.journal, **self._server_kwargs
        )
        self._retired: list[ProcessRpcServer] = []
        self._clients: list = []  # CxlRpcClient-shaped: has adopt_ring
        self._monitor = HeartbeatMonitor(n_hosts=1, timeout_s=self.grace)
        # blocking_ok: this lock EXISTS to serialize the blocking restart
        # section (stop/join the corpse, boot + wait_ready the successor,
        # warm-restore over RPC) against concurrent check()/close() —
        # see the class docstring; data-plane traffic never takes it
        self._lock = make_lock(
            "procserver.ShardSupervisor._lock", blocking_ok=True
        )
        self._stop = threading.Event()
        self._probe: threading.Thread | None = None
        self._closed = False
        atexit.register(self.close)

    # -- wiring ----------------------------------------------------------
    @property
    def ring(self) -> ShmRing:
        return self.server.ring

    def alive(self) -> bool:
        """Liveness of the CURRENT generation (client ``liveness=``)."""
        return self.server.alive()

    def register_client(self, client) -> None:
        """Clients to cut over (``adopt_ring``) after each restart."""
        with self._lock:
            self._clients.append(client)

    def start(self) -> "ShardSupervisor":
        self.server.start()
        self._monitor.beat(0)
        self._stop.clear()
        self._probe = threading.Thread(
            target=self._probe_loop, name="shard-supervisor", daemon=True
        )
        self._probe.start()
        return self

    def wait_ready(self, timeout: float = 10.0) -> bool:
        return self.server.wait_ready(timeout)

    # -- stats (cumulative across generations) ---------------------------
    @property
    def served(self) -> int:
        return self.server.served + sum(s.served for s in self._retired)

    @property
    def busy_ns(self) -> int:
        return self.server.busy_ns + sum(s.busy_ns for s in self._retired)

    def segment_names(self) -> list[str]:
        """Every /dev/shm name this supervisor owns (hygiene checks)."""
        names = [self.journal.name, self.server.ring.shm_name]
        names += [s.ring.shm_name for s in self._retired]
        return names

    def client_doorbell(self) -> Doorbell | None:
        """Producer handle on the CURRENT generation's doorbell."""
        return self.server.client_doorbell()

    def doorbell_paths(self) -> list[str]:
        """Every FIFO path this supervisor owns (hygiene checks)."""
        servers = [self.server, *self._retired]
        return [s.doorbell.path for s in servers if s.doorbell is not None]

    # -- failure handling ------------------------------------------------
    def kill(self) -> None:
        """Crash the current child ungracefully (chaos hook)."""
        self.server.kill()

    def _probe_loop(self) -> None:
        last_snap = time.monotonic()
        while not self._stop.wait(self.probe_interval):
            with self._lock:
                if self._closed:
                    return
                if self.server.alive():
                    self._monitor.beat(0)
                    if (
                        self.snapshot_interval is not None
                        and time.monotonic() - last_snap
                        >= self.snapshot_interval
                    ):
                        self.capture_snapshot()
                        last_snap = time.monotonic()
                elif self._monitor.dead_hosts():
                    self._restart_locked()
                    self._monitor.beat(0)

    def capture_snapshot(self) -> bool:
        """Page the live shard (LRU order + hit/miss counters) into the
        supervisor's warm snapshot.

        Best-effort by design: the positional snapshot cursor pages a
        LIVE index, so concurrent mutation can tear a page — the restore
        path re-validates every entry against the journal's live state,
        so a torn page degrades warmth, never correctness.  Uses the
        first registered client that can ``call`` (slot acquisition is
        thread-safe), returns False when there is none or the page
        failed."""
        client = next(
            (c for c in self._clients if hasattr(c, "call")), None
        )
        if client is None or not self.server.alive():
            return False
        from repro.core import wire

        try:
            entries: list[tuple[bytes, int, int, int]] = []
            start = 0
            page = max(1, (self.server.spec.payload_bytes - 24) // 36)
            while True:
                total, keys, ids, eps, ntk = wire.decode_snapshot_resp(
                    client.call(wire.encode_snapshot(start, page))
                )
                entries.extend(zip(keys, ids, eps, ntk))
                start += len(keys)
                if start >= total or not keys:
                    break
            _, hits, misses, _, _ = wire.decode_stats_resp(
                client.call(wire.encode_stats())
            )
        except Exception:  # noqa: BLE001 — a failed capture keeps the old one
            diag.note("procserver.capture_snapshot.failed")
            return False
        self._snapshot = (entries, hits, misses)
        return True

    def _apply_snapshot(self, srv: ProcessRpcServer) -> None:
        """Warm-restore a freshly respawned child from the last snapshot.

        The child already replayed the journal (entries are complete but
        in journal order, counters zeroed); re-publishing the snapshot's
        entries in ITS order rebuilds the pre-crash LRU recency (publish
        re-touches), and OP_SEED_STATS restores the hit/miss counters.
        Every snapshot entry is validated against the journal's CURRENT
        live state first — an entry retracted or remapped since the
        capture must not resurrect (a resurrected stale row could
        double-free its block at eviction).  Best-effort: any failure
        leaves the journal rebuild as the contract."""
        snap = self._snapshot
        if snap is None:
            return
        entries, hits, misses = snap
        from repro.core import wire
        from repro.core.rpc import CxlRpcClient
        from repro.core.shm import live_entries

        live = live_entries(self.journal.records())
        keep = [
            (k, b, e, t)
            for k, b, e, t in entries
            if (lv := live.get(k)) is not None and lv[0] == b and lv[1] == e
        ]
        client = CxlRpcClient(srv.ring, liveness=srv.alive)
        page = max(1, (srv.spec.payload_bytes - 24) // 36)
        try:
            for off in range(0, len(keep), page):
                chunk = keep[off : off + page]
                client.call(wire.encode_restore(
                    [k for k, _, _, _ in chunk],
                    [b for _, b, _, _ in chunk],
                    [e for _, _, e, _ in chunk],
                    [t for _, _, _, t in chunk],
                ))
            client.call(wire.encode_seed_stats(hits, misses))
        except Exception:  # noqa: BLE001 — warmth is optional, healing is not
            diag.note("procserver.apply_snapshot.failed")

    def _restart_locked(self) -> None:
        if self.restarts >= self.max_restarts:
            return  # flapping shard: stop resuscitating, clients degrade
        old = self.server
        old.stop()  # reap; ring segment stays mapped until close()
        if old.ring.ctrl is not None:
            # a kill -9'd child never saw the stop word; flip it anyway so
            # CTRL_STOP-based liveness probes (engine workers share no
            # process handle with this supervisor) fail fast on the
            # retired ring instead of burning full RPC timeouts
            old.ring.ctrl[CTRL_STOP] = 1
        self._retired.append(old)
        srv = ProcessRpcServer(
            self._pool_spec, journal=self.journal, **self._server_kwargs
        )
        srv.start()
        self.server = srv
        self.restarts += 1
        if not srv.wait_ready(timeout=10.0):
            return  # replacement stillborn; next probe pass retries
        self._apply_snapshot(srv)
        for client in self._clients:
            client.adopt_ring(
                srv.ring, liveness=srv.alive, doorbell=srv.client_doorbell()
            )

    def check(self) -> None:
        """Synchronous probe step (tests drive restarts without waiting
        out the probe thread's schedule)."""
        with self._lock:
            if self._closed:
                return
            if self.server.alive():
                self._monitor.beat(0)
            elif self._monitor.dead_hosts():
                self._restart_locked()
                self._monitor.beat(0)

    def close(self) -> None:
        if self._closed:
            return
        with self._lock:
            self._closed = True
        self._stop.set()
        if self._probe is not None and self._probe.is_alive():
            self._probe.join(timeout=5)
        self.server.close()
        for srv in self._retired:
            srv.close()
        self._retired.clear()
        self.journal.close()
        try:
            atexit.unregister(self.close)
        except Exception:  # noqa: BLE001
            diag.note("procserver.supervisor_close.unregister_failed")

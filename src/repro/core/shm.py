"""Named shared-memory segment helpers (cross-process metadata plane).

The process-per-shard metadata service (``repro.core.procserver``) and the
shared-memory ring (``repro.core.rpc.ShmRing``) both attach plain
``multiprocessing.shared_memory`` segments by name — the repro stand-in
for the paper's CXL pool mappings (every participant sees the same bytes
via load/store, nothing is pickled across the trust boundary).

Two wrinkles this module hides:

  * on Python < 3.13 *attaching* a segment registers it with the
    ``resource_tracker`` as if the attacher owned it, so the tracker
    unlinks (and warns about) segments it does not own when the attaching
    process exits.  ``attach_segment`` unregisters after attach — only
    the CREATOR of a segment may unlink it;
  * numpy views keep the mapping exported: ``close_segment`` drops the
    caller's views first (caller passes/clears them), then retries the
    close through a ``gc.collect()`` so a lingering view cannot turn
    shutdown into a ``BufferError`` crash.
"""

from __future__ import annotations

import gc
from multiprocessing import shared_memory


def create_segment(size: int) -> shared_memory.SharedMemory:
    """Create a zero-filled named segment (caller owns the unlink)."""
    seg = shared_memory.SharedMemory(create=True, size=size)
    seg.buf[:] = bytes(len(seg.buf))  # deterministic start state
    return seg


def attach_segment(name: str) -> shared_memory.SharedMemory:
    """Attach an existing segment WITHOUT adopting unlink responsibility.

    Python < 3.13 registers *attachers* with the resource tracker as if
    they owned the segment, which (a) makes a spawned child's tracker
    unlink a segment the parent still uses when the child exits, and
    (b) under fork's shared tracker makes unregister-after-attach delete
    the creator's registration.  Suppressing the register call during
    attach avoids both; the creator's registration (and unlink duty) is
    untouched."""
    try:
        from multiprocessing import resource_tracker

        orig_register = resource_tracker.register
        resource_tracker.register = lambda *a, **k: None
        try:
            return shared_memory.SharedMemory(name=name)
        finally:
            resource_tracker.register = orig_register
    except ImportError:  # no tracker on this platform: plain attach
        return shared_memory.SharedMemory(name=name)


def close_segment(seg: shared_memory.SharedMemory | None, *, unlink: bool) -> None:
    """Close (and optionally unlink) a segment; tolerate stale views.

    Idempotent and safe under double-close/unlink: lifecycle teardown runs
    from ``Cluster.close``, ``atexit`` hooks AND test cleanups, any of
    which may win the race.
    """
    if seg is None:
        return
    try:
        seg.close()
    except BufferError:
        gc.collect()  # a dropped numpy view still held the export
        try:
            seg.close()
        except BufferError:
            pass
    except Exception:  # noqa: BLE001
        pass
    if unlink:
        try:
            seg.unlink()
        except FileNotFoundError:
            pass
        except Exception:  # noqa: BLE001
            pass

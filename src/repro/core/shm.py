"""Named shared-memory segment helpers (cross-process metadata plane).

The process-per-shard metadata service (``repro.core.procserver``) and the
shared-memory ring (``repro.core.rpc.ShmRing``) both attach plain
``multiprocessing.shared_memory`` segments by name — the repro stand-in
for the paper's CXL pool mappings (every participant sees the same bytes
via load/store, nothing is pickled across the trust boundary).

Two wrinkles this module hides:

  * on Python < 3.13 *attaching* a segment registers it with the
    ``resource_tracker`` as if the attacher owned it, so the tracker
    unlinks (and warns about) segments it does not own when the attaching
    process exits.  ``attach_segment`` unregisters after attach — only
    the CREATOR of a segment may unlink it;
  * numpy views keep the mapping exported: ``close_segment`` drops the
    caller's views first (caller passes/clears them), then retries the
    close through a ``gc.collect()`` so a lingering view cannot turn
    shutdown into a ``BufferError`` crash.
"""

from __future__ import annotations

import gc
import os
import secrets
import select
import struct
import tempfile
from multiprocessing import shared_memory

from repro.core import diag
from repro.core.locks import make_lock


def create_segment(size: int) -> shared_memory.SharedMemory:
    """Create a zero-filled named segment (caller owns the unlink)."""
    seg = shared_memory.SharedMemory(create=True, size=size)
    seg.buf[:] = bytes(len(seg.buf))  # deterministic start state
    return seg


def attach_segment(name: str) -> shared_memory.SharedMemory:
    """Attach an existing segment WITHOUT adopting unlink responsibility.

    Python < 3.13 registers *attachers* with the resource tracker as if
    they owned the segment, which (a) makes a spawned child's tracker
    unlink a segment the parent still uses when the child exits, and
    (b) under fork's shared tracker makes unregister-after-attach delete
    the creator's registration.  Suppressing the register call during
    attach avoids both; the creator's registration (and unlink duty) is
    untouched."""
    try:
        from multiprocessing import resource_tracker

        orig_register = resource_tracker.register
        resource_tracker.register = lambda *a, **k: None
        try:
            return shared_memory.SharedMemory(name=name)
        finally:
            resource_tracker.register = orig_register
    except ImportError:  # no tracker on this platform: plain attach
        return shared_memory.SharedMemory(name=name)


def close_segment(seg: shared_memory.SharedMemory | None, *, unlink: bool) -> None:
    """Close (and optionally unlink) a segment; tolerate stale views.

    Idempotent and safe under double-close/unlink: lifecycle teardown runs
    from ``Cluster.close``, ``atexit`` hooks AND test cleanups, any of
    which may win the race.
    """
    if seg is None:
        return
    try:
        seg.close()
    except BufferError:
        gc.collect()  # a dropped numpy view still held the export
        try:
            seg.close()
        except BufferError:
            pass
    except Exception:  # noqa: BLE001
        diag.note("shm.close_segment.close_failed")
    if unlink:
        try:
            seg.unlink()
        except FileNotFoundError:
            pass
        except Exception:  # noqa: BLE001
            diag.note("shm.close_segment.unlink_failed")


# ---------------------------------------------------------------------------
# doorbell: cross-process wakeup for idle ring consumers
# ---------------------------------------------------------------------------
class Doorbell:
    """Edge-triggered wakeup channel for a ring consumer (named FIFO).

    Replaces pure busy-poll on an idle ring: the consumer ARMS the ring's
    ``CTRL_DOORBELL`` word, re-scans once, then blocks here; a producer
    that posts a request while the word is armed writes one byte into the
    FIFO and the consumer wakes.  A FIFO rather than an eventfd because it
    attaches BY PATH — exactly like the named shared-memory segments the
    rest of the plane uses — so it crosses a spawn-based process boundary
    with nothing but a string in the service spec (fds don't).

    Lost-wakeup safety is a protocol property, not a channel property:

      1. the waiter sets ``ctrl[CTRL_DOORBELL] = 1`` FIRST, re-scans the
         ring, and only then blocks in ``wait`` — so a request posted
         after the scan sees the armed word and rings;
      2. ``wait`` is BOUNDED (``timeout``): a ring lost to the tiny
         arm/post race (or to a producer whose FIFO open failed) costs at
         most one timeout of latency, never a hang;
      3. spurious rings are harmless: ``wait`` drains the FIFO and the
         serve loop re-scans anyway.

    The waiter opens the FIFO ``O_RDWR`` (it becomes its own phantom
    writer) so zero-producer moments read EAGAIN instead of EOF — a plain
    ``O_RDONLY`` FIFO with no writers is permanently "readable", which
    would turn ``select`` into a busy spin.  Producers open
    ``O_WRONLY | O_NONBLOCK`` lazily and tolerate ENXIO (no reader yet),
    a full pipe (a wakeup is already pending) and a vanished reader.

    The CREATOR owns the path unlink (same rule as the shm segments);
    attach-side ``close`` only drops fds.
    """

    def __init__(self, path: str, *, _owner: bool):
        self.path = path
        self._owner = _owner
        self._rfd: int | None = None
        self._wfd: int | None = None
        self._closed = False

    @classmethod
    def create(cls) -> "Doorbell | None":
        """New FIFO in tmpdir; None when the platform has no mkfifo
        (callers fall back to the configurable spin/backoff poll)."""
        path = os.path.join(
            tempfile.gettempdir(),
            f"beluga-doorbell-{os.getpid()}-{secrets.token_hex(6)}",
        )
        try:
            os.mkfifo(path)
        except (AttributeError, NotImplementedError, OSError):
            return None
        return cls(path, _owner=True)

    @classmethod
    def attach(cls, path: str) -> "Doorbell":
        """Consumer/producer-side handle on an existing FIFO (by path)."""
        return cls(path, _owner=False)

    # -- consumer side ---------------------------------------------------
    def open_read(self) -> None:
        """Open the read end eagerly (before the first arm, so a producer
        that sees the armed word can always reach a live reader)."""
        if self._rfd is None and not self._closed:
            self._rfd = os.open(self.path, os.O_RDWR | os.O_NONBLOCK)

    def wait(self, timeout: float) -> bool:
        """Block until rung (or ``timeout`` seconds); drains pending
        rings.  Returns True when a ring arrived."""
        self.open_read()
        if self._rfd is None:
            return False
        try:
            readable, _, _ = select.select([self._rfd], [], [], timeout)
        except OSError:
            return False
        woke = bool(readable)
        while True:  # edge-triggered: swallow every pending byte
            try:
                if not os.read(self._rfd, 4096):
                    break
            except BlockingIOError:
                break
            except OSError:
                break
        return woke

    # -- producer side ---------------------------------------------------
    def ring(self) -> bool:
        """One wakeup byte; False (never raises) when no reader exists."""
        if self._closed:
            return False
        if self._wfd is None:
            try:
                self._wfd = os.open(self.path, os.O_WRONLY | os.O_NONBLOCK)
            except OSError:  # ENXIO: no reader yet — nothing to wake
                return False
        try:
            os.write(self._wfd, b"\x01")
            return True
        except BlockingIOError:
            return True  # FIFO full: a wakeup is already pending
        except OSError:  # reader vanished; drop the stale fd
            try:
                os.close(self._wfd)
            except OSError:
                pass
            self._wfd = None
            return False

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        """Idempotent: drop fds; the creator also unlinks the path."""
        if self._closed:
            return
        self._closed = True
        for fd in (self._rfd, self._wfd):
            if fd is not None:
                try:
                    os.close(fd)
                except OSError:
                    pass
        self._rfd = self._wfd = None
        if self._owner:
            try:
                os.unlink(self.path)
            except OSError:
                pass


# ---------------------------------------------------------------------------
# per-shard publish journal (the self-healing metadata plane's flight
# recorder — see ``repro.core.procserver.ShardSupervisor``)
# ---------------------------------------------------------------------------
JOURNAL_PUBLISH, JOURNAL_RETRACT, JOURNAL_REMAP = 1, 2, 3


def live_entries(records) -> dict[bytes, tuple[int, int, int]]:
    """Fold a journal record stream into the surviving index entries.

    Returns an insertion-ordered ``key -> (block_id, epoch, n_tokens)``
    map: the state a shard's ``GlobalIndex`` replays to after a crash
    (``GlobalIndex.rebuild_from_journal``), and the state a compaction
    rewrites the journal down to.

      * PUBLISH upserts the key (a re-publish moves it to the end — the
        MRU approximation of the single-publish LRU refresh);
      * RETRACT (an eviction's freed block id) removes the key that LAST
        published that block — exactly the row the index dropped.  Stale
        alias rows (an older key whose block was recycled under a new
        key) survive, as they do in the live index;
      * REMAP re-points an existing key to its migrated (block, epoch),
        keeping n_tokens (the payload moved tiers, the tokens did not).
    """
    live: dict[bytes, list[int]] = {}
    block2key: dict[int, bytes] = {}
    for op, key, bid, epoch, ntk in records:
        if op == JOURNAL_PUBLISH:
            if key in live:
                del live[key]  # move to end: re-publish refreshes LRU
            live[key] = [bid, epoch, ntk]
            block2key[bid] = key
        elif op == JOURNAL_RETRACT:
            k = block2key.pop(bid, None)
            if k is not None and k in live and live[k][0] == bid:
                del live[k]
        elif op == JOURNAL_REMAP:
            ent = live.get(key)
            if ent is not None:
                old = ent[0]
                if block2key.get(old) == key:
                    del block2key[old]
                ent[0] = bid
                ent[1] = epoch
                block2key[bid] = key
    return {k: (v[0], v[1], v[2]) for k, v in live.items()}


class ShardJournal:
    """Append-only per-shard publish journal in a named segment.

    The pool-OWNING process (the RPC client side — the only place that
    knows an op actually round-tripped) appends one fixed-size record per
    observable index mutation it drove: publish, eviction (retract by
    freed block id), remap.  A respawned shard service replays the
    journal at boot (``GlobalIndex.rebuild_from_journal``) before
    serving, so a kill -9 of the service loses no published block.

    Crash-atomicity contract: a record is appended only AFTER the RPC
    reply confirmed the mutation.  A mutation applied server-side whose
    reply was lost to the crash is therefore NOT replayed — for publish
    that's safe (the client retries and re-publishes idempotently), for
    evict it's safe by omission (``on_freed`` never ran, the pool still
    holds the block, and the rebuilt index still owns it — nothing is
    lost or double-freed).

    Layout: header ``generation:u64 count:u64 capacity:u64`` then
    ``capacity`` records ``op:u8 key:16s block_id:i64 epoch:i64
    n_tokens:i32`` (37 B).  Single writer (the pool owner, lock inside);
    the only reader is a BOOTING shard service, whose ring is down — the
    journal is quiescent for the whole read.  On overflow the writer
    compacts in place (rewrite of ``live_entries`` as pure publishes)
    and bumps ``generation``.
    """

    _HDR = struct.Struct("<QQQ")  # generation, count, capacity
    _REC = struct.Struct("<B16sqqi")  # op, key, block_id, epoch, n_tokens

    def __init__(self, seg: shared_memory.SharedMemory, capacity: int,
                 *, _owner: bool):
        self._seg = seg
        self._owner = _owner
        self.capacity = capacity
        self.name = seg.name
        self._lock = make_lock("shm.ShardJournal._lock")

    @classmethod
    def segment_size(cls, capacity: int) -> int:
        return cls._HDR.size + capacity * cls._REC.size

    @classmethod
    def create(cls, capacity: int) -> "ShardJournal":
        seg = create_segment(cls.segment_size(capacity))
        j = cls(seg, capacity, _owner=True)
        cls._HDR.pack_into(seg.buf, 0, 0, 0, capacity)
        return j

    @classmethod
    def attach(cls, name: str, capacity: int) -> "ShardJournal":
        seg = attach_segment(name)
        j = cls(seg, capacity, _owner=False)
        _, _, cap = cls._HDR.unpack_from(seg.buf, 0)
        if cap != capacity:
            raise ValueError(
                f"journal {name}: capacity mismatch (segment {cap}, spec {capacity})"
            )
        return j

    # -- header ----------------------------------------------------------
    @property
    def generation(self) -> int:
        return self._HDR.unpack_from(self._seg.buf, 0)[0]

    def __len__(self) -> int:
        return self._HDR.unpack_from(self._seg.buf, 0)[1]

    def _set_header(self, generation: int, count: int) -> None:
        self._HDR.pack_into(self._seg.buf, 0, generation, count, self.capacity)

    # -- records ---------------------------------------------------------
    def _write_rec(self, i: int, op: int, key: bytes, bid: int, epoch: int,
                   ntk: int) -> None:
        self._REC.pack_into(
            self._seg.buf, self._HDR.size + i * self._REC.size,
            op, key, bid, epoch, ntk,
        )

    def records(self) -> list[tuple[int, bytes, int, int, int]]:
        """Decode every committed record (op, key, block_id, epoch, n_tokens)."""
        gen, count, _ = self._HDR.unpack_from(self._seg.buf, 0)
        out = []
        off = self._HDR.size
        for _ in range(count):
            op, key, bid, epoch, ntk = self._REC.unpack_from(self._seg.buf, off)
            out.append((op, key, bid, epoch, ntk))
            off += self._REC.size
        return out

    def _append(self, recs) -> None:
        with self._lock:
            gen, count, _ = self._HDR.unpack_from(self._seg.buf, 0)
            if count + len(recs) > self.capacity:
                live = live_entries(self.records())
                if len(live) + len(recs) > self.capacity:
                    raise RuntimeError(
                        f"journal {self.name} overflow: {len(live)} live + "
                        f"{len(recs)} new > capacity {self.capacity}"
                    )
                # compact in place: the live map as pure publishes
                for i, (k, (bid, epoch, ntk)) in enumerate(live.items()):
                    self._write_rec(i, JOURNAL_PUBLISH, k, bid, epoch, ntk)
                gen, count = gen + 1, len(live)
            for op, key, bid, epoch, ntk in recs:
                self._write_rec(count, op, key, bid, epoch, ntk)
                count += 1
            # count is published LAST: a reader attached mid-append never
            # sees a half-written record as committed
            self._set_header(gen, count)

    def append_publish(self, keys, block_ids, epochs, n_tokens: int) -> None:
        self._append([
            (JOURNAL_PUBLISH, k, int(b), int(e), n_tokens)
            for k, b, e in zip(keys, block_ids, epochs)
        ])

    def append_retract(self, block_ids) -> None:
        self._append([
            (JOURNAL_RETRACT, b"\0" * 16, int(b), 0, 0) for b in block_ids
        ])

    def append_remap(self, keys, new_ids, new_epochs) -> None:
        self._append([
            (JOURNAL_REMAP, k, int(b), int(e), -1)
            for k, b, e in zip(keys, new_ids, new_epochs)
        ])

    def close(self) -> None:
        close_segment(self._seg, unlink=self._owner)
        self._seg = None

"""Attach-side view of a shared-data ``BelugaPool`` (zero-copy data plane).

``BelugaPool.share_data`` re-homes the pool's block payload array — not
just its metadata — into one named shared-memory segment.  This module is
the OTHER side of that export: what an engine worker OS process
(``repro.serving.engineproc``) maps to scatter/gather KV blocks directly
against the modeled CXL pool, with zero payload copies through the parent
interpreter.

Division of labour across the process boundary:

  * payload loads/stores and epoch publication happen HERE, on the shared
    arrays (``SharedPoolData``) — the paper's native load/store path;
  * allocate/retain/release stay with the pool-owning parent and travel
    over a ring (``repro.core.wire.PoolRpcClient``) — the allocator's free
    stacks are ordinary Python state that must have exactly one owner;
  * ``WorkerPoolView`` glues the two into the full pool surface
    ``KVCacheManager`` + ``TransferEngine`` expect, so the serving stack
    runs unmodified inside a worker.

Why payload stores need NO cross-process lock (paper §5.1): a block is
written only between ``allocate`` (exclusive ownership to one worker) and
``publish`` (after which every toucher is a reader until refcount hits
zero back in the owning pool).  The only concurrent epoch mutation is the
pool owner's release-side bump, which by the same contract only targets
blocks no worker is writing.  Torn int64 reads on the shared epoch array
are theoretical on the platforms this runs on (aligned 8-byte loads), and
the committed-flag check backstops them.
"""

from __future__ import annotations

import numpy as np

from repro.core.pool import PoolLayout
from repro.core.shm import attach_segment, close_segment


class SharedPoolData:
    """Attach-side view of ``BelugaPool.share_data``'s segments.

    Maps BOTH exports — the payload segment and the metadata segment that
    ``share_data`` implies — and rebuilds the real ``PoolLayout`` from the
    spec, so fragment math matches the owner exactly.  Mirrors the
    ``BelugaPool`` data-plane surface (``write_blocks`` / ``read_blocks``
    / ``validate_epochs`` / ``read_fragments``); never unlinks on close
    (the creator owns unlink, same rule as every segment in the plane).
    """

    def __init__(self, spec: dict):
        self.layout = PoolLayout(
            block_tokens=spec["block_tokens"],
            n_layers_kv=spec["n_layers_kv"],
            n_kv_heads=spec["n_kv_heads"],
            head_dim=spec["head_dim"],
            dtype_bytes=spec["dtype_bytes"],
        )
        self.n_blocks = spec["n_blocks"]
        n = self.n_blocks
        self._data_segment = attach_segment(spec["data_shm_name"])
        self._meta_segment = attach_segment(spec["meta"]["shm_name"])
        self.data = np.frombuffer(self._data_segment.buf, np.uint8).reshape(
            n, self.layout.block_bytes
        )
        mbuf = self._meta_segment.buf
        self.epochs = np.frombuffer(mbuf, np.int64, n, 0)
        self.refcounts = np.frombuffer(mbuf, np.int32, n, 8 * n)
        self.committed = np.frombuffer(mbuf, np.bool_, n, 12 * n)

    # -- data plane (same contracts as BelugaPool) -----------------------
    def write_block(self, block_id: int, payload: np.ndarray | None) -> int:
        if payload is not None:
            assert payload.nbytes == self.layout.block_bytes
            self.data[block_id] = payload.reshape(-1).view(np.uint8)
        self.epochs[block_id] += 1
        self.committed[block_id] = True
        return int(self.epochs[block_id])

    def write_blocks(
        self, block_ids, payloads: np.ndarray | None = None
    ) -> list[int]:
        """Batch store + publish, straight into the shared segment.

        Lock-free on purpose: the caller owns these freshly-allocated
        blocks exclusively until this publish (module docstring)."""
        ids = np.asarray(block_ids, np.intp)
        if payloads is not None:
            assert payloads.nbytes == len(ids) * self.layout.block_bytes
            self.data[ids] = payloads.reshape(len(ids), -1).view(np.uint8)
        self.epochs[ids] += 1
        self.committed[ids] = True
        return self.epochs[ids].tolist()

    def read_block(self, block_id: int) -> tuple[np.ndarray, int]:
        e = int(self.epochs[block_id])
        return self.data[block_id].copy(), e

    def read_blocks(
        self, block_ids, out: np.ndarray | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Batch gather; epoch snapshot BEFORE the copy (§5.1 protocol)."""
        ids = np.asarray(block_ids, np.intp)
        eps = self.epochs[ids].copy()
        if out is None:
            return self.data[ids], eps
        assert out.shape == (len(ids), self.layout.block_bytes)
        data = self.data
        for j, b in enumerate(ids):
            out[j] = data[b]
        return out, eps

    def read_fragments(self, block_id: int, frag_ids) -> np.ndarray:
        fb = self.layout.fragment_bytes
        block = self.data[block_id]
        return block.reshape(self.layout.n_fragments, fb)[
            np.asarray(frag_ids, np.intp)
        ]

    def validate_epoch(self, block_id: int, epoch: int) -> bool:
        return bool(self.committed[block_id]) and int(
            self.epochs[block_id]
        ) == epoch

    def validate_epochs(self, block_ids, epochs) -> np.ndarray:
        ids = np.asarray(block_ids, np.intp)
        return self.committed[ids] & (self.epochs[ids] == np.asarray(epochs))

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        """Drop the mappings; NEVER unlinks (attacher is not the owner)."""
        if self._data_segment is None:
            return
        self.data = None
        self.epochs = self.refcounts = self.committed = None
        close_segment(self._data_segment, unlink=False)
        close_segment(self._meta_segment, unlink=False)
        self._data_segment = self._meta_segment = None


class WorkerPoolView:
    """The full pool surface, split across the process boundary.

    Data ops hit the shared segment (``SharedPoolData``); allocator ops
    round-trip to the pool-owning parent over a ring
    (``repro.core.wire.PoolRpcClient``).  This is exactly the paper's
    split: load/store to the shared pool for payloads, RPC slots for the
    allocator — ``KVCacheManager`` and ``TransferEngine`` cannot tell the
    difference from an in-process ``BelugaPool``.
    """

    is_tiered = False

    def __init__(self, shared: SharedPoolData, alloc):
        self._shared = shared
        self._alloc = alloc
        self.layout = shared.layout
        self.n_blocks = shared.n_blocks

    # -- allocator plane (over the wire) ---------------------------------
    def allocate(self, n: int) -> list[int]:
        return self._alloc.allocate(n)

    def retain(self, block_ids) -> None:
        self._alloc.retain(block_ids)

    def release(self, block_ids) -> None:
        self._alloc.release(block_ids)

    def free_blocks(self) -> int:
        return self._alloc.free_blocks()

    # -- data plane (shared segment, zero-copy) --------------------------
    @property
    def data(self):
        return self._shared.data

    @property
    def epochs(self):
        return self._shared.epochs

    @property
    def refcounts(self):
        return self._shared.refcounts

    @property
    def committed(self):
        return self._shared.committed

    def write_block(self, block_id, payload):
        return self._shared.write_block(block_id, payload)

    def write_blocks(self, block_ids, payloads=None):
        return self._shared.write_blocks(block_ids, payloads)

    def read_block(self, block_id):
        return self._shared.read_block(block_id)

    def read_blocks(self, block_ids, out=None):
        return self._shared.read_blocks(block_ids, out=out)

    def read_fragments(self, block_id, frag_ids):
        return self._shared.read_fragments(block_id, frag_ids)

    def validate_epoch(self, block_id, epoch):
        return self._shared.validate_epoch(block_id, epoch)

    def validate_epochs(self, block_ids, epochs):
        return self._shared.validate_epochs(block_ids, epochs)

    def close(self) -> None:
        self._shared.close()

"""Attach-side view of a shared-data ``BelugaPool`` (zero-copy data plane).

``BelugaPool.share_data`` re-homes the pool's block payload array — not
just its metadata — into one named shared-memory segment.  This module is
the OTHER side of that export: what an engine worker OS process
(``repro.serving.engineproc``) maps to scatter/gather KV blocks directly
against the modeled CXL pool, with zero payload copies through the parent
interpreter.

Division of labour across the process boundary:

  * payload loads/stores and epoch publication happen HERE, on the shared
    arrays (``SharedPoolData``) — the paper's native load/store path;
  * allocate/retain/release stay with the pool-owning parent and travel
    over a ring (``repro.core.wire.PoolRpcClient``) — the allocator's free
    stacks are ordinary Python state that must have exactly one owner;
  * ``WorkerPoolView`` glues the two into the full pool surface
    ``KVCacheManager`` + ``TransferEngine`` expect, so the serving stack
    runs unmodified inside a worker.

Why payload stores need NO cross-process lock (paper §5.1): a block is
written only between ``allocate`` (exclusive ownership to one worker) and
``publish`` (after which every toucher is a reader until refcount hits
zero back in the owning pool).  The only concurrent epoch mutation is the
pool owner's release-side bump, which by the same contract only targets
blocks no worker is writing.  Torn int64 reads on the shared epoch array
are theoretical on the platforms this runs on (aligned 8-byte loads), and
the committed-flag check backstops them.
"""

from __future__ import annotations

import numpy as np

from repro.core.locks import make_lock
from repro.core.pool import PoolLayout
from repro.core.shm import attach_segment, close_segment


class SharedPoolData:
    """Attach-side view of ``BelugaPool.share_data``'s segments.

    Maps BOTH exports — the payload segment and the metadata segment that
    ``share_data`` implies — and rebuilds the real ``PoolLayout`` from the
    spec, so fragment math matches the owner exactly.  Mirrors the
    ``BelugaPool`` data-plane surface (``write_blocks`` / ``read_blocks``
    / ``validate_epochs`` / ``read_fragments``); never unlinks on close
    (the creator owns unlink, same rule as every segment in the plane).
    """

    def __init__(self, spec: dict):
        self.layout = PoolLayout(
            block_tokens=spec["block_tokens"],
            n_layers_kv=spec["n_layers_kv"],
            n_kv_heads=spec["n_kv_heads"],
            head_dim=spec["head_dim"],
            dtype_bytes=spec["dtype_bytes"],
        )
        self.n_blocks = spec["n_blocks"]
        n = self.n_blocks
        self._data_segment = attach_segment(spec["data_shm_name"])
        self._meta_segment = attach_segment(spec["meta"]["shm_name"])
        self.data = np.frombuffer(self._data_segment.buf, np.uint8).reshape(
            n, self.layout.block_bytes
        )
        mbuf = self._meta_segment.buf
        self.epochs = np.frombuffer(mbuf, np.int64, n, 0)
        self.refcounts = np.frombuffer(mbuf, np.int32, n, 8 * n)
        self.committed = np.frombuffer(mbuf, np.bool_, n, 12 * n)

    # -- data plane (same contracts as BelugaPool) -----------------------
    def write_block(self, block_id: int, payload: np.ndarray | None) -> int:
        if payload is not None:
            assert payload.nbytes == self.layout.block_bytes
            self.data[block_id] = payload.reshape(-1).view(np.uint8)
        self.epochs[block_id] += 1
        self.committed[block_id] = True
        return int(self.epochs[block_id])

    def write_blocks(
        self, block_ids, payloads: np.ndarray | None = None
    ) -> list[int]:
        """Batch store + publish, straight into the shared segment.

        Lock-free on purpose: the caller owns these freshly-allocated
        blocks exclusively until this publish (module docstring)."""
        ids = np.asarray(block_ids, np.intp)
        if payloads is not None:
            assert payloads.nbytes == len(ids) * self.layout.block_bytes
            self.data[ids] = payloads.reshape(len(ids), -1).view(np.uint8)
        self.epochs[ids] += 1
        self.committed[ids] = True
        return self.epochs[ids].tolist()

    def read_block(self, block_id: int) -> tuple[np.ndarray, int]:
        e = int(self.epochs[block_id])
        return self.data[block_id].copy(), e

    def read_blocks(
        self, block_ids, out: np.ndarray | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """Batch gather; epoch snapshot BEFORE the copy (§5.1 protocol)."""
        ids = np.asarray(block_ids, np.intp)
        eps = self.epochs[ids].copy()
        if out is None:
            return self.data[ids], eps
        assert out.shape == (len(ids), self.layout.block_bytes)
        data = self.data
        for j, b in enumerate(ids):
            out[j] = data[b]
        return out, eps

    def read_fragments(self, block_id: int, frag_ids) -> np.ndarray:
        fb = self.layout.fragment_bytes
        block = self.data[block_id]
        return block.reshape(self.layout.n_fragments, fb)[
            np.asarray(frag_ids, np.intp)
        ]

    def validate_epoch(self, block_id: int, epoch: int) -> bool:
        return bool(self.committed[block_id]) and int(
            self.epochs[block_id]
        ) == epoch

    def validate_epochs(self, block_ids, epochs) -> np.ndarray:
        ids = np.asarray(block_ids, np.intp)
        return self.committed[ids] & (self.epochs[ids] == np.asarray(epochs))

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        """Drop the mappings; NEVER unlinks (attacher is not the owner)."""
        if self._data_segment is None:
            return
        self.data = None
        self.epochs = self.refcounts = self.committed = None
        close_segment(self._data_segment, unlink=False)
        close_segment(self._meta_segment, unlink=False)
        self._data_segment = self._meta_segment = None


class WorkerLeaseLedger:
    """Per-worker retained-block ledger on the pool-owning side.

    The allocator ring handler mirrors every ALLOC/RETAIN/RELEASE into
    this ledger (``repro.core.wire.make_pool_handler`` with
    ``ledger=``), tagged with the posting worker (slot partitions make
    the slot identify the worker).  When a worker dies, ``reconcile``
    releases exactly the refs that worker still held — and ONLY those —
    using the PR-5 epoch-validity rule so a block whose lease has since
    moved on is never freed under its new owner:

      * ``epoch == grant``                 — untouched since the grant
        (fresh allocation never written, or a retain-ref on a committed
        block): release;
      * ``epoch == grant+1`` and committed — the worker wrote it; probe
        the metadata plane (``owners_of``): if the index owns
        ``(block, grant+1)`` the alloc-ref transferred at publish and
        must survive, otherwise it is a wrote-but-unpublished leak and
        is released.  Reallocation by another worker is impossible
        without an intervening free, which would bump the epoch past
        ``grant+1`` — so this release can never land on a new owner;
      * anything else                      — the lease provably moved
        on (or the state is unaccountable): skip.  The bias is
        leak-not-corrupt; skipped blocks are reported, not freed.

    Publishes clear the lease eagerly (``on_publish``, driven by the
    journal-proxy handler), so in steady state the ledger holds only a
    worker's transient refs.  ``mutex`` serializes pool mutation between
    the allocator service thread (handler) and the supervisor's
    reconcile (parent main thread)."""

    def __init__(self):
        self.mutex = make_lock("shmpool.WorkerLeaseLedger.mutex")
        # worker -> {block_id: [ref_count, grant_epoch]}
        self._leases: dict[int, dict[int, list[int]]] = {}

    # -- handler-side mirror hooks (called under ``mutex``) --------------
    def on_alloc(self, worker: int, block_ids, pool) -> None:
        held = self._leases.setdefault(worker, {})
        eps = pool.epochs
        for b in block_ids:
            b = int(b)
            lease = held.get(b)
            if lease is None:
                held[b] = [1, int(eps[b])]
            else:
                lease[0] += 1
                lease[1] = int(eps[b])

    on_retain = on_alloc  # same bookkeeping: one more ref at current epoch

    def on_release(self, worker: int, block_ids) -> None:
        """Unknown ids are tolerated on purpose: a worker also routes
        index-eviction releases (``on_freed``) through its ring, and
        those free refs the INDEX owned, not leases of this worker."""
        held = self._leases.get(worker)
        if held is None:
            return
        for b in block_ids:
            lease = held.get(int(b))
            if lease is None:
                continue
            lease[0] -= 1
            if lease[0] <= 0:
                del held[int(b)]

    def on_publish(self, worker: int, block_ids) -> None:
        """Alloc-ref ownership transfer: published blocks belong to the
        index (eviction releases them via ``on_freed``)."""
        self.on_release(worker, block_ids)

    # -- supervisor-side --------------------------------------------------
    def leases(self, worker: int) -> dict[int, tuple[int, int]]:
        with self.mutex:
            return {
                b: (c, e)
                for b, (c, e) in self._leases.get(worker, {}).items()
            }

    def drop(self, worker: int) -> None:
        with self.mutex:
            self._leases.pop(worker, None)

    def reconcile(self, worker: int, pool: "BelugaPool",  # noqa: F821
                  owners_of=None) -> dict:
        """Release a dead worker's leases exactly once (epoch-validated).

        The worker's entry is popped up front, so a second call (or a
        concurrent handler append from a not-actually-dead worker) finds
        nothing — exactly-once by construction.  Returns a summary:
        refs released / skipped and the block ids involved.

        The ``owners_of`` probe is an RPC round-trip to the metadata
        plane, so it runs OUTSIDE ``mutex`` — holding the allocator
        serialization lock across a remote call would stall every live
        worker's ALLOC/RELEASE for the probe's full latency (and a dead
        index shard's full timeout).  Dropping the mutex around the
        probe keeps the leak-not-corrupt bias: only the dead worker
        could publish its own allocations, so ownership observed by the
        probe can go stale in exactly one direction (an eviction/remap
        lands after the probe), and every lease is RE-classified against
        fresh pool state under the mutex before anything is released —
        a stale probe answer can at worst keep (leak) a block, never
        free one under a new owner."""
        with self.mutex:
            held = self._leases.pop(worker, {})
            if not held:
                return {"released": 0, "skipped": 0, "blocks": [], "kept": []}
            # probe candidates only (no releases yet): blocks the worker
            # wrote exactly once — published-or-leaked is undecidable
            # without asking the index
            eps, committed, refcounts = pool.epochs, pool.committed, pool.refcounts
            probe_ids = [
                b for b, (_, grant) in held.items()
                if int(refcounts[b]) > 0
                and int(eps[b]) == grant + 1
                and bool(committed[b])
            ]
        probe_set = set(probe_ids)
        owned: set | None = None
        if probe_ids and owners_of is not None:
            keys, ids, owner_eps = owners_of(probe_ids)
            owned = set(zip(ids, owner_eps))
        with self.mutex:
            eps, committed, refcounts = pool.epochs, pool.committed, pool.refcounts
            to_release: list[int] = []
            kept: list[int] = []
            for b, (count, grant) in held.items():
                rc = int(refcounts[b])
                if rc <= 0:
                    kept.append(b)  # already free: nothing to reclaim
                    continue
                ec = int(eps[b])
                if ec == grant:
                    to_release.extend([b] * min(count, rc))
                elif ec == grant + 1 and bool(committed[b]):
                    count = min(count, rc)
                    if owned is None or b not in probe_set:
                        # unprobed (no owners_of, or the block reached
                        # this state after the probe): leak, don't guess
                        kept.append(b)
                    elif (b, grant + 1) in owned:
                        # publish applied before death: the index holds
                        # the alloc-ref now — it must survive the worker
                        if count > 1:
                            to_release.extend([b] * (count - 1))
                        else:
                            kept.append(b)
                    else:
                        to_release.extend([b] * count)
                else:
                    kept.append(b)  # lease moved on: leak-not-corrupt
            if to_release:
                pool.release(to_release)
            return {
                "released": len(to_release),
                "skipped": len(kept),
                "blocks": sorted(set(to_release)),
                "kept": sorted(set(kept)),
            }


class WorkerPoolView:
    """The full pool surface, split across the process boundary.

    Data ops hit the shared segment (``SharedPoolData``); allocator ops
    round-trip to the pool-owning parent over a ring
    (``repro.core.wire.PoolRpcClient``).  This is exactly the paper's
    split: load/store to the shared pool for payloads, RPC slots for the
    allocator — ``KVCacheManager`` and ``TransferEngine`` cannot tell the
    difference from an in-process ``BelugaPool``.
    """

    is_tiered = False

    def __init__(self, shared: SharedPoolData, alloc):
        self._shared = shared
        self._alloc = alloc
        self.layout = shared.layout
        self.n_blocks = shared.n_blocks

    # -- allocator plane (over the wire) ---------------------------------
    def allocate(self, n: int) -> list[int]:
        return self._alloc.allocate(n)

    def retain(self, block_ids) -> None:
        self._alloc.retain(block_ids)

    def release(self, block_ids) -> None:
        self._alloc.release(block_ids)

    def free_blocks(self) -> int:
        return self._alloc.free_blocks()

    # -- data plane (shared segment, zero-copy) --------------------------
    @property
    def data(self):
        return self._shared.data

    @property
    def epochs(self):
        return self._shared.epochs

    @property
    def refcounts(self):
        return self._shared.refcounts

    @property
    def committed(self):
        return self._shared.committed

    def write_block(self, block_id, payload):
        return self._shared.write_block(block_id, payload)

    def write_blocks(self, block_ids, payloads=None):
        return self._shared.write_blocks(block_ids, payloads)

    def read_block(self, block_id):
        return self._shared.read_block(block_id)

    def read_blocks(self, block_ids, out=None):
        return self._shared.read_blocks(block_ids, out=out)

    def read_fragments(self, block_id, frag_ids):
        return self._shared.read_fragments(block_id, frag_ids)

    def validate_epoch(self, block_id, epoch):
        return self._shared.validate_epoch(block_id, epoch)

    def validate_epochs(self, block_ids, epochs):
        return self._shared.validate_epochs(block_ids, epochs)

    def close(self) -> None:
        self._shared.close()


class TieredWorkerPoolView(WorkerPoolView):
    """Worker-side surface of a shared ``TieredPool``.

    ``TieredPool.share_data`` exports ONE concatenated segment over the
    global block-id space, so the zero-copy data plane is byte-identical
    to the flat case — this subclass only adds the tiered *control*
    surface ``KVCacheManager`` uses when ``is_tiered``:

      * ``allocate(n, keys=...)`` forwards writeback keys over the ring
        (``OP_POOL_ALLOC_KEYS``) so ghost-LRU admission runs where the
        policy lives, in the pool-owning parent;
      * ``touch_demand`` round-trips the demand signal
        (``OP_POOL_TOUCH``) — heat decay, promotion enqueue and the
        per-tier split all happen parent-side; the reply's per-tier
        counts price the fetch locally;
      * ``tick`` is a no-op: the hotness clock advances in the parent
        on every touch, and a worker-local clock would race it;
      * ``count_tier_hits`` books into a worker-local ``TierStats``
        (classified against the exported tier boundaries) — actual-hit
        accounting is observability, not policy, so it stays off the
        ring.
    """

    is_tiered = True

    def __init__(self, shared: SharedPoolData, alloc, tiering: dict):
        super().__init__(shared, alloc)
        from repro.tiering.stats import TierStats

        self._starts = np.asarray(tiering["starts"], np.intp)
        self.tier_media = tuple(tiering["media"])
        self.spill_media = (
            self.tier_media[1] if len(self.tier_media) > 1
            else self.tier_media[0]
        )
        self.tier_stats = TierStats()

    # -- tiered control plane (over the wire) ----------------------------
    def allocate(self, n: int, keys=None) -> list[int]:
        return self._alloc.allocate(n, keys=keys)

    def touch_demand(self, block_ids, now: float) -> tuple[int, ...]:
        return self._alloc.touch_demand(block_ids, now)

    def tick(self, now: float) -> None:
        pass  # hotness clock is parent-owned (advanced by every touch)

    def count_tier_hits(self, block_ids) -> None:
        ids = np.asarray(block_ids, np.intp)
        if not len(ids):
            return
        n_fast = int((ids < self._starts[1]).sum()) if len(
            self._starts
        ) > 1 else len(ids)
        self.tier_stats.fast_hit_blocks += n_fast
        self.tier_stats.spill_hit_blocks += len(ids) - n_fast

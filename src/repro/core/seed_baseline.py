"""Frozen seed (pre-vectorization) control-plane implementations.

These are verbatim-behavior copies of the original hot paths that
``benchmarks/exp12_control_plane.py`` times against and
``tests/test_pool_allocator.py`` checks observable equivalence against:

  * ``SeedPool``      — the single-free-list allocator whose ``allocate()``
    rebuilt a by-shard dict of the whole free list per call, scanned all
    ``n_blocks`` in ``shard_occupancy()``, and kept per-block metadata in
    Python objects;
  * ``seed_block_key`` / ``seed_keys_for`` — blake2b chain hashing over
    per-int ``str()`` encodings;
  * ``seed_scatter_read`` — the per-block read-copy-unpack loop.

Do NOT use these in production paths; they exist so the perf trajectory
(before/after) stays measurable from any checkout without replaying git
history.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from repro.core.locks import make_lock
from repro.core.pool import OutOfPoolMemory, PoolLayout


@dataclass
class SeedBlockMeta:
    epoch: int = 0
    refcount: int = 0
    committed: bool = False


class SeedPool:
    """Seed allocator: one flat free list, per-call by-shard rebuild."""

    def __init__(
        self,
        layout: PoolLayout,
        n_blocks: int,
        n_shards: int = 32,
        backing: str = "meta",
        interleave: bool = True,
    ):
        assert n_blocks % n_shards == 0, (n_blocks, n_shards)
        self.layout = layout
        self.n_blocks = n_blocks
        self.n_shards = n_shards
        self.interleave = interleave
        self.backing = backing
        self._lock = make_lock("seed_baseline.SeedPool._lock")
        self._free: list[int] = list(range(n_blocks))
        self.meta: list[SeedBlockMeta] = [SeedBlockMeta() for _ in range(n_blocks)]
        self.alloc_count = 0
        if backing == "numpy":
            self.data = np.zeros((n_blocks, layout.block_bytes), np.uint8)
        else:
            self.data = None

    def shard_of(self, block_id: int) -> int:
        if self.interleave:
            return block_id % self.n_shards
        return block_id // (self.n_blocks // self.n_shards)

    def free_blocks(self) -> int:
        with self._lock:
            return len(self._free)

    def shard_occupancy(self) -> list[int]:
        occ = [0] * self.n_shards
        with self._lock:
            free = set(self._free)
        for b in range(self.n_blocks):
            if b not in free:
                occ[self.shard_of(b)] += 1
        return occ

    def allocate(self, n: int) -> list[int]:
        with self._lock:
            if len(self._free) < n:
                raise OutOfPoolMemory(f"need {n}, have {len(self._free)}")
            if self.interleave:
                by_shard: dict[int, list[int]] = {}
                for b in self._free:
                    by_shard.setdefault(b % self.n_shards, []).append(b)
                out: list[int] = []
                shard_ids = sorted(by_shard, key=lambda s: -len(by_shard[s]))
                i = 0
                while len(out) < n:
                    s = shard_ids[i % len(shard_ids)]
                    if by_shard[s]:
                        out.append(by_shard[s].pop())
                    i += 1
                    if i > 4 * self.n_shards + n * 2:
                        remaining = [b for lst in by_shard.values() for b in lst]
                        out.extend(remaining[: n - len(out)])
                        break
            else:
                out = [self._free[i] for i in range(n)]
            free_set = set(out)
            self._free = [b for b in self._free if b not in free_set]
            for b in out:
                m = self.meta[b]
                m.refcount = 1
                m.committed = False
            self.alloc_count += n
            return out

    def retain(self, block_ids: list[int]) -> None:
        with self._lock:
            for b in block_ids:
                assert self.meta[b].refcount > 0, f"retain of free block {b}"
                self.meta[b].refcount += 1

    def release(self, block_ids: list[int]) -> None:
        with self._lock:
            for b in block_ids:
                m = self.meta[b]
                m.refcount -= 1
                assert m.refcount >= 0, f"double free of block {b}"
                if m.refcount == 0:
                    m.committed = False
                    m.epoch += 1
                    self._free.append(b)

    def write_block(self, block_id: int, payload: np.ndarray | None) -> int:
        if self.data is not None and payload is not None:
            assert payload.nbytes == self.layout.block_bytes
            self.data[block_id] = payload.reshape(-1).view(np.uint8)
        with self._lock:
            m = self.meta[block_id]
            m.epoch += 1
            m.committed = True
            return m.epoch

    def read_block(self, block_id: int) -> tuple[np.ndarray, int]:
        with self._lock:
            e = self.meta[block_id].epoch
        if self.data is None:
            return np.zeros(self.layout.block_bytes, np.uint8), e
        return self.data[block_id].copy(), e

    def validate_epoch(self, block_id: int, epoch: int) -> bool:
        with self._lock:
            m = self.meta[block_id]
            return m.committed and m.epoch == epoch


# ---------------------------------------------------------------------------
# seed chain hashing: per-int str() encoding, no memoization
# ---------------------------------------------------------------------------

SEED_ROOT = b"ROOT"


def seed_block_key(parent: bytes, tokens: tuple[int, ...]) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    h.update(parent)
    h.update(b"|")
    h.update(b",".join(str(t).encode() for t in tokens))
    return h.digest()


def seed_keys_for(tokens: list[int], block_tokens: int) -> list[bytes]:
    bt = block_tokens
    keys, parent = [], SEED_ROOT
    for i in range(0, len(tokens) - len(tokens) % bt, bt):
        k = seed_block_key(parent, tuple(tokens[i : i + bt]))
        keys.append(k)
        parent = k
    return keys


# ---------------------------------------------------------------------------
# seed scatter-read: per-block read_block + copy + view/reshape loop
# ---------------------------------------------------------------------------


def seed_scatter_read(
    pool, block_ids: list[int], epochs: list[int] | None = None, dtype=np.float16
) -> np.ndarray:
    """The seed TransferEngine data loop (latency modeling stripped)."""
    lay = pool.layout
    n = len(block_ids)
    shape = (n, lay.n_fragments, lay.block_tokens, lay.n_kv_heads, lay.head_dim)
    out = np.empty(shape, dtype)
    for i, bid in enumerate(block_ids):
        payload, epoch = pool.read_block(bid)
        if epochs is not None and epoch != epochs[i]:
            from repro.core.coherence import CoherenceError

            raise CoherenceError(f"block {bid} epoch changed during read")
        out[i] = payload.view(dtype).reshape(
            lay.n_fragments, lay.block_tokens, lay.n_kv_heads, lay.head_dim
        )
    return out

"""Memory-fabric cost model, calibrated to the paper's measured hardware.

The container is CPU-only, so the *performance* of CXL vs RDMA paths is
modeled (latency/bandwidth/queueing) while the *functionality* (actual data
movement, allocator, index, coherence) is executed for real.  Every constant
below is traceable to a paper measurement:

  Table 4 (Exp #1)  — 16 KB coherence-method latencies
  Fig. 5  (Exp #2)  — latency vs I/O size for all paths
  §2.3              — XConn switch: ~750 ns 64 B port-to-port
  §5.3              — device BW 22.5 GB/s; adapter 46.2 GB/s read / 33 GB/s
                      write; GPU⇄CXL 26 GB/s via root complex
  Fig. 15 (Exp #11) — CXL-RPC 2.11 µs RTT vs RDMA-RC 8.39 µs / UD 8.83 µs

All times in **seconds**, sizes in **bytes**.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


US = 1e-6
KB = 1024
MB = 1024 * 1024
GB = 1024**3


@dataclass(frozen=True)
class FabricConstants:
    # --- CXL path (Beluga) ---
    cxl_64b_latency: float = 0.75 * US  # switch port-to-port, §2.3
    cxl_dev_bw: float = 22.5 * GB  # per memory device, §5.3
    cxl_adapter_read_bw: float = 46.2 * GB  # per PCIe5 x16 adapter, §5.3
    cxl_adapter_write_bw: float = 33.0 * GB  # RC write bottleneck, §5.3
    gpu_cxl_bw: float = 26.0 * GB  # GPU⇄CXL through root complex, §5.3
    n_devices: int = 32  # memory devices in the pool (Table 2)
    n_adapters: int = 2  # PCIe/CXL adapters per server (Table 2)
    interleave_bytes: int = 2 * MB  # software interleaving granularity §5.3

    # CPU instruction-path costs (Exp #1, 16 KB points)
    ntstore_16k: float = 2.41 * US
    store_clflush_16k: float = 8.50 * US
    store_uc_16k: float = 281.56 * US
    load_clflush_16k: float = 5.98 * US
    load_uc_16k: float = 166.49 * US
    dsa_write_16k: float = 1.69 * US  # uncacheable/bypass
    dsa_read_16k: float = 2.12 * US
    dsa_setup: float = 0.9 * US  # DMA descriptor setup (crossover @~4KB, Fig5)
    clflush_per_line: float = 0.03 * US  # 64B line flush amortized

    # GPU path (Exp #1/#2)
    kernel_launch: float = 7.9 * US  # CUDA kernel launch+sync overhead (§3.2:
    # 10.55us total - 2.68us transfer for 16 KB)
    gpu_copy_16k: float = 2.68 * US  # in-kernel data movement, 16 KB
    cudamemcpy_uc_small: float = 1230 * US  # <24KB H2D from UC memory (§5.2)

    # --- RDMA path (MoonCake-style baseline) ---
    rdma_base_latency: float = 3.2 * US  # one-sided verb, QD=1 small msg
    rdma_bw: float = 50.0 * GB  # 400 Gbps NIC
    rdma_request_overhead: float = 1.0 * US  # WQE prep + doorbell + CQ poll
    rdma_sgl_max: int = 30  # ConnectX-7 sglist entries (§6.1)
    # CPU-side allocation + staging per (super-)block transfer in the
    # MoonCake/LMCache path — calibrated to Fig. 13c block-size sweep
    rdma_sw_per_superblock: float = 25.0 * US * 1000
    rdma_rc_rpc_rtt: float = 8.39 * US  # Exp #11
    rdma_ud_rpc_rtt: float = 8.83 * US
    bounce_copy_bw: float = 40.0 * GB  # GPU->host bounce buffer copy
    host_sync_overhead: float = 8.0 * US  # CPU<->GPU coordination (§3.2)

    # --- local DRAM baseline ---
    dram_latency: float = 0.09 * US
    dram_bw: float = 80.0 * GB

    # CXL-RPC (Exp #11)
    cxl_rpc_rtt: float = 2.11 * US

    # --- spill-tier media (tiered pool, Exp #13) ---
    # Colder/cheaper capacity BELOW the CXL pool: far-NUMA DRAM reached
    # over one-sided RDMA (ITME-style hybrid memory) and NVMe-SSD-class
    # storage. Latency = media access + bandwidth term; the tiered pool
    # pays this on every spill-tier block it touches, which is what makes
    # demotion a *latency* trade (spill hit ≪ recompute ≪ destroy+recompute)
    # rather than a free capacity extension.
    spill_dram_rdma_latency: float = 4.0 * US  # far-memory one-sided read
    spill_dram_rdma_bw: float = 20.0 * GB  # shared far-NUMA / RDMA fabric
    spill_ssd_latency: float = 80.0 * US  # NVMe read latency class
    spill_ssd_bw: float = 6.0 * GB  # PCIe4 x4 NVMe device
    spill_hdd_latency: float = 4000.0 * US  # archival spindle/SMR class
    spill_hdd_bw: float = 0.25 * GB


DEFAULT = FabricConstants()

# spill-media catalog: medium name -> (latency attr, bandwidth attr) on
# ``FabricConstants``.  The tiered pool chain prices each boundary from
# this table, so adding a medium is one row + two constants.
SPILL_MEDIA: dict = {
    "rdma_dram": ("spill_dram_rdma_latency", "spill_dram_rdma_bw"),
    "ssd": ("spill_ssd_latency", "spill_ssd_bw"),
    "hdd": ("spill_hdd_latency", "spill_hdd_bw"),
}


# ---------------------------------------------------------------------------
# Point latency models (QD=1), one per data path in Fig. 4 / Fig. 5
# ---------------------------------------------------------------------------


def cpu_write_latency(size: int, method: str = "ntstore", c: FabricConstants = DEFAULT) -> float:
    """CPU -> CXL pool write."""
    lines = max(1, size // 64)
    if method == "ntstore":  # O1 — bypass cache, no flush
        return c.cxl_64b_latency + size / c.cxl_adapter_write_bw + lines * 0.004 * US
    if method == "clflush":  # store + CLFLUSH per line
        return c.cxl_64b_latency + size / c.cxl_adapter_write_bw + lines * c.clflush_per_line
    if method == "uncacheable":  # each store stalls the pipeline
        return lines * (c.store_uc_16k / 256)
    if method == "dsa":  # O2 — DSA with cache bypass
        return c.dsa_setup + c.cxl_64b_latency + size / c.cxl_adapter_write_bw
    raise ValueError(method)


def cpu_read_latency(size: int, method: str = "clflush", c: FabricConstants = DEFAULT) -> float:
    """CPU <- CXL pool read."""
    lines = max(1, size // 64)
    if method == "clflush":  # O1 — invalidate then load
        return c.cxl_64b_latency + size / c.cxl_adapter_read_bw + lines * c.clflush_per_line
    if method == "uncacheable":
        return lines * (c.load_uc_16k / 256)
    if method == "dsa":  # O2
        return c.dsa_setup + c.cxl_64b_latency + size / c.cxl_adapter_read_bw
    raise ValueError(method)


def gpu_transfer_latency(
    size: int,
    n_fragments: int = 1,
    method: str = "fused_kernel",
    direction: str = "read",
    c: FabricConstants = DEFAULT,
) -> float:
    """GPU <-> CXL pool transfer (O3/O5/O6 paths).

    ``fused_kernel`` — one custom copy kernel moves all fragments (Beluga):
    single launch, fine-grained gather/scatter at memory semantics.
    ``cudamemcpy``  — one cudaMemcpy per contiguous fragment.
    """
    bw = c.gpu_cxl_bw
    if method == "fused_kernel":
        return c.kernel_launch + c.cxl_64b_latency + size / bw
    if method == "cudamemcpy":
        per = c.kernel_launch + c.cxl_64b_latency + (size / n_fragments) / bw
        if direction == "read" and size / n_fragments < 24 * KB:
            per = c.cudamemcpy_uc_small  # §5.2 UC-small-pathology
        return n_fragments * per
    raise ValueError(method)


def rdma_transfer_latency(
    size: int,
    n_fragments: int = 1,
    gpu_side: bool = True,
    c: FabricConstants = DEFAULT,
) -> float:
    """CPU-driven RDMA path (MoonCake): bounce buffer + sglist batching.

    GPU -> host bounce copy (D2H), then ceil(frags/30) RDMA requests, plus
    host<->GPU synchronization. Reads are the mirror path.
    """
    t = 0.0
    if gpu_side:
        t += c.host_sync_overhead  # CPU<->GPU coordination (§3.2 microbench)
        t += c.kernel_launch + size / c.bounce_copy_bw  # staging copy
    n_req = math.ceil(n_fragments / c.rdma_sgl_max)
    t += n_req * (c.rdma_base_latency + c.rdma_request_overhead)
    t += size / c.rdma_bw
    return t


def local_dram_latency(size: int, c: FabricConstants = DEFAULT) -> float:
    return c.dram_latency + size / c.dram_bw


def spill_transfer_latency(
    size: int, media: str = "rdma_dram", c: FabricConstants = DEFAULT
) -> float:
    """Spill-tier (below-pool) media access, priced per medium from the
    ``SPILL_MEDIA`` catalog (far DRAM over RDMA, NVMe SSD, archival HDD)."""
    try:
        lat_attr, bw_attr = SPILL_MEDIA[media]
    except KeyError:
        raise ValueError(media) from None
    return getattr(c, lat_attr) + size / getattr(c, bw_attr)


# ---------------------------------------------------------------------------
# Pool-device queueing model (Exp #3/#4: skew + background pressure)
# ---------------------------------------------------------------------------


@dataclass
class DeviceQueues:
    """Per-memory-device FIFO queues; models O9 interleaving benefits.

    Service time = bytes / dev_bw. Requests target a device either by
    interleaved round-robin (``interleave=True``) or by address hash of the
    block (hot blocks collide on one device when interleaving is off).
    """

    n_devices: int = 32
    dev_bw: float = DEFAULT.cxl_dev_bw
    interleave_bytes: int = DEFAULT.interleave_bytes
    total_bytes: int = 8 * (1024**4)  # 8 TB pool (Table 2)
    busy_until: list[float] = field(default_factory=list)

    def __post_init__(self):
        if not self.busy_until:
            self.busy_until = [0.0] * self.n_devices

    def submit(self, now: float, addr: int, size: int, interleave: bool) -> float:
        """Returns completion time of the request."""
        if interleave:
            # split across devices at interleave granularity
            n_chunks = max(1, math.ceil(size / self.interleave_bytes))
            per_chunk = size / n_chunks
            done = now
            start_dev = (addr // self.interleave_bytes) % self.n_devices
            for i in range(n_chunks):
                d = (start_dev + i) % self.n_devices
                svc = per_chunk / self.dev_bw
                start = max(now, self.busy_until[d])
                self.busy_until[d] = start + svc
                done = max(done, start + svc)
            return done
        # no interleaving: contiguous address partition — hot (zipf) regions
        # all land on the first device(s) (the paper's §5.3 bottleneck)
        region = max(1, self.total_bytes // self.n_devices)
        d = min(self.n_devices - 1, addr // region)
        svc = size / self.dev_bw
        start = max(now, self.busy_until[d])
        self.busy_until[d] = start + svc
        return start + svc

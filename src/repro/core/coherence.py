"""Software-managed multi-host coherence (paper §5.1, O1–O3).

CXL 2.0 switches give a unified address space but NO cross-host cache
coherence: a writer's lines sit in its private hierarchy until flushed, and
a reader may hit stale lines it cached earlier.  The paper's answer — and
ours — is a single-writer / multi-reader *publication protocol*:

  WRITER:  write payload with a cache-bypassing method (ntstore / DSA-bypass
           / DDIO-off GPU copy)  →  fence  →  bump block epoch  →  publish
           (key, block_id, epoch) in the global index (via CXL-RPC).
  READER:  read (block_id, epoch) from index  →  invalidate local lines
           (CLFLUSH-before-read / UC mapping for DSA+GPU)  →  copy payload →
           re-validate epoch unchanged (a concurrent evict+rewrite would
           have bumped it) → else retry.

On a TPU pod the mechanism differs (there is no host-written cache to
flush; remote HBM reads are always coherent at the collective level) but
the *ordering obligation* is identical: a pool block must not be readable
before its payload write completes, and readers must detect reuse of a
recycled block.  The epoch validation below is exactly that obligation, so
the control plane is shared between the modeled-CXL benchmarks and the TPU
serving runtime.

The per-method latency accounting reproduces Table 4.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import fabric
from repro.core.fabric import DEFAULT, FabricConstants
from repro.core.pool import BelugaPool


class CoherenceError(RuntimeError):
    """Reader observed a torn / recycled block (epoch mismatch)."""


@dataclass
class CoherenceStats:
    writes: int = 0
    write_bytes: int = 0
    reads: int = 0
    read_bytes: int = 0
    retries: int = 0
    modeled_write_s: float = 0.0
    modeled_read_s: float = 0.0


@dataclass
class CoherentWriter:
    """Single designated writer for a set of blocks (one LLM instance)."""

    pool: BelugaPool
    method: str = "ntstore"  # O1: ntstore | clflush | uncacheable | dsa
    constants: FabricConstants = DEFAULT
    stats: CoherenceStats = field(default_factory=CoherenceStats)

    def write_block(self, block_id: int, payload: np.ndarray) -> int:
        """Flush-to-pool write; returns the publish epoch."""
        size = payload.nbytes
        # modeled cost of the cache-bypassing write (Table 4 row)
        self.stats.modeled_write_s += fabric.cpu_write_latency(
            size, self.method, self.constants
        )
        epoch = self.pool.write_block(block_id, payload)  # real data move
        self.stats.writes += 1
        self.stats.write_bytes += size
        return epoch


@dataclass
class CoherentReader:
    pool: BelugaPool
    method: str = "clflush"  # O1: clflush | uncacheable | dsa
    constants: FabricConstants = DEFAULT
    max_retries: int = 3
    stats: CoherenceStats = field(default_factory=CoherenceStats)

    def read_block(self, block_id: int, expected_epoch: int) -> np.ndarray:
        """Invalidate-then-read with epoch validation; retries on races."""
        for _ in range(self.max_retries):
            if not self.pool.validate_epoch(block_id, expected_epoch):
                raise CoherenceError(
                    f"block {block_id}: epoch {expected_epoch} no longer valid"
                )
            payload, epoch_after = self.pool.read_block(block_id)
            self.stats.modeled_read_s += fabric.cpu_read_latency(
                payload.nbytes, self.method, self.constants
            )
            if epoch_after == expected_epoch:
                self.stats.reads += 1
                self.stats.read_bytes += payload.nbytes
                return payload
            self.stats.retries += 1  # concurrent recycle: revalidate
        raise CoherenceError(f"block {block_id}: unstable epoch after retries")

"""Global prefix index: token-block hash chain -> pool block (paper §6).

The index is the metadata service that every LLM instance queries before
prefill ("which prefix blocks are already in the pool?") and updates after
("these new blocks now hold tokens [i, i+16)").  In the paper it is a
centralized service reached via CXL-RPC; here the same object is either
called in-process (tests), or behind ``repro.core.rpc`` + the
``repro.core.wire`` binary codec (cluster benchmarks, Exp #11).

Key design points mirrored from MoonCake/vLLM prefix caching:
  * chain hashing: block key = H(parent_key, tokens_in_block) so a prefix
    match is a walk down the chain — O(n_blocks) lookups, no trie needed;
  * entries carry (block_id, epoch); readers must validate the epoch against
    the pool before trusting the payload (multi-host coherence, §5.1);
  * eviction: LRU over unreferenced committed blocks.

Storage is a structure-of-arrays store, not a dict of entry objects:

  * one ``bytes -> row`` hash table assigns each key a row in flat numpy
    arrays (``block_id / epoch / n_tokens / last_used``), so every batch
    operation — ``match_prefix_keys``, ``publish_many``, ``remap_many``,
    ``evict_blocks`` — is a vectorized gather/scatter under ONE lock
    acquisition instead of a per-entry attribute walk;
  * LRU order is an intrusive array-linked list (``lru_prev/lru_next``
    with head/tail sentinel rows). A batch "move to MRU" unlinks an
    arbitrary row set with pointer-doubling (O(log run-length) vectorized
    passes) and has an O(1) fast path for the steady state where a
    re-matched chain is already the MRU suffix — no per-key
    ``move_to_end`` anywhere;
  * the block->owner reverse map is a flat ``block2row`` array (invariant:
    ``block2row[b] == r`` implies ``block_id[r] == b``), making the
    tiering migrator's owner lookups a single fancy-indexed gather.

Hashing cost notes (the other half of the request hot path):
  * token blocks are hashed from ``np.int64`` buffers via ``tobytes()``;
  * a bounded (parent_key, block_bytes) -> key memo caches chain links;
  * the request-level memo is keyed by the token tuple itself (exact
    equality, no digest pass over the buffer): a repeat request costs one
    tuple hash, not a 120 KB blake2b. Returned chains are TUPLES — shared
    between callers and structurally immutable, so cache aliasing cannot
    corrupt them.
"""

from __future__ import annotations

import hashlib
import time
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.core.locks import make_lock
from repro.core.pool import BelugaPool

ROOT = b"ROOT"

_CHAIN_CACHE_MAX = 1 << 18
_REQUEST_CACHE_MAX = 256

# LRU sentinel rows (data rows start at 2)
_HEAD, _TAIL = 0, 1


def _hash_link(parent: bytes, token_bytes: bytes) -> bytes:
    return hashlib.blake2b(
        parent + b"|" + token_bytes, digest_size=16
    ).digest()


def block_key(parent: bytes, tokens: tuple[int, ...]) -> bytes:
    return _hash_link(parent, np.asarray(tokens, np.int64).tobytes())


@dataclass(slots=True)
class IndexEntry:
    """Point-in-time snapshot of one index row (API compatibility object;
    the store itself is columnar — mutating a snapshot has no effect)."""

    block_id: int
    epoch: int
    n_tokens: int
    last_used: float


class PrefixHasher:
    """Chain hashing + memoization, independent of the index store.

    Hashing is pure computation over the tokens, so RPC clients
    (``repro.core.wire.RpcIndexClient``) run it locally and only ship the
    resulting 16-byte keys over the ring.
    """

    __slots__ = ("block_tokens", "_chain_cache", "_request_cache")

    def __init__(self, block_tokens: int):
        self.block_tokens = block_tokens
        # parent_key||block_token_bytes -> key chain memo (bounded FIFO)
        self._chain_cache: OrderedDict[bytes, bytes] = OrderedDict()
        # request memo: cheap signature -> (token-list copy, key chain).
        # The signature is four sampled elements + length; a hit is then
        # CONFIRMED by a C-level list compare against the stored copy, so
        # a recurring request costs ~one list equality — no digest pass
        # and no hash over 15k tokens. The stored copy also makes caller
        # mutation of their token list safe: the compare simply misses.
        # Memory: the copy is a pointer array sharing the caller's int
        # objects (which outlive it in Request.tokens anyway), ~8 B/token
        # marginal — ~30 MB worst case at 256 entries of 15k tokens,
        # same order as the digest-keyed chain lists it replaced.
        self._request_cache: OrderedDict[
            tuple, tuple[list[int], tuple[bytes, ...]]
        ] = OrderedDict()

    def keys_for(self, tokens: list[int]) -> tuple[bytes, ...]:
        bt = self.block_tokens
        n = len(tokens) // bt
        if not n:
            return ()
        sig = (len(tokens), tokens[0], tokens[len(tokens) >> 1], tokens[-1])
        hit = self._request_cache.get(sig)
        if hit is not None and hit[0] == tokens:
            return hit[1]
        arr = np.asarray(tokens[: n * bt], np.int64).reshape(n, bt)
        keys: list[bytes] = []
        parent = ROOT
        cache = self._chain_cache
        cache_get = cache.get
        for i in range(n):
            tb = arr[i].tobytes()
            ck = parent + tb
            k = cache_get(ck)
            if k is None:
                k = _hash_link(parent, tb)
                cache[ck] = k
                if len(cache) > _CHAIN_CACHE_MAX:
                    cache.popitem(last=False)
            keys.append(k)
            parent = k
        out = tuple(keys)
        self._request_cache[sig] = (list(tokens), out)
        if len(self._request_cache) > _REQUEST_CACHE_MAX:
            self._request_cache.popitem(last=False)
        return out


class GlobalIndex:
    def __init__(self, pool: BelugaPool):
        self.pool = pool
        self.block_tokens = pool.layout.block_tokens
        self.hasher = PrefixHasher(self.block_tokens)
        self._lock = make_lock("index.GlobalIndex._lock")
        # key -> row in the flat arrays below
        self._rows: dict[bytes, int] = {}
        cap = 1 << 10
        self._cap = cap
        self._block_id = np.full(cap, -1, np.int64)
        self._epoch = np.zeros(cap, np.int64)
        self._n_tokens = np.zeros(cap, np.int32)
        self._last_used = np.zeros(cap, np.float64)
        self._lru_prev = np.zeros(cap, np.int64)
        self._lru_next = np.zeros(cap, np.int64)
        self._mark = np.zeros(cap, bool)  # scratch for batch LRU splices
        self._pos = np.zeros(cap, np.int64)  # scratch: row -> batch position
        self._keys: list[bytes | None] = [None] * cap
        # pop() order: row 2 first (0/1 are the LRU sentinels)
        self._free_rows: list[int] = list(range(cap - 1, 1, -1))
        self._lru_next[_HEAD] = _TAIL
        self._lru_prev[_TAIL] = _HEAD
        # block_id -> owning row (-1 = unindexed): the reverse map the
        # tiering migrator uses to find/re-point a cold block's entry
        self._block2row = np.full(pool.n_blocks, -1, np.int64)
        # optional hook fired with the keys of entries destroyed by
        # eviction (evict_lru / evict_blocks): the tiering policy's
        # ghost-LRU admission filter subscribes here. None = zero cost.
        self.on_evict = None
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------
    # hashing (delegates to the standalone hasher)
    # ------------------------------------------------------------------
    def keys_for(self, tokens: list[int]) -> tuple[bytes, ...]:
        return self.hasher.keys_for(tokens)

    # ------------------------------------------------------------------
    # row + LRU plumbing (all called with self._lock held)
    # ------------------------------------------------------------------
    def _grow(self, min_free: int) -> None:
        new_cap = self._cap
        while new_cap - 2 - len(self._rows) < min_free:
            new_cap *= 2
        if new_cap == self._cap:
            return
        old = self._cap
        for name in ("_block_id", "_epoch", "_n_tokens", "_last_used",
                     "_lru_prev", "_lru_next", "_mark", "_pos"):
            arr = getattr(self, name)
            grown = np.zeros(new_cap, arr.dtype)
            grown[:old] = arr
            setattr(self, name, grown)
        self._block_id[old:] = -1
        self._keys.extend([None] * (new_cap - old))
        self._free_rows.extend(range(new_cap - 1, old - 1, -1))
        self._cap = new_cap

    def _lru_append(self, rows: np.ndarray) -> None:
        """Link ``rows`` (in order) at the MRU tail."""
        nxt, prv = self._lru_next, self._lru_prev
        t = int(prv[_TAIL])
        first, last = int(rows[0]), int(rows[-1])
        nxt[t] = first
        prv[first] = t
        if len(rows) > 1:
            nxt[rows[:-1]] = rows[1:]
            prv[rows[1:]] = rows[:-1]
        nxt[last] = _TAIL
        prv[_TAIL] = last

    def _lru_unlink(self, rows: np.ndarray) -> None:
        """Splice an arbitrary row set out of the list, vectorized.

        Pointer-doubling computes, for every row, the first list successor
        OUTSIDE the set (O(log max-run-length) vectorized passes); each
        maximal run is then bridged with one scatter — no per-row Python
        relink loop.
        """
        nxt, prv = self._lru_next, self._lru_prev
        mk, pos = self._mark, self._pos
        mk[rows] = True
        pos[rows] = np.arange(len(rows))
        jump = nxt[rows]  # gather copies
        ins = mk[jump]
        while ins.any():
            jump[ins] = jump[pos[jump[ins]]]
            ins = mk[jump]
        pr = prv[rows]
        starts = ~mk[pr]  # rows whose predecessor survives = run starts
        left = pr[starts]
        right = jump[starts]
        nxt[left] = right
        prv[right] = left
        mk[rows] = False

    def _lru_move_to_tail(self, rows: np.ndarray) -> None:
        nxt = self._lru_next
        last = int(rows[-1])
        # steady-state fast path: a re-matched chain is usually already
        # the MRU suffix in order — two gathers, no splice
        if nxt[last] == _TAIL and (
            len(rows) == 1 or (nxt[rows[:-1]] == rows[1:]).all()
        ):
            return
        self._lru_unlink(rows)
        self._lru_append(rows)

    def _drop_rows(self, rows: np.ndarray) -> None:
        """Destroy rows: unlink, clear reverse map, recycle row slots."""
        self._lru_unlink(rows)
        bids = self._block_id[rows]
        owned = self._block2row[bids] == rows
        self._block2row[bids[owned]] = -1
        keys, free = self._keys, self._free_rows
        rows_dict = self._rows
        for r in rows.tolist():
            del rows_dict[keys[r]]
            keys[r] = None
            free.append(r)
        self._block_id[rows] = -1

    # ------------------------------------------------------------------
    def match_prefix(self, tokens: list[int]) -> list[tuple[bytes, int, int]]:
        """Longest cached prefix: [(key, block_id, epoch)] with valid epochs."""
        return self.match_prefix_keys(self.keys_for(tokens))

    def match_prefix_keys(
        self, keys: tuple[bytes, ...] | list[bytes]
    ) -> list[tuple[bytes, int, int]]:
        """``match_prefix`` over a pre-computed key chain (lets callers that
        also need the keys — e.g. the writeback path — hash once)."""
        out: list[tuple[bytes, int, int]] = []
        now = time.monotonic()
        with self._lock:
            rows = list(map(self._rows.get, keys))  # C-level bulk lookup
            try:
                n_present = rows.index(None)
            except ValueError:
                n_present = len(rows)
            if n_present:
                ra = np.asarray(rows[:n_present], np.int64)
                ids = self._block_id[ra]
                eps = self._epoch[ra]
                # one vectorized epoch+committed check for ALL candidates
                ok = self.pool.validate_epochs(ids, eps)
                n_ok = n_present if ok.all() else int(np.argmin(ok))
                if n_ok:
                    ga = ra[:n_ok]
                    self._last_used[ga] = now
                    self._lru_move_to_tail(ga)
                    out = list(
                        zip(keys[:n_ok], ids[:n_ok].tolist(), eps[:n_ok].tolist())
                    )
                if n_ok < n_present:  # stale entry: drop it
                    self._drop_rows(ra[n_ok : n_ok + 1])
            self.hits += len(out)
            self.misses += max(0, len(keys) - len(out))
        return out

    def publish(self, key: bytes, block_id: int, epoch: int, n_tokens: int) -> None:
        """Writer publishes AFTER the block payload is flushed (coherence).

        Unlike ``publish_many``, a single publish refreshes the LRU even
        on re-publish (the seed ``move_to_end`` semantics). One lock,
        atomic insert-and-move."""
        with self._lock:
            if not self._free_rows:
                self._grow(1)
            r = self._rows.get(key)
            if r is None:
                r = self._free_rows.pop()
                self._rows[key] = r
                self._keys[r] = key
                fresh = True
            else:
                ob = int(self._block_id[r])
                if self._block2row[ob] == r:
                    self._block2row[ob] = -1
                fresh = False
            self._block_id[r] = block_id
            self._epoch[r] = epoch
            self._n_tokens[r] = n_tokens
            self._last_used[r] = time.monotonic()
            self._block2row[block_id] = r
            ra = np.asarray([r], np.int64)
            if fresh:
                self._lru_append(ra)
            else:
                self._lru_move_to_tail(ra)

    def publish_many(
        self,
        keys: list[bytes],
        block_ids: list[int],
        epochs: list[int],
        n_tokens: int,
    ) -> None:
        """Batch publish: one lock, one scatter per column.

        Fresh keys are appended to the MRU tail in batch order; a
        re-publish of a still-present key (rare: epoch-invalidated entry
        not yet dropped) keeps its old LRU slot, which only makes it
        eviction-eligible sooner — safe.
        """
        n = len(keys)
        if not n:
            return
        if n > 1:
            # a key published twice in one batch (degenerate, but a wire
            # OP_PUBLISH can carry it) must resolve to its LAST occurrence
            # BEFORE the column scatters: the first occurrence would
            # otherwise leave a stale block2row pointer at a block the
            # row no longer owns
            last = {k: i for i, k in enumerate(keys)}
            if len(last) != n:
                sel = sorted(last.values())
                keys = [keys[i] for i in sel]
                block_ids = [block_ids[i] for i in sel]
                epochs = [epochs[i] for i in sel]
                n = len(keys)
        now = time.monotonic()
        with self._lock:
            if len(self._free_rows) < n:
                self._grow(n)
            rows = np.empty(n, np.int64)
            fresh = np.zeros(n, bool)
            get = self._rows.get
            rows_dict, row_keys, free = self._rows, self._keys, self._free_rows
            for i, k in enumerate(keys):
                r = get(k)
                if r is None:
                    r = free.pop()
                    rows_dict[k] = r
                    row_keys[r] = k
                    fresh[i] = True
                rows[i] = r
            bids = np.asarray(block_ids, np.int64)
            # a re-published row abandons its old block: clear the reverse
            # pointer it still owns before re-pointing
            if not fresh.all():
                ro = rows[~fresh]
                ob = self._block_id[ro]
                owned = self._block2row[ob] == ro
                self._block2row[ob[owned]] = -1
            self._block_id[rows] = bids
            self._epoch[rows] = np.asarray(epochs, np.int64)
            self._n_tokens[rows] = n_tokens
            self._last_used[rows] = now
            self._block2row[bids] = rows
            if fresh.any():
                self._lru_append(rows[fresh])

    def lookup(self, key: bytes) -> IndexEntry | None:
        with self._lock:
            r = self._rows.get(key)
            if r is None:
                return None
            return IndexEntry(
                int(self._block_id[r]), int(self._epoch[r]),
                int(self._n_tokens[r]), float(self._last_used[r]),
            )

    def lookup_many(self, keys: list[bytes]) -> list[IndexEntry | None]:
        """Batch lookup under one lock acquisition (snapshots)."""
        with self._lock:
            out: list[IndexEntry | None] = []
            get = self._rows.get
            for k in keys:
                r = get(k)
                out.append(
                    None
                    if r is None
                    else IndexEntry(
                        int(self._block_id[r]), int(self._epoch[r]),
                        int(self._n_tokens[r]), float(self._last_used[r]),
                    )
                )
            return out

    def filter_unpublished(self, keys) -> list[int]:
        """Positions in ``keys`` with no valid (committed, current-epoch)
        entry — i.e. the blocks a writeback still has to write. One lock +
        one vectorized epoch check; over RPC this folds the writeback's
        lookup round-trip and the epoch validation into a single op."""
        n = len(keys)
        if not n:
            return []
        with self._lock:
            rows = np.fromiter(
                (self._rows.get(k, -1) for k in keys), np.int64, n
            )
            present = rows >= 0
            ids = self._block_id[rows[present]]
            eps = self._epoch[rows[present]]
        ok = np.zeros(n, bool)
        if ids.size:
            ok[present] = self.pool.validate_epochs(ids, eps)
        return np.nonzero(~ok)[0].tolist()

    def evict_lru(self, n: int) -> list[int]:
        """Evict up to n unreferenced blocks; returns freed block ids.

        A row is a VICTIM only while the index still owns its block:
        refcount exactly 1 AND the row's epoch matches the pool's.  A
        stale row (its block already released — epoch bumped, possibly
        even REALLOCATED to a new owner) must never be "freed" again:
        that was a double release, and against a reallocated block it
        would free someone else's live payload.  Stale rows met during
        the walk are garbage-collected silently instead — dropped from
        the index, not counted in ``freed``, not fed to ``on_evict``
        (they are leftovers, not evictions; the match path's stale-drop
        doesn't arm ghosts either)."""
        freed: list[int] = []
        dropped: list[bytes] = []
        with self._lock:
            nxt = self._lru_next
            block_id = self._block_id
            refcounts = self.pool.refcounts
            epochs = self.pool.epochs
            committed = self.pool.committed
            drop: list[int] = []
            stale: list[int] = []
            r = int(nxt[_HEAD])
            while r != _TAIL and len(freed) < n:
                b = int(block_id[r])
                if refcounts[b] == 1 and committed[b] and epochs[b] == self._epoch[r]:
                    freed.append(b)
                    dropped.append(self._keys[r])
                    drop.append(r)
                elif refcounts[b] <= 0 or epochs[b] != self._epoch[r]:
                    stale.append(r)  # dead row: GC, do NOT re-release
                r = int(nxt[r])
            if drop or stale:
                self._drop_rows(np.asarray(drop + stale, np.int64))
        if freed:
            self.pool.release(freed)
        if dropped and self.on_evict is not None:
            self.on_evict(dropped)
        return freed

    def evict_blocks(self, block_ids: list[int]) -> list[int]:
        """Evict the entries owning specific blocks (tier-local pressure
        relief: the migrator frees cold spill blocks to make demotion
        room). Skips blocks with in-flight references; returns freed ids.

        Same victim rule as ``evict_lru``: the index must still OWN the
        block (refcount exactly 1, row epoch current) — a stale row is
        dropped as garbage without a second ``pool.release`` (which
        could free a reallocated block under its new owner)."""
        freed: list[int] = []
        dropped: list[bytes] = []
        with self._lock:
            ids = np.asarray(block_ids, np.int64)
            if len(ids) > 1:  # dedupe, keeping first-occurrence order
                _, first = np.unique(ids, return_index=True)
                ids = ids[np.sort(first)]
            rows = self._block2row[ids]
            m = rows >= 0
            if m.any():
                cand_ids = ids[m]
                cand_rows = rows[m]
                current = np.asarray(
                    self.pool.validate_epochs(cand_ids, self._epoch[cand_rows]),
                    bool,
                )
                evictable = (self.pool.refcounts[cand_ids] == 1) & current
                stale = ~current & np.asarray(
                    self.pool.refcounts[cand_ids] <= 1, bool
                )
                if evictable.any() or stale.any():
                    drop = cand_rows[evictable | stale]
                    freed = cand_ids[evictable].tolist()
                    dropped = [
                        self._keys[r] for r in cand_rows[evictable].tolist()
                    ]
                    self._drop_rows(drop)
        if freed:
            self.pool.release(freed)
        if dropped and self.on_evict is not None:
            self.on_evict(dropped)
        return freed

    # ------------------------------------------------------------------
    # Tier-migration support: the migrator moves a payload to a new block
    # in another tier, then re-points the (key -> block, epoch) row.
    # ------------------------------------------------------------------
    def keys_of_blocks(self, block_ids) -> list[bytes | None]:
        """Owning key per block id (None for unindexed blocks)."""
        with self._lock:
            rows = self._block2row[np.asarray(block_ids, np.int64)]
            return [self._keys[r] if r >= 0 else None for r in rows.tolist()]

    def owners_of(
        self, block_ids
    ) -> tuple[list[bytes], list[int], list[int]]:
        """(keys, block_ids, epochs) of the currently-indexed blocks among
        ``block_ids`` — the migrator's pre-copy snapshot, taken under ONE
        lock so key and epoch can't disagree (the old two-call sequence
        could race an eviction between them)."""
        with self._lock:
            ids = np.asarray(block_ids, np.int64)
            rows = self._block2row[ids]
            m = rows >= 0
            rows_m = rows[m]
            keys = [self._keys[r] for r in rows_m.tolist()]
            return keys, ids[m].tolist(), self._epoch[rows_m].tolist()

    def remap_many(
        self,
        keys: list[bytes],
        old_ids: list[int],
        old_epochs: list[int],
        new_ids: list[int],
        new_epochs: list[int],
    ) -> list[bool]:
        """Atomically re-point rows after a tier migration.

        Each remap succeeds only if the row still maps to
        (old_id, old_epoch) — a concurrent eviction/re-publish loses the
        race and the caller must roll its copy back. Readers that matched
        before the remap hold (old_id, old_epoch); once the caller
        releases the old block its epoch bumps and their validate fails,
        which is exactly the §5.1 recycle-detection path."""
        n = len(keys)
        if not n:
            return []
        with self._lock:
            rows = np.fromiter(
                (self._rows.get(k, -1) for k in keys), np.int64, n
            )
            ok = (
                (rows >= 0)
                & (self._block_id[rows] == np.asarray(old_ids, np.int64))
                & (self._epoch[rows] == np.asarray(old_epochs, np.int64))
            )
            if ok.any():
                ro = rows[ok]
                old_ok = np.asarray(old_ids, np.int64)[ok]
                owned = self._block2row[old_ok] == ro
                self._block2row[old_ok[owned]] = -1
                new_ok = np.asarray(new_ids, np.int64)[ok]
                self._block_id[ro] = new_ok
                self._epoch[ro] = np.asarray(new_epochs, np.int64)[ok]
                self._block2row[new_ok] = ro
            return ok.tolist()

    def n_entries(self) -> int:
        """Occupancy probe: the eviction-pressure signal of the sharded
        plane (cheap — one lock, one len)."""
        with self._lock:
            return len(self._rows)

    # ------------------------------------------------------------------
    # crash-restart support (the self-healing plane, repro.core.procserver)
    # ------------------------------------------------------------------
    def rebuild_from_journal(self, records) -> int:
        """Replay a shard journal (``repro.core.shm.ShardJournal`` record
        stream) into this — freshly constructed — index.

        Pure replay, deliberately WITHOUT epoch validation against the
        pool: a row that had gone stale before the crash must reappear
        stale, not vanish, so post-restart lookup/match behavior tracks
        the pre-crash index (match GCs stale rows exactly as it would
        have).  Entries are inserted in journal order, so the rebuilt LRU
        approximates the pre-crash recency order (exact up to match
        touches the journal never sees — covered by the chaos harness's
        "modulo evictions" contract).  Returns the number of rows."""
        from repro.core.shm import live_entries

        live = live_entries(records)
        for k, (bid, epoch, ntk) in live.items():
            self.publish(k, bid, epoch, max(0, ntk))
        return len(live)

    def snapshot_entries(
        self, start: int, max_items: int
    ) -> tuple[int, list[bytes], list[int], list[int], list[int]]:
        """One page of the index in LRU order (oldest first).

        Returns ``(total, keys, block_ids, epochs, n_tokens)`` with at
        most ``max_items`` rows starting ``start`` rows in — the paged
        OP_SNAPSHOT op the chaos harness uses to diff a rebuilt shard
        against its pre-crash peer. The cursor is positional: callers
        page a QUIESCED index (a booting/verifying shard), not a live
        one."""
        with self._lock:
            total = len(self._rows)
            keys: list[bytes] = []
            ids: list[int] = []
            eps: list[int] = []
            ntk: list[int] = []
            r = int(self._lru_next[_HEAD])
            i = 0
            while r != _TAIL and len(keys) < max_items:
                if i >= start:
                    keys.append(self._keys[r])
                    ids.append(int(self._block_id[r]))
                    eps.append(int(self._epoch[r]))
                    ntk.append(int(self._n_tokens[r]))
                i += 1
                r = int(self._lru_next[r])
            return total, keys, ids, eps, ntk

    def restore_entries(self, keys, block_ids, epochs, n_tokens) -> int:
        """Bulk-insert entries in order (supervisor-pushed rebuild path:
        the OP_RESTORE twin of ``snapshot_entries``)."""
        for k, b, e, t in zip(keys, block_ids, epochs, n_tokens):
            self.publish(k, int(b), int(e), int(t))
        return len(keys)

    def seed_stats(self, hits: int, misses: int) -> None:
        """Seed the hit/miss counters (warm-snapshot restore path).

        A journal rebuild restores entries but zeroes the counters; a
        supervisor that captured OP_STATS before the crash pushes them
        back so post-restart hit-rate reporting continues from the
        pre-crash totals instead of resetting."""
        with self._lock:
            self.hits = int(hits)
            self.misses = int(misses)

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._rows),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / max(1, self.hits + self.misses),
            }


# ---------------------------------------------------------------------------
# Sharded metadata plane (paper §6 deployment shape: the metadata service
# scales horizontally; one service thread per shard behind its own ring)
# ---------------------------------------------------------------------------
def shard_of_key(key: bytes, n_shards: int) -> int:
    """Routing function of the sharded metadata plane: keys are uniform
    blake2b digests, so a 4-byte prefix mod S balances the shards. Shared
    by the in-process ``ShardedIndex`` and the RPC-side
    ``repro.core.wire.ShardedRpcIndexClient`` — both MUST agree."""
    return int.from_bytes(key[:4], "little") % n_shards


def evict_blocks_sharded(shards, block_ids) -> list[int]:
    """Fan ``evict_blocks`` over shard backends sequentially WITH
    filtering: once a shard frees a block, later shards are never offered
    it (a stale cross-shard alias row must not double-release the freed
    id). Shared by the in-process ``ShardedIndex`` and the RPC
    ``ShardedRpcIndexClient`` so the two planes stay in lockstep."""
    remaining = list(block_ids)
    freed: list[int] = []
    for sh in shards:
        if not remaining:
            break
        got = sh.evict_blocks(remaining)
        if got:
            freed.extend(got)
            gs = set(got)
            remaining = [b for b in remaining if b not in gs]
    return freed


def evict_lru_pressure(shards, n: int) -> list[int]:
    """Occupancy-LEVELING eviction over per-shard LRU lists (waterfill).

    The PR-4 policy split the quota BLINDLY (ceil(n/S) per shard,
    round-robin), so a hot shard holding a handful of live entries lost
    them while a cold shard sat on hundreds of idle ones.  Here every
    round samples each live shard's occupancy (``n_entries``) and drains
    the FULLEST shards down toward a common level: one unit of quota at a
    time goes to the shard with the largest residual occupancy (ties
    toward the lower shard index).  A shard below the resulting water
    level — the hot shard with few entries — is not asked at all while
    any fuller shard can still absorb the pressure.

    The plan is a deterministic function of shard occupancies, and
    occupancy (not LRU-head age) is the signal ON PURPOSE: entry counts
    are identical across transports by construction, while monotonic
    timestamps are not comparable across service PROCESSES — an age
    signal would make thread- and process-mode eviction diverge.  The
    in-process ``ShardedIndex`` and the RPC ``ShardedRpcIndexClient``
    share THIS function, which is what lets the differential harness
    hold every transport to identical freed lists.

    A shard that returns fewer victims than asked is out of evictable
    entries (its ``evict_lru`` walks its whole list) and drops out; the
    loop re-levels the survivors until the need is met or everyone is
    dry.  Each round either frees at least one block or removes a shard,
    so termination is structural.
    """
    freed: list[int] = []
    alive = list(range(len(shards)))
    while len(freed) < n and alive:
        occ = {s: shards[s].n_entries() for s in alive}
        alive = [s for s in alive if occ[s] > 0]
        if not alive:
            break
        need = min(n - len(freed), sum(occ[s] for s in alive))
        # waterfill, closed form: drain every shard down to the minimal
        # common level L with sum(max(0, occ-L)) <= need, then hand the
        # remaining units one each (by shard index) to the shards still
        # AT the level — exactly the plan of granting one unit at a time
        # to the largest residual with ties toward the lower index, in
        # O(S log maxocc) instead of O(need * S)
        lo, hi = 0, max(occ[s] for s in alive)
        while lo < hi:
            mid = (lo + hi) // 2
            if sum(occ[s] - mid for s in alive if occ[s] > mid) <= need:
                hi = mid
            else:
                lo = mid + 1
        level = lo
        quota = {s: max(0, occ[s] - level) for s in alive}
        left = need - sum(quota.values())
        for s in alive:  # < #shards at the level, by construction
            if left <= 0:
                break
            if occ[s] >= level > 0:
                quota[s] += 1
                left -= 1
        survivors = []
        for s in alive:
            k = quota[s]
            if k <= 0:
                survivors.append(s)  # below the water level: spared
                continue
            got = shards[s].evict_lru(k)
            freed.extend(got)
            if len(got) >= k:  # short return = out of victims: drop out
                survivors.append(s)
        alive = survivors
    return freed


def partition_keys(
    keys, n_shards: int
) -> tuple[list[list[bytes]], list[list[int]]]:
    """Split a key chain by owning shard, preserving chain order inside
    each shard. Returns (per-shard key lists, per-shard global positions).

    The prefix property survives the split: the global longest all-hit
    prefix ends at the first missing position m, and every shard's own
    first miss sits at a position >= m, so each shard's prefix-stopping
    ``match_prefix_keys`` over its sub-chain still reports a hit for
    every position < m it owns — merging shard hits back by position and
    cutting at the first hole reconstructs the exact global prefix."""
    key_lists: list[list[bytes]] = [[] for _ in range(n_shards)]
    pos_lists: list[list[int]] = [[] for _ in range(n_shards)]
    for i, k in enumerate(keys):
        s = shard_of_key(k, n_shards)
        key_lists[s].append(k)
        pos_lists[s].append(i)
    return key_lists, pos_lists


class ShardedIndex:
    """S independent ``GlobalIndex`` partitions behind one front.

    Keys route by digest hash (``shard_of_key``); each shard keeps its own
    lock, LRU list and block ownership (a pool block is owned by exactly
    one shard: the shard of the key that published it), so the S service
    threads of the RPC deployment never contend on one lock. The front
    exposes the full ``GlobalIndex`` API surface:

      * chain ops (``match_prefix_keys`` / ``publish_many`` /
        ``lookup_many`` / ``filter_unpublished`` / ``remap_many``) fan out
        the positions each shard owns and merge replies back by position —
        ``match_prefix_keys`` cuts the merged hits at the first hole,
        which is exactly the global longest all-hit prefix (see
        ``partition_keys``);
      * block-keyed ops (``owners_of`` / ``evict_blocks`` /
        ``keys_of_blocks``) ask every shard — only the owner answers;
      * ``evict_lru`` approximates global LRU by occupancy-weighted
        per-shard quotas (``evict_lru_pressure``; exact for S=1).

    S=1 delegates every op verbatim to the single shard: bit-identical to
    an unsharded ``GlobalIndex``. For S>1 two semantics shift slightly,
    both benign: a shard LRU-touches (and epoch-drops) its hits past the
    global prefix cut, and the aggregated hit/miss counters count those
    shard-local hits — they only diverge from the unsharded numbers when
    a chain has a hole (stale entry mid-chain), never on clean hit/miss
    traffic.
    """

    is_sharded = True

    def __init__(self, pool: BelugaPool, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.pool = pool
        self.n_shards = n_shards
        self.block_tokens = pool.layout.block_tokens
        self.shards = [GlobalIndex(pool) for _ in range(n_shards)]
        # hash once at the front; shards share the memo (hashing is pure)
        self.hasher = self.shards[0].hasher
        for sh in self.shards[1:]:
            sh.hasher = self.hasher

    # the ghost-LRU admission filter subscribes to evictions on EVERY
    # shard (ring-served evictions run against the shard objects directly)
    @property
    def on_evict(self):
        return self.shards[0].on_evict

    @on_evict.setter
    def on_evict(self, fn) -> None:
        for sh in self.shards:
            sh.on_evict = fn

    # ------------------------------------------------------------------
    def keys_for(self, tokens: list[int]) -> tuple[bytes, ...]:
        return self.hasher.keys_for(tokens)

    def match_prefix(self, tokens: list[int]) -> list[tuple[bytes, int, int]]:
        return self.match_prefix_keys(self.keys_for(tokens))

    def match_prefix_keys(
        self, keys: tuple[bytes, ...] | list[bytes]
    ) -> list[tuple[bytes, int, int]]:
        if self.n_shards == 1:
            return self.shards[0].match_prefix_keys(keys)
        key_lists, pos_lists = partition_keys(keys, self.n_shards)
        found: list[tuple[int, int] | None] = [None] * len(keys)
        for sh, kl, pl in zip(self.shards, key_lists, pos_lists):
            if kl:
                for (_, b, e), i in zip(sh.match_prefix_keys(kl), pl):
                    found[i] = (b, e)
        out: list[tuple[bytes, int, int]] = []
        for i, k in enumerate(keys):
            f = found[i]
            if f is None:
                break  # first hole ends the global all-hit prefix
            out.append((k, f[0], f[1]))
        return out

    def publish(self, key: bytes, block_id: int, epoch: int, n_tokens: int) -> None:
        self.shards[shard_of_key(key, self.n_shards)].publish(
            key, block_id, epoch, n_tokens
        )

    def publish_many(
        self,
        keys: list[bytes],
        block_ids: list[int],
        epochs: list[int],
        n_tokens: int,
    ) -> None:
        if self.n_shards == 1:
            return self.shards[0].publish_many(keys, block_ids, epochs, n_tokens)
        key_lists, pos_lists = partition_keys(keys, self.n_shards)
        for sh, kl, pl in zip(self.shards, key_lists, pos_lists):
            if kl:
                sh.publish_many(
                    kl,
                    [block_ids[i] for i in pl],
                    [epochs[i] for i in pl],
                    n_tokens,
                )

    def lookup(self, key: bytes) -> IndexEntry | None:
        return self.shards[shard_of_key(key, self.n_shards)].lookup(key)

    def lookup_many(self, keys: list[bytes]) -> list[IndexEntry | None]:
        if self.n_shards == 1:
            return self.shards[0].lookup_many(keys)
        key_lists, pos_lists = partition_keys(keys, self.n_shards)
        out: list[IndexEntry | None] = [None] * len(keys)
        for sh, kl, pl in zip(self.shards, key_lists, pos_lists):
            if kl:
                for e, i in zip(sh.lookup_many(kl), pl):
                    out[i] = e
        return out

    def filter_unpublished(self, keys) -> list[int]:
        if self.n_shards == 1:
            return self.shards[0].filter_unpublished(keys)
        key_lists, pos_lists = partition_keys(keys, self.n_shards)
        out: list[int] = []
        for sh, kl, pl in zip(self.shards, key_lists, pos_lists):
            if kl:
                out.extend(pl[p] for p in sh.filter_unpublished(kl))
        out.sort()
        return out

    def evict_lru(self, n: int) -> list[int]:
        """Approximate global LRU via occupancy-weighted per-shard quotas
        (``evict_lru_pressure``): pressure lands on the shards that hold
        the entries, so a hot shard with a few live entries is spared
        while a cold, full shard absorbs the eviction."""
        if self.n_shards == 1:
            return self.shards[0].evict_lru(n)
        return evict_lru_pressure(self.shards, n)

    def evict_blocks(self, block_ids: list[int]) -> list[int]:
        if self.n_shards == 1:
            return self.shards[0].evict_blocks(block_ids)
        return evict_blocks_sharded(self.shards, block_ids)

    def keys_of_blocks(self, block_ids) -> list[bytes | None]:
        if self.n_shards == 1:
            return self.shards[0].keys_of_blocks(block_ids)
        out: list[bytes | None] = [None] * len(block_ids)
        for sh in self.shards:
            for i, k in enumerate(sh.keys_of_blocks(block_ids)):
                if k is not None:
                    out[i] = k
        return out

    def owners_of(
        self, block_ids
    ) -> tuple[list[bytes], list[int], list[int]]:
        if self.n_shards == 1:
            return self.shards[0].owners_of(block_ids)
        owner: dict[int, tuple[bytes, int]] = {}
        for sh in self.shards:
            keys, ids, eps = sh.owners_of(block_ids)
            for k, b, e in zip(keys, ids, eps):
                owner[b] = (k, e)
        keys_o: list[bytes] = []
        ids_o: list[int] = []
        eps_o: list[int] = []
        for b in block_ids:
            f = owner.get(int(b))
            if f is not None:
                keys_o.append(f[0])
                ids_o.append(int(b))
                eps_o.append(f[1])
        return keys_o, ids_o, eps_o

    def remap_many(
        self,
        keys: list[bytes],
        old_ids: list[int],
        old_epochs: list[int],
        new_ids: list[int],
        new_epochs: list[int],
    ) -> list[bool]:
        if self.n_shards == 1:
            return self.shards[0].remap_many(
                keys, old_ids, old_epochs, new_ids, new_epochs
            )
        key_lists, pos_lists = partition_keys(keys, self.n_shards)
        ok = [False] * len(keys)
        for sh, kl, pl in zip(self.shards, key_lists, pos_lists):
            if kl:
                sub = sh.remap_many(
                    kl,
                    [old_ids[i] for i in pl],
                    [old_epochs[i] for i in pl],
                    [new_ids[i] for i in pl],
                    [new_epochs[i] for i in pl],
                )
                for o, i in zip(sub, pl):
                    ok[i] = o
        return ok

    def stats(self) -> dict:
        per = [sh.stats() for sh in self.shards]
        hits = sum(p["hits"] for p in per)
        misses = sum(p["misses"] for p in per)
        out = {
            "entries": sum(p["entries"] for p in per),
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / max(1, hits + misses),
        }
        if self.n_shards > 1:
            out["shards"] = [p["entries"] for p in per]
        return out

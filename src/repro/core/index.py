"""Global prefix index: token-block hash chain -> pool block (paper §6).

The index is the metadata service that every LLM instance queries before
prefill ("which prefix blocks are already in the pool?") and updates after
("these new blocks now hold tokens [i, i+16)").  In the paper it is a
centralized service reached via CXL-RPC; here the same object is either
called in-process (tests) or behind ``repro.core.rpc`` (cluster benchmarks).

Key design points mirrored from MoonCake/vLLM prefix caching:
  * chain hashing: block key = H(parent_key, tokens_in_block) so a prefix
    match is a walk down the chain — O(n_blocks) lookups, no trie needed;
  * entries carry (block_id, epoch); readers must validate the epoch against
    the pool before trusting the payload (multi-host coherence, §5.1);
  * eviction: LRU over unreferenced committed blocks.
"""

from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass

from repro.core.pool import BelugaPool


def block_key(parent: bytes, tokens: tuple[int, ...]) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    h.update(parent)
    h.update(b"|")
    h.update(b",".join(str(t).encode() for t in tokens))
    return h.digest()


ROOT = b"ROOT"


@dataclass
class IndexEntry:
    block_id: int
    epoch: int
    n_tokens: int
    last_used: float


class GlobalIndex:
    def __init__(self, pool: BelugaPool):
        self.pool = pool
        self.block_tokens = pool.layout.block_tokens
        self._lock = threading.Lock()
        self._map: OrderedDict[bytes, IndexEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------
    def keys_for(self, tokens: list[int]) -> list[bytes]:
        bt = self.block_tokens
        keys, parent = [], ROOT
        for i in range(0, len(tokens) - len(tokens) % bt, bt):
            k = block_key(parent, tuple(tokens[i : i + bt]))
            keys.append(k)
            parent = k
        return keys

    def match_prefix(self, tokens: list[int]) -> list[tuple[bytes, int, int]]:
        """Longest cached prefix: [(key, block_id, epoch)] with valid epochs."""
        out = []
        now = time.monotonic()
        with self._lock:
            for k in self.keys_for(tokens):
                e = self._map.get(k)
                if e is None or not self.pool.validate_epoch(e.block_id, e.epoch):
                    if e is not None:  # stale entry: drop it
                        self._map.pop(k, None)
                    break
                e.last_used = now
                self._map.move_to_end(k)
                out.append((k, e.block_id, e.epoch))
        with self._lock:
            self.hits += len(out)
            self.misses += max(
                0, (len(tokens) // self.block_tokens) - len(out)
            )
        return out

    def publish(self, key: bytes, block_id: int, epoch: int, n_tokens: int) -> None:
        """Writer publishes AFTER the block payload is flushed (coherence)."""
        with self._lock:
            self._map[key] = IndexEntry(block_id, epoch, n_tokens, time.monotonic())
            self._map.move_to_end(key)

    def lookup(self, key: bytes) -> IndexEntry | None:
        with self._lock:
            return self._map.get(key)

    def evict_lru(self, n: int) -> list[int]:
        """Evict up to n unreferenced blocks; returns freed block ids."""
        freed = []
        with self._lock:
            for k in list(self._map.keys()):
                if len(freed) >= n:
                    break
                e = self._map[k]
                if self.pool.meta[e.block_id].refcount <= 1:
                    freed.append(e.block_id)
                    del self._map[k]
        if freed:
            self.pool.release(freed)
        return freed

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._map),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / max(1, self.hits + self.misses),
            }

"""Global prefix index: token-block hash chain -> pool block (paper §6).

The index is the metadata service that every LLM instance queries before
prefill ("which prefix blocks are already in the pool?") and updates after
("these new blocks now hold tokens [i, i+16)").  In the paper it is a
centralized service reached via CXL-RPC; here the same object is either
called in-process (tests) or behind ``repro.core.rpc`` (cluster benchmarks).

Key design points mirrored from MoonCake/vLLM prefix caching:
  * chain hashing: block key = H(parent_key, tokens_in_block) so a prefix
    match is a walk down the chain — O(n_blocks) lookups, no trie needed;
  * entries carry (block_id, epoch); readers must validate the epoch against
    the pool before trusting the payload (multi-host coherence, §5.1);
  * eviction: LRU over unreferenced committed blocks.

Control-plane cost notes (the paths every request hits):
  * token blocks are hashed from ``np.int64`` buffers via ``tobytes()``
    (one C-level encode per block, not one ``str()`` per token);
  * a bounded (parent_key, block_bytes) -> key memo caches chain links, so
    re-deriving the chain for a shared prefix is a dict walk, not blake2b;
  * ``match_prefix`` walks the map under one lock and validates every
    matched entry against the pool's epoch ARRAY in a single vectorized
    check instead of a per-key pool round-trip.
"""

from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.core.pool import BelugaPool

ROOT = b"ROOT"

_CHAIN_CACHE_MAX = 1 << 18


def _hash_link(parent: bytes, token_bytes: bytes) -> bytes:
    return hashlib.blake2b(
        parent + b"|" + token_bytes, digest_size=16
    ).digest()


def block_key(parent: bytes, tokens: tuple[int, ...]) -> bytes:
    return _hash_link(parent, np.asarray(tokens, np.int64).tobytes())


@dataclass(slots=True)
class IndexEntry:
    block_id: int
    epoch: int
    n_tokens: int
    last_used: float


class GlobalIndex:
    def __init__(self, pool: BelugaPool):
        self.pool = pool
        self.block_tokens = pool.layout.block_tokens
        self._lock = threading.Lock()
        self._map: OrderedDict[bytes, IndexEntry] = OrderedDict()
        # block_id -> key reverse map: lets the tiering migrator find the
        # owning key of a cold block in O(1) (and re-point the entry after
        # a tier migration) without walking the whole map
        self._by_block: dict[int, bytes] = {}
        # optional hook fired with the keys of entries destroyed by
        # eviction (evict_lru / evict_blocks): the tiering policy's
        # ghost-LRU admission filter subscribes here. None = zero cost.
        self.on_evict = None
        # parent_key||block_token_bytes -> key chain memo (bounded FIFO)
        self._chain_cache: OrderedDict[bytes, bytes] = OrderedDict()
        # digest(whole token buffer) -> full key list (one hash instead of
        # a 1000-link chain walk when the same request recurs: plan_fetch
        # -> writeback, populate -> cache-hit phase, per-engine locality
        # probes). Returned lists are shared — callers must not mutate.
        self._request_cache: OrderedDict[bytes, list[bytes]] = OrderedDict()
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------
    def keys_for(self, tokens: list[int]) -> list[bytes]:
        bt = self.block_tokens
        n = len(tokens) // bt
        if not n:
            return []
        arr = np.asarray(tokens[: n * bt], np.int64).reshape(n, bt)
        req_key = hashlib.blake2b(arr.tobytes(), digest_size=16).digest()
        cached = self._request_cache.get(req_key)
        if cached is not None:
            return cached
        keys: list[bytes] = []
        parent = ROOT
        cache = self._chain_cache
        cache_get = cache.get
        for i in range(n):
            tb = arr[i].tobytes()
            ck = parent + tb
            k = cache_get(ck)
            if k is None:
                k = _hash_link(parent, tb)
                cache[ck] = k
                if len(cache) > _CHAIN_CACHE_MAX:
                    cache.popitem(last=False)
            keys.append(k)
            parent = k
        self._request_cache[req_key] = keys
        if len(self._request_cache) > 1024:
            self._request_cache.popitem(last=False)
        return keys

    def match_prefix(self, tokens: list[int]) -> list[tuple[bytes, int, int]]:
        """Longest cached prefix: [(key, block_id, epoch)] with valid epochs."""
        return self.match_prefix_keys(self.keys_for(tokens))

    def match_prefix_keys(
        self, keys: list[bytes]
    ) -> list[tuple[bytes, int, int]]:
        """``match_prefix`` over a pre-computed key chain (lets callers that
        also need the keys — e.g. the writeback path — hash once)."""
        out: list[tuple[bytes, int, int]] = []
        now = time.monotonic()
        with self._lock:
            entries: list[tuple[bytes, IndexEntry]] = []
            for k in keys:
                e = self._map.get(k)
                if e is None:
                    break
                entries.append((k, e))
            if entries:
                ids = np.fromiter(
                    (e.block_id for _, e in entries), np.intp, len(entries)
                )
                eps = np.fromiter(
                    (e.epoch for _, e in entries), np.int64, len(entries)
                )
                # one vectorized epoch+committed check for ALL candidates
                ok = self.pool.validate_epochs(ids, eps)
                n_ok = len(entries) if ok.all() else int(np.argmin(ok))
                for k, e in entries[:n_ok]:
                    e.last_used = now
                    self._map.move_to_end(k)
                    out.append((k, e.block_id, e.epoch))
                if n_ok < len(entries):  # stale entry: drop it
                    sk, se = entries[n_ok]
                    self._map.pop(sk, None)
                    if self._by_block.get(se.block_id) == sk:
                        del self._by_block[se.block_id]
            self.hits += len(out)
            self.misses += max(0, len(keys) - len(out))
        return out

    def publish(self, key: bytes, block_id: int, epoch: int, n_tokens: int) -> None:
        """Writer publishes AFTER the block payload is flushed (coherence)."""
        with self._lock:
            old = self._map.get(key)
            if old is not None and self._by_block.get(old.block_id) == key:
                del self._by_block[old.block_id]
            self._map[key] = IndexEntry(block_id, epoch, n_tokens, time.monotonic())
            self._map.move_to_end(key)
            self._by_block[block_id] = key

    def publish_many(
        self,
        keys: list[bytes],
        block_ids: list[int],
        epochs: list[int],
        n_tokens: int,
    ) -> None:
        """Batch publish under one lock acquisition.

        No per-key ``move_to_end``: a NEW key lands at the back (most
        recent) by dict insertion order already; only a re-publish of a
        still-present key (rare: epoch-invalidated entry not yet dropped)
        keeps its old LRU slot, which only makes it eviction-eligible
        sooner — safe."""
        now = time.monotonic()
        with self._lock:
            m = self._map
            by_block = self._by_block
            for key, bid, epoch in zip(keys, block_ids, epochs):
                old = m.get(key)
                if old is not None and by_block.get(old.block_id) == key:
                    del by_block[old.block_id]
                m[key] = IndexEntry(bid, epoch, n_tokens, now)
                by_block[bid] = key

    def lookup(self, key: bytes) -> IndexEntry | None:
        with self._lock:
            return self._map.get(key)

    def lookup_many(self, keys: list[bytes]) -> list[IndexEntry | None]:
        """Batch lookup under one lock acquisition."""
        with self._lock:
            return [self._map.get(k) for k in keys]

    def evict_lru(self, n: int) -> list[int]:
        """Evict up to n unreferenced blocks; returns freed block ids."""
        freed, dropped = [], []
        with self._lock:
            for k in list(self._map.keys()):
                if len(freed) >= n:
                    break
                e = self._map[k]
                if self.pool.refcounts[e.block_id] <= 1:
                    freed.append(e.block_id)
                    dropped.append(k)
                    del self._map[k]
                    if self._by_block.get(e.block_id) == k:
                        del self._by_block[e.block_id]
        if freed:
            self.pool.release(freed)
        if dropped and self.on_evict is not None:
            self.on_evict(dropped)
        return freed

    def evict_blocks(self, block_ids: list[int]) -> list[int]:
        """Evict the entries owning specific blocks (tier-local pressure
        relief: the migrator frees cold spill blocks to make demotion
        room). Skips blocks with in-flight references; returns freed ids."""
        freed, dropped = [], []
        with self._lock:
            for b in block_ids:
                k = self._by_block.get(b)
                if k is None:
                    continue
                e = self._map.get(k)
                if e is None or e.block_id != b:
                    continue
                if self.pool.refcounts[b] > 1:
                    continue
                freed.append(b)
                dropped.append(k)
                del self._map[k]
                del self._by_block[b]
        if freed:
            self.pool.release(freed)
        if dropped and self.on_evict is not None:
            self.on_evict(dropped)
        return freed

    # ------------------------------------------------------------------
    # Tier-migration support: the migrator moves a payload to a new block
    # in another tier, then re-points the (key -> block, epoch) entry.
    # ------------------------------------------------------------------
    def keys_of_blocks(self, block_ids) -> list[bytes | None]:
        """Owning key per block id (None for unindexed blocks)."""
        with self._lock:
            return [self._by_block.get(int(b)) for b in block_ids]

    def remap_many(
        self,
        keys: list[bytes],
        old_ids: list[int],
        old_epochs: list[int],
        new_ids: list[int],
        new_epochs: list[int],
    ) -> list[bool]:
        """Atomically re-point entries after a tier migration.

        Each remap succeeds only if the entry still maps to
        (old_id, old_epoch) — a concurrent eviction/re-publish loses the
        race and the caller must roll its copy back. Readers that matched
        before the remap hold (old_id, old_epoch); once the caller
        releases the old block its epoch bumps and their validate fails,
        which is exactly the §5.1 recycle-detection path."""
        out = []
        with self._lock:
            for key, old_id, old_epoch, new_id, new_epoch in zip(
                keys, old_ids, old_epochs, new_ids, new_epochs
            ):
                e = self._map.get(key)
                if e is None or e.block_id != old_id or e.epoch != old_epoch:
                    out.append(False)
                    continue
                if self._by_block.get(old_id) == key:
                    del self._by_block[old_id]
                e.block_id = new_id
                e.epoch = new_epoch
                self._by_block[new_id] = key
                out.append(True)
        return out

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._map),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / max(1, self.hits + self.misses),
            }

"""Global prefix index: token-block hash chain -> pool block (paper §6).

The index is the metadata service that every LLM instance queries before
prefill ("which prefix blocks are already in the pool?") and updates after
("these new blocks now hold tokens [i, i+16)").  In the paper it is a
centralized service reached via CXL-RPC; here the same object is either
called in-process (tests) or behind ``repro.core.rpc`` (cluster benchmarks).

Key design points mirrored from MoonCake/vLLM prefix caching:
  * chain hashing: block key = H(parent_key, tokens_in_block) so a prefix
    match is a walk down the chain — O(n_blocks) lookups, no trie needed;
  * entries carry (block_id, epoch); readers must validate the epoch against
    the pool before trusting the payload (multi-host coherence, §5.1);
  * eviction: LRU over unreferenced committed blocks.

Control-plane cost notes (the paths every request hits):
  * token blocks are hashed from ``np.int64`` buffers via ``tobytes()``
    (one C-level encode per block, not one ``str()`` per token);
  * a bounded (parent_key, block_bytes) -> key memo caches chain links, so
    re-deriving the chain for a shared prefix is a dict walk, not blake2b;
  * ``match_prefix`` walks the map under one lock and validates every
    matched entry against the pool's epoch ARRAY in a single vectorized
    check instead of a per-key pool round-trip.
"""

from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.core.pool import BelugaPool

ROOT = b"ROOT"

_CHAIN_CACHE_MAX = 1 << 18


def _hash_link(parent: bytes, token_bytes: bytes) -> bytes:
    return hashlib.blake2b(
        parent + b"|" + token_bytes, digest_size=16
    ).digest()


def block_key(parent: bytes, tokens: tuple[int, ...]) -> bytes:
    return _hash_link(parent, np.asarray(tokens, np.int64).tobytes())


@dataclass(slots=True)
class IndexEntry:
    block_id: int
    epoch: int
    n_tokens: int
    last_used: float


class GlobalIndex:
    def __init__(self, pool: BelugaPool):
        self.pool = pool
        self.block_tokens = pool.layout.block_tokens
        self._lock = threading.Lock()
        self._map: OrderedDict[bytes, IndexEntry] = OrderedDict()
        # parent_key||block_token_bytes -> key chain memo (bounded FIFO)
        self._chain_cache: OrderedDict[bytes, bytes] = OrderedDict()
        # digest(whole token buffer) -> full key list (one hash instead of
        # a 1000-link chain walk when the same request recurs: plan_fetch
        # -> writeback, populate -> cache-hit phase, per-engine locality
        # probes). Returned lists are shared — callers must not mutate.
        self._request_cache: OrderedDict[bytes, list[bytes]] = OrderedDict()
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------
    def keys_for(self, tokens: list[int]) -> list[bytes]:
        bt = self.block_tokens
        n = len(tokens) // bt
        if not n:
            return []
        arr = np.asarray(tokens[: n * bt], np.int64).reshape(n, bt)
        req_key = hashlib.blake2b(arr.tobytes(), digest_size=16).digest()
        cached = self._request_cache.get(req_key)
        if cached is not None:
            return cached
        keys: list[bytes] = []
        parent = ROOT
        cache = self._chain_cache
        cache_get = cache.get
        for i in range(n):
            tb = arr[i].tobytes()
            ck = parent + tb
            k = cache_get(ck)
            if k is None:
                k = _hash_link(parent, tb)
                cache[ck] = k
                if len(cache) > _CHAIN_CACHE_MAX:
                    cache.popitem(last=False)
            keys.append(k)
            parent = k
        self._request_cache[req_key] = keys
        if len(self._request_cache) > 1024:
            self._request_cache.popitem(last=False)
        return keys

    def match_prefix(self, tokens: list[int]) -> list[tuple[bytes, int, int]]:
        """Longest cached prefix: [(key, block_id, epoch)] with valid epochs."""
        return self.match_prefix_keys(self.keys_for(tokens))

    def match_prefix_keys(
        self, keys: list[bytes]
    ) -> list[tuple[bytes, int, int]]:
        """``match_prefix`` over a pre-computed key chain (lets callers that
        also need the keys — e.g. the writeback path — hash once)."""
        out: list[tuple[bytes, int, int]] = []
        now = time.monotonic()
        with self._lock:
            entries: list[tuple[bytes, IndexEntry]] = []
            for k in keys:
                e = self._map.get(k)
                if e is None:
                    break
                entries.append((k, e))
            if entries:
                ids = np.fromiter(
                    (e.block_id for _, e in entries), np.intp, len(entries)
                )
                eps = np.fromiter(
                    (e.epoch for _, e in entries), np.int64, len(entries)
                )
                # one vectorized epoch+committed check for ALL candidates
                ok = self.pool.validate_epochs(ids, eps)
                n_ok = len(entries) if ok.all() else int(np.argmin(ok))
                for k, e in entries[:n_ok]:
                    e.last_used = now
                    self._map.move_to_end(k)
                    out.append((k, e.block_id, e.epoch))
                if n_ok < len(entries):  # stale entry: drop it
                    self._map.pop(entries[n_ok][0], None)
            self.hits += len(out)
            self.misses += max(0, len(keys) - len(out))
        return out

    def publish(self, key: bytes, block_id: int, epoch: int, n_tokens: int) -> None:
        """Writer publishes AFTER the block payload is flushed (coherence)."""
        with self._lock:
            self._map[key] = IndexEntry(block_id, epoch, n_tokens, time.monotonic())
            self._map.move_to_end(key)

    def publish_many(
        self,
        keys: list[bytes],
        block_ids: list[int],
        epochs: list[int],
        n_tokens: int,
    ) -> None:
        """Batch publish under one lock acquisition.

        No per-key ``move_to_end``: a NEW key lands at the back (most
        recent) by dict insertion order already; only a re-publish of a
        still-present key (rare: epoch-invalidated entry not yet dropped)
        keeps its old LRU slot, which only makes it eviction-eligible
        sooner — safe."""
        now = time.monotonic()
        with self._lock:
            m = self._map
            for key, bid, epoch in zip(keys, block_ids, epochs):
                m[key] = IndexEntry(bid, epoch, n_tokens, now)

    def lookup(self, key: bytes) -> IndexEntry | None:
        with self._lock:
            return self._map.get(key)

    def lookup_many(self, keys: list[bytes]) -> list[IndexEntry | None]:
        """Batch lookup under one lock acquisition."""
        with self._lock:
            return [self._map.get(k) for k in keys]

    def evict_lru(self, n: int) -> list[int]:
        """Evict up to n unreferenced blocks; returns freed block ids."""
        freed = []
        with self._lock:
            for k in list(self._map.keys()):
                if len(freed) >= n:
                    break
                e = self._map[k]
                if self.pool.refcounts[e.block_id] <= 1:
                    freed.append(e.block_id)
                    del self._map[k]
        if freed:
            self.pool.release(freed)
        return freed

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._map),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / max(1, self.hits + self.misses),
            }

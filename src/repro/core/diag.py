"""Lightweight diagnostics counters for intentionally-tolerated failures.

The exception-hygiene lint pass (``tools/beluga_lint``) fails any broad
``except Exception`` handler that neither re-raises, logs, nor records
the event.  Teardown and best-effort paths (idempotent double-close,
atexit hygiene, dead-worker forwarding) must stay silent and cheap — but
not *invisible*: they call ``note(event)`` here, which bumps a named
counter that tests and operators can read back via ``counters()``.

Counters, not log records, on purpose: several of these sites run inside
``atexit`` during interpreter shutdown, where the logging machinery may
already be torn down; a dict increment can never fail there.  The
counters are process-local (each engine worker / shard service keeps its
own) and are NOT thread-exact under contention — a lost increment on two
racing teardowns is acceptable for a diagnostic, a crash is not.
"""

from __future__ import annotations

from collections import Counter

_counters: Counter[str] = Counter()


def note(event: str) -> None:
    """Record one occurrence of a tolerated failure (never raises)."""
    _counters[event] += 1


def counters() -> dict[str, int]:
    """Snapshot of every recorded event count."""
    return dict(_counters)


def count(event: str) -> int:
    return _counters.get(event, 0)


def reset() -> None:
    """Test hook: clear all counters."""
    _counters.clear()

"""BelugaPool: the shared, interleaved KV block pool (the paper's §4 + O9).

One pool instance represents the rack-scale shared memory (8 TB behind the
CXL switch in the paper; the sharded host/HBM capacity tier on a TPU pod).
The pool is paged: fixed-size *blocks* of ``block_tokens`` tokens, each
holding every layer's K and V fragments for those tokens, packed contiguous.

Two backings:
  * ``numpy`` — the serving control plane (real allocator + real copies);
  * ``jax``   — device-side pool array used by the Pallas/XLA data path
                (gather/scatter reads feed attention directly).

Interleaving (O9): block b lives on shard ``b % n_shards``; the allocator
balances allocation across shards and exposes per-shard occupancy so the
benchmarks can show the skew/queueing effect of turning interleaving off.

Single-writer / multi-reader coherence (§5.1) is enforced with per-block
epochs — see ``repro.core.coherence``.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class PoolLayout:
    """Byte layout of one pool block for a model config."""

    block_tokens: int
    n_layers_kv: int  # attention layers
    n_kv_heads: int
    head_dim: int
    dtype_bytes: int = 2

    @property
    def fragment_bytes(self) -> int:
        """One (layer, k|v) fragment for a block: the paper's 20 KB unit."""
        return self.block_tokens * self.n_kv_heads * self.head_dim * self.dtype_bytes

    @property
    def n_fragments(self) -> int:
        """Fragments per block: 2 * n_layers (Qwen3-32B: 128)."""
        return 2 * self.n_layers_kv

    @property
    def block_bytes(self) -> int:
        return self.n_fragments * self.fragment_bytes

    @property
    def token_bytes(self) -> int:
        return self.block_bytes // self.block_tokens

    @classmethod
    def for_model(cls, cfg: ModelConfig, block_tokens: int = 16) -> "PoolLayout":
        return cls(
            block_tokens=block_tokens,
            n_layers_kv=max(1, len(cfg.attn_layer_ids())),
            n_kv_heads=max(1, cfg.n_kv_heads),
            head_dim=max(1, cfg.head_dim),
        )


class OutOfPoolMemory(RuntimeError):
    pass


@dataclass
class BlockMeta:
    epoch: int = 0  # bumped on every (re)write; readers validate
    refcount: int = 0
    committed: bool = False


class BelugaPool:
    """Block allocator + storage over interleaved shards."""

    def __init__(
        self,
        layout: PoolLayout,
        n_blocks: int,
        n_shards: int = 32,
        backing: str = "numpy",
        interleave: bool = True,
    ):
        assert n_blocks % n_shards == 0, (n_blocks, n_shards)
        self.layout = layout
        self.n_blocks = n_blocks
        self.n_shards = n_shards
        self.interleave = interleave
        self.backing = backing
        self._lock = threading.Lock()
        self._free: list[int] = list(range(n_blocks))
        self.meta: list[BlockMeta] = [BlockMeta() for _ in range(n_blocks)]
        self.alloc_count = 0
        if backing == "meta":
            # control-plane only (cluster sim at paper scale): allocator,
            # epochs and index run for real; payloads are not stored.
            self.data = None
        elif backing == "numpy":
            # (n_blocks, block_bytes) uint8 — fragment-addressable
            self.data = np.zeros((n_blocks, layout.block_bytes), np.uint8)
        elif backing == "jax":
            import jax.numpy as jnp

            # (n_blocks, 2*L, block_tokens, hkv, hd) device-side pool
            self.data = jnp.zeros(
                (
                    n_blocks,
                    layout.n_fragments,
                    layout.block_tokens,
                    layout.n_kv_heads,
                    layout.head_dim,
                ),
                jnp.bfloat16,
            )
        else:
            raise ValueError(backing)

    # ------------------------------------------------------------------
    def shard_of(self, block_id: int) -> int:
        if self.interleave:
            return block_id % self.n_shards
        # no interleaving: fill shard 0 first (the paper's §5.3 bottleneck)
        return block_id // (self.n_blocks // self.n_shards)

    def free_blocks(self) -> int:
        with self._lock:
            return len(self._free)

    def shard_occupancy(self) -> list[int]:
        occ = [0] * self.n_shards
        with self._lock:
            free = set(self._free)
        for b in range(self.n_blocks):
            if b not in free:
                occ[self.shard_of(b)] += 1
        return occ

    # ------------------------------------------------------------------
    def allocate(self, n: int) -> list[int]:
        """Allocate n blocks, round-robin across shards when interleaving."""
        with self._lock:
            if len(self._free) < n:
                raise OutOfPoolMemory(f"need {n}, have {len(self._free)}")
            if self.interleave:
                # pick blocks spreading across shards
                by_shard: dict[int, list[int]] = {}
                for b in self._free:
                    by_shard.setdefault(b % self.n_shards, []).append(b)
                out: list[int] = []
                shard_ids = sorted(by_shard, key=lambda s: -len(by_shard[s]))
                i = 0
                while len(out) < n:
                    s = shard_ids[i % len(shard_ids)]
                    if by_shard[s]:
                        out.append(by_shard[s].pop())
                    i += 1
                    if i > 4 * self.n_shards + n * 2:  # degenerate fallback
                        remaining = [b for lst in by_shard.values() for b in lst]
                        out.extend(remaining[: n - len(out)])
                        break
            else:
                out = [self._free[i] for i in range(n)]
            free_set = set(out)
            self._free = [b for b in self._free if b not in free_set]
            for b in out:
                m = self.meta[b]
                m.refcount = 1
                m.committed = False
            self.alloc_count += n
            return out

    def retain(self, block_ids: list[int]) -> None:
        with self._lock:
            for b in block_ids:
                assert self.meta[b].refcount > 0, f"retain of free block {b}"
                self.meta[b].refcount += 1

    def release(self, block_ids: list[int]) -> None:
        with self._lock:
            for b in block_ids:
                m = self.meta[b]
                m.refcount -= 1
                assert m.refcount >= 0, f"double free of block {b}"
                if m.refcount == 0:
                    m.committed = False
                    m.epoch += 1  # invalidate readers holding stale ids
                    self._free.append(b)

    # ------------------------------------------------------------------
    # Data plane (numpy backing): fragment reads/writes
    # ------------------------------------------------------------------
    def write_block(self, block_id: int, payload: np.ndarray) -> int:
        """Write a full block; returns the publish epoch (see coherence)."""
        if self.data is not None:
            assert payload.nbytes == self.layout.block_bytes
            self.data[block_id] = payload.reshape(-1).view(np.uint8)
        with self._lock:
            m = self.meta[block_id]
            m.epoch += 1
            m.committed = True
            return m.epoch

    def read_block(self, block_id: int) -> tuple[np.ndarray, int]:
        with self._lock:
            e = self.meta[block_id].epoch
        if self.data is None:
            return np.zeros(self.layout.block_bytes, np.uint8), e
        return self.data[block_id].copy(), e

    def read_fragments(self, block_id: int, frag_ids: list[int]) -> np.ndarray:
        fb = self.layout.fragment_bytes
        block = self.data[block_id]
        return np.stack([block[f * fb : (f + 1) * fb] for f in frag_ids])

    def validate_epoch(self, block_id: int, epoch: int) -> bool:
        with self._lock:
            m = self.meta[block_id]
            return m.committed and m.epoch == epoch

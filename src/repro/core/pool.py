"""BelugaPool: the shared, interleaved KV block pool (the paper's §4 + O9).

One pool instance represents the rack-scale shared memory (8 TB behind the
CXL switch in the paper; the sharded host/HBM capacity tier on a TPU pod).
The pool is paged: fixed-size *blocks* of ``block_tokens`` tokens, each
holding every layer's K and V fragments for those tokens, packed contiguous.

Two backings:
  * ``numpy`` — the serving control plane (real allocator + real copies);
  * ``jax``   — device-side pool array used by the Pallas/XLA data path
                (gather/scatter reads feed attention directly).

Interleaving (O9): block b lives on shard ``b % n_shards``; the allocator
balances allocation across shards and exposes per-shard occupancy so the
benchmarks can show the skew/queueing effect of turning interleaving off.

Allocator design (control plane must be O(blocks touched), never O(pool)):
  * one persistent free stack per shard — ``allocate`` pops round-robin
    across shards (fullest-first order, as the seed allocator placed
    blocks) without ever walking the whole free set;
  * occupancy counters are maintained incrementally, so
    ``shard_occupancy()`` is O(n_shards) and ``free_blocks()`` is O(1);
  * per-block metadata (epoch / refcount / committed) lives in flat numpy
    arrays so retain/release/validate batch under ONE lock acquisition
    with vectorized index arithmetic.

Single-writer / multi-reader coherence (§5.1) is enforced with per-block
epochs — see ``repro.core.coherence``.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import diag
from repro.core.locks import make_lock


@dataclass(frozen=True)
class PoolLayout:
    """Byte layout of one pool block for a model config."""

    block_tokens: int
    n_layers_kv: int  # attention layers
    n_kv_heads: int
    head_dim: int
    dtype_bytes: int = 2

    @property
    def fragment_bytes(self) -> int:
        """One (layer, k|v) fragment for a block: the paper's 20 KB unit."""
        return self.block_tokens * self.n_kv_heads * self.head_dim * self.dtype_bytes

    @property
    def n_fragments(self) -> int:
        """Fragments per block: 2 * n_layers (Qwen3-32B: 128)."""
        return 2 * self.n_layers_kv

    @property
    def block_bytes(self) -> int:
        return self.n_fragments * self.fragment_bytes

    @property
    def token_bytes(self) -> int:
        return self.block_bytes // self.block_tokens

    @classmethod
    def for_model(cls, cfg: ModelConfig, block_tokens: int = 16) -> "PoolLayout":
        return cls(
            block_tokens=block_tokens,
            n_layers_kv=max(1, len(cfg.attn_layer_ids())),
            n_kv_heads=max(1, cfg.n_kv_heads),
            head_dim=max(1, cfg.head_dim),
        )


class OutOfPoolMemory(RuntimeError):
    pass


class BelugaPool:
    """Block allocator + storage over interleaved shards."""

    def __init__(
        self,
        layout: PoolLayout,
        n_blocks: int,
        n_shards: int = 32,
        backing: str = "numpy",
        interleave: bool = True,
    ):
        assert n_blocks % n_shards == 0, (n_blocks, n_shards)
        self.layout = layout
        self.n_blocks = n_blocks
        self.n_shards = n_shards
        self.interleave = interleave
        self.backing = backing
        self._lock = make_lock("pool.BelugaPool._lock")
        # vectorized per-block metadata (re-homed into a named shared
        # segment by ``share_meta`` for cross-process metadata services)
        self.epochs = np.zeros(n_blocks, np.int64)
        self.refcounts = np.zeros(n_blocks, np.int32)
        self.committed = np.zeros(n_blocks, bool)
        self._meta_segment = None
        self._meta_spec: dict | None = None
        self._data_segment = None
        self._data_spec: dict | None = None
        # free structures: per-shard LIFO stacks (interleave) or one FIFO
        # queue (no interleave: fill shard 0 first, the §5.3 bottleneck)
        if interleave:
            self._free_by_shard: list[list[int]] = [
                list(range(s, n_blocks, n_shards)) for s in range(n_shards)
            ]
            self._free_fifo: deque[int] | None = None
        else:
            self._free_by_shard = []
            self._free_fifo = deque(range(n_blocks))
        # free-age stamps: ties between equally-full shards resolve toward
        # the shard whose oldest free block has been free longest — the
        # order the seed allocator's by-shard rebuild produced implicitly
        self._age = np.arange(n_blocks, dtype=np.int64)
        self._stamp = n_blocks
        self._n_free = n_blocks
        self._occ = [0] * n_shards  # allocated (non-free) blocks per shard
        self.alloc_count = 0
        if backing == "meta":
            # control-plane only (cluster sim at paper scale): allocator,
            # epochs and index run for real; payloads are not stored.
            self.data = None
        elif backing == "numpy":
            # (n_blocks, block_bytes) uint8 — fragment-addressable
            self.data = np.zeros((n_blocks, layout.block_bytes), np.uint8)
        elif backing == "jax":
            import jax.numpy as jnp

            # (n_blocks, 2*L, block_tokens, hkv, hd) device-side pool
            self.data = jnp.zeros(
                (
                    n_blocks,
                    layout.n_fragments,
                    layout.block_tokens,
                    layout.n_kv_heads,
                    layout.head_dim,
                ),
                jnp.bfloat16,
            )
        else:
            raise ValueError(backing)

    # ------------------------------------------------------------------
    # Cross-process metadata export (paper: pool state IS shared memory)
    # ------------------------------------------------------------------
    def share_meta(self) -> dict:
        """Re-home epochs/refcounts/committed into a named shared segment.

        An out-of-process metadata service (``repro.core.procserver``)
        attaches the SAME arrays by name (``SharedPoolMeta``) and reads
        the truth the engines write — epoch validation and refcount
        checks are plain loads on the shared pool state, exactly the
        paper's trust model (the service owns no copy of anything).
        Idempotent; returns the attach spec (plain data, picklable).
        The pool keeps sole ownership of allocation/release — attachers
        never mutate.
        """
        if self._meta_spec is not None:
            return self._meta_spec
        from repro.core.shm import create_segment

        n = self.n_blocks
        seg = create_segment(13 * n)  # 8 B epoch + 4 B refcount + 1 B flag
        eps = np.frombuffer(seg.buf, np.int64, n, 0)
        rcs = np.frombuffer(seg.buf, np.int32, n, 8 * n)
        com = np.frombuffer(seg.buf, np.bool_, n, 12 * n)
        with self._lock:
            eps[:] = self.epochs
            rcs[:] = self.refcounts
            com[:] = self.committed
            self.epochs, self.refcounts, self.committed = eps, rcs, com
        self._meta_segment = seg
        self._meta_spec = {
            "shm_name": seg.name,
            "n_blocks": n,
            "block_tokens": self.layout.block_tokens,
        }
        import atexit

        atexit.register(self.unshare_meta)  # no leaked /dev/shm entries
        return self._meta_spec

    def unshare_meta(self) -> None:
        """Copy metadata back to private arrays and unlink the segment.

        Safe to call repeatedly / when never shared; the pool stays fully
        functional afterwards (values preserved)."""
        seg = self._meta_segment
        if seg is None:
            return
        from repro.core.shm import close_segment

        with self._lock:
            self.epochs = np.array(self.epochs, np.int64)
            self.refcounts = np.array(self.refcounts, np.int32)
            self.committed = np.array(self.committed, bool)
        self._meta_segment = None
        self._meta_spec = None
        close_segment(seg, unlink=True)
        import atexit

        try:
            atexit.unregister(self.unshare_meta)
        except Exception:  # noqa: BLE001
            diag.note("pool.unshare_meta.unregister_failed")

    # ------------------------------------------------------------------
    # Cross-process DATA export (the paper's headline: the block payloads
    # themselves are one shared pool every participant loads/stores)
    # ------------------------------------------------------------------
    def share_data(self) -> dict:
        """Re-home the block payload array into a named shared segment.

        Engine worker processes (``repro.serving.engineproc``) attach the
        SAME ``(n_blocks, block_bytes)`` array by name
        (``repro.core.shmpool.SharedPoolData``) and scatter/gather KV
        blocks against it directly — zero payload copies through the
        parent, the modeled CXL load/store path crossing a real OS
        process boundary.  Allocation stays with this pool (served over
        a ring); writers own freshly-allocated blocks exclusively until
        publish, so payload stores need no cross-process lock (§5.1
        single-writer).  Implies ``share_meta`` (epoch validation is a
        plain load on the shared metadata).  Idempotent; returns the
        attach spec (plain data, picklable).
        """
        if self._data_spec is not None:
            return self._data_spec
        if self.backing != "numpy":
            raise ValueError(
                f"share_data requires backing='numpy', not {self.backing!r}"
            )
        meta = self.share_meta()
        from repro.core.shm import create_segment

        lay = self.layout
        seg = create_segment(self.n_blocks * lay.block_bytes)
        view = np.frombuffer(seg.buf, np.uint8).reshape(
            self.n_blocks, lay.block_bytes
        )
        with self._lock:
            view[:] = self.data
            self.data = view
        self._data_segment = seg
        self._data_spec = {
            "data_shm_name": seg.name,
            "meta": meta,
            "n_blocks": self.n_blocks,
            "block_tokens": lay.block_tokens,
            "n_layers_kv": lay.n_layers_kv,
            "n_kv_heads": lay.n_kv_heads,
            "head_dim": lay.head_dim,
            "dtype_bytes": lay.dtype_bytes,
        }
        import atexit

        atexit.register(self.unshare_data)  # no leaked /dev/shm entries
        return self._data_spec

    def unshare_data(self) -> None:
        """Copy payloads back to a private array and unlink the segment.

        Safe to call repeatedly / when never shared; leaves ``share_meta``
        as-is (its own unshare handles it)."""
        seg = self._data_segment
        if seg is None:
            return
        from repro.core.shm import close_segment

        with self._lock:
            self.data = np.array(self.data, np.uint8)
        self._data_segment = None
        self._data_spec = None
        close_segment(seg, unlink=True)
        import atexit

        try:
            atexit.unregister(self.unshare_data)
        except Exception:  # noqa: BLE001
            diag.note("pool.unshare_data.unregister_failed")

    # ------------------------------------------------------------------
    def shard_of(self, block_id: int) -> int:
        if self.interleave:
            return block_id % self.n_shards
        # no interleaving: fill shard 0 first (the paper's §5.3 bottleneck)
        return block_id // (self.n_blocks // self.n_shards)

    def free_blocks(self) -> int:
        with self._lock:
            return self._n_free

    def shard_occupancy(self) -> list[int]:
        with self._lock:
            return list(self._occ)

    # ------------------------------------------------------------------
    def allocate(self, n: int) -> list[int]:
        """Allocate n blocks, round-robin across shards when interleaving."""
        with self._lock:
            if self._n_free < n:
                raise OutOfPoolMemory(f"need {n}, have {self._n_free}")
            out: list[int] = []
            if self.interleave:
                stacks = self._free_by_shard
                # fullest shards first, then round-robin over that order —
                # the same placement policy as the seed allocator, but over
                # persistent stacks instead of a per-call full-list rebuild
                age = self._age
                order = sorted(
                    (s for s in range(self.n_shards) if stacks[s]),
                    key=lambda s: (-len(stacks[s]), age[stacks[s][0]]),
                )
                i = 0
                while len(out) < n:
                    s = order[i % len(order)]
                    if stacks[s]:
                        out.append(stacks[s].pop())
                        self._occ[s] += 1
                    i += 1
                    if i > 4 * self.n_shards + n * 2:  # degenerate fallback
                        # seed parity: sweep the remaining free blocks in
                        # by-shard build order (oldest free block first),
                        # oldest-to-newest within each shard
                        rem = sorted(
                            (s for s in range(self.n_shards) if stacks[s]),
                            key=lambda s: age[stacks[s][0]],
                        )
                        for s in rem:
                            k = min(len(stacks[s]), n - len(out))
                            if k <= 0:
                                break
                            out.extend(stacks[s][:k])
                            del stacks[s][:k]
                            self._occ[s] += k
                        break
            else:
                fifo = self._free_fifo
                per = self.n_blocks // self.n_shards
                for _ in range(n):
                    b = fifo.popleft()
                    out.append(b)
                    self._occ[b // per] += 1
            self._n_free -= n
            ids = np.asarray(out, np.intp)
            self.refcounts[ids] = 1
            self.committed[ids] = False
            self.alloc_count += n
            return out

    def retain(self, block_ids: list[int]) -> None:
        if not len(block_ids):
            return
        ids = np.asarray(block_ids, np.intp)
        with self._lock:
            assert (self.refcounts[ids] > 0).all(), "retain of free block"
            np.add.at(self.refcounts, ids, 1)

    def release(self, block_ids: list[int]) -> None:
        if not len(block_ids):
            return
        ids = np.asarray(block_ids, np.intp)
        with self._lock:
            np.subtract.at(self.refcounts, ids, 1)
            assert (self.refcounts[ids] >= 0).all(), "double free"
            zero = self.refcounts[ids] == 0
            if not zero.any():
                return
            # freed blocks re-enter the free structures in CALLER order
            # (dedup'd), preserving the seed allocator's reuse order
            seen: set[int] = set()
            freed = [
                b for b, z in zip(ids.tolist(), zero.tolist())
                if z and not (b in seen or seen.add(b))
            ]
            farr = np.asarray(freed, np.intp)
            self.committed[farr] = False
            self.epochs[farr] += 1  # invalidate readers holding stale ids
            if self.interleave:
                for b in freed:
                    s = b % self.n_shards
                    self._free_by_shard[s].append(b)
                    self._occ[s] -= 1
                    self._age[b] = self._stamp
                    self._stamp += 1
            else:
                per = self.n_blocks // self.n_shards
                for b in freed:
                    self._free_fifo.append(b)
                    self._occ[b // per] -= 1
            self._n_free += len(freed)

    # ------------------------------------------------------------------
    # Data plane (numpy backing): fragment reads/writes
    # ------------------------------------------------------------------
    def write_block(self, block_id: int, payload: np.ndarray | None) -> int:
        """Write a full block; returns the publish epoch (see coherence)."""
        if self.data is not None and payload is not None:
            assert payload.nbytes == self.layout.block_bytes
            self.data[block_id] = payload.reshape(-1).view(np.uint8)
        with self._lock:
            self.epochs[block_id] += 1
            self.committed[block_id] = True
            return int(self.epochs[block_id])

    def write_blocks(
        self, block_ids: list[int], payloads: np.ndarray | None = None
    ) -> list[int]:
        """Batch write + publish: one fancy-indexed copy, one epoch bump.

        ``payloads``: (n, block_bytes)-viewable array, or None when the
        payload was staged elsewhere (meta backing / device-side writes).
        Returns the publish epochs.
        """
        ids = np.asarray(block_ids, np.intp)
        if self.data is not None and payloads is not None:
            assert payloads.nbytes == len(block_ids) * self.layout.block_bytes
            self.data[ids] = payloads.reshape(len(block_ids), -1).view(np.uint8)
        with self._lock:
            self.epochs[ids] += 1
            self.committed[ids] = True
            return self.epochs[ids].tolist()

    def read_block(self, block_id: int) -> tuple[np.ndarray, int]:
        with self._lock:
            e = int(self.epochs[block_id])
        if self.data is None:
            return np.zeros(self.layout.block_bytes, np.uint8), e
        return self.data[block_id].copy(), e

    def read_blocks(
        self, block_ids, out: np.ndarray | None = None
    ) -> tuple[np.ndarray | None, np.ndarray]:
        """Batch read: one batched copy + one epoch snapshot.

        Returns (payloads (n, block_bytes) or None for meta backing,
        epochs-at-read (n,)). The epoch snapshot is taken BEFORE the copy,
        mirroring the per-block read protocol (§5.1): a caller comparing
        the snapshot against its expected epochs detects concurrent
        recycling the same way the scalar path did.

        ``out``: optional (n, block_bytes) uint8 destination. Reading into
        a persistent buffer (the serving steady state: pool -> fixed HBM
        slots) skips the dominant cost of a fresh multi-hundred-MB
        allocation — per-row C memcpy into warm pages.
        """
        ids = np.asarray(block_ids, np.intp)
        with self._lock:
            eps = self.epochs[ids].copy()
        if self.data is None:
            return None, eps
        if out is None:
            return self.data[ids], eps
        assert out.shape == (len(ids), self.layout.block_bytes)
        data = self.data
        for j, b in enumerate(ids):
            out[j] = data[b]
        return out, eps

    def read_fragments(self, block_id: int, frag_ids: list[int]) -> np.ndarray:
        fb = self.layout.fragment_bytes
        block = self.data[block_id]
        return block.reshape(self.layout.n_fragments, fb)[
            np.asarray(frag_ids, np.intp)
        ]

    def validate_epoch(self, block_id: int, epoch: int) -> bool:
        with self._lock:
            return bool(self.committed[block_id]) and int(
                self.epochs[block_id]
            ) == epoch

    def validate_epochs(self, block_ids, epochs) -> np.ndarray:
        """Vectorized committed+epoch check; one lock, one compare."""
        ids = np.asarray(block_ids, np.intp)
        exp = np.asarray(epochs)
        with self._lock:
            return self.committed[ids] & (self.epochs[ids] == exp)

"""Declared locks + the ``BELUGA_SANITIZE=1`` lock-order sanitizer.

Every lock in the concurrency surface of this repo is created through
``make_lock(name, blocking_ok=...)`` instead of a bare
``threading.Lock()`` — enforced by the lock-discipline pass in
``tools/beluga_lint``.  The declaration buys two things:

  * a stable cross-process NAME for each lock ("class role", not object
    identity: every ``CxlRpcClient`` instance's slot lock is the same
    node in the order graph), which is what both the static
    lock-acquisition graph and the runtime recorder key on;
  * a machine-readable ``blocking_ok`` annotation: supervision locks
    whose entire purpose is serializing a blocking restart section
    (probe/stop/join/replay under ``ShardSupervisor._lock``) declare it,
    and the static pass then permits blocking calls under them — a
    blocking call under any *undeclared* lock is a lint failure.

In normal runs ``make_lock`` returns a plain ``threading.Lock`` — zero
overhead beyond one call at construction.  With ``BELUGA_SANITIZE=1`` in
the environment it returns a ``SanitizedLock`` that records every
ACTUAL nested acquisition (lock A held while acquiring lock B → edge
A→B) into a process-global edge set, flagging an inversion (both A→B
and B→A observed) as a violation the test session fails on.  Edges are
keyed by declared name, so orders observed in different processes and
different object instances compose into one graph.

Set ``BELUGA_SANITIZE_LOG=<dir>`` to have every participating process
dump its recorded edges to ``<dir>/lock_order.<pid>.json`` at interpreter
exit; ``python -m tools.beluga_lint src --check-lock-log <dir>`` then
asserts the union of runtime edges is consistent (acyclic) with the
statically derived graph.
"""

from __future__ import annotations

import atexit
import json
import os
import threading

SANITIZE = os.environ.get("BELUGA_SANITIZE", "") not in ("", "0")

# process-global recorder state (guarded by a raw lock, which is itself
# exempt from sanitizing — it can never nest with a sanitized lock)
_registry_lock = threading.Lock()
_edges: set[tuple[str, str]] = set()
_violations: list[dict] = []
_declared: dict[str, bool] = {}  # name -> blocking_ok
_held = threading.local()  # per-thread stack of held lock names


def make_lock(name: str, *, blocking_ok: bool = False):
    """Create a named lock (sanitized when ``BELUGA_SANITIZE=1``).

    ``name`` should be the stable role of the lock, conventionally
    ``"<module>.<Class>.<attr>"``.  ``blocking_ok=True`` declares that
    blocking calls (joins, RPC round-trips, sleeps) under this lock are
    intentional — the static lint pass reads the declaration straight
    out of this call's AST.
    """
    with _registry_lock:
        _declared.setdefault(name, blocking_ok)
    if not SANITIZE:
        return threading.Lock()
    return SanitizedLock(name)


class SanitizedLock:
    """``threading.Lock`` wrapper that records acquisition order."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()

    def _stack(self) -> list[str]:
        st = getattr(_held, "stack", None)
        if st is None:
            st = _held.stack = []
        return st

    def _record(self) -> None:
        st = self._stack()
        if st:
            outer = st[-1]
            if outer != self.name:
                edge = (outer, self.name)
                with _registry_lock:
                    if (self.name, outer) in _edges and edge not in _edges:
                        _violations.append({
                            "edge": list(edge),
                            "conflicts_with": [self.name, outer],
                            "thread": threading.current_thread().name,
                        })
                    _edges.add(edge)
        st.append(self.name)

    # -- threading.Lock surface -----------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            self._record()
        return ok

    def release(self) -> None:
        st = self._stack()
        # released out of acquisition order is legal for Lock: drop the
        # most recent matching frame
        for i in range(len(st) - 1, -1, -1):
            if st[i] == self.name:
                del st[i]
                break
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


# -- introspection (tests, conftest session hook, nightly artifact) ------
def recorded_edges() -> list[tuple[str, str]]:
    with _registry_lock:
        return sorted(_edges)


def violations() -> list[dict]:
    with _registry_lock:
        return list(_violations)


def declared_locks() -> dict[str, bool]:
    with _registry_lock:
        return dict(_declared)


def reset() -> None:
    """Test hook: clear recorded edges and violations (declarations stay)."""
    with _registry_lock:
        _edges.clear()
        _violations.clear()


def dump(path: str) -> None:
    """Write this process's recorded graph as JSON (one file per pid)."""
    with _registry_lock:
        payload = {
            "pid": os.getpid(),
            "edges": sorted(list(e) for e in _edges),
            "violations": list(_violations),
            "declared": dict(_declared),
        }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)


def _autodump() -> None:
    log_dir = os.environ.get("BELUGA_SANITIZE_LOG", "")
    if not log_dir:
        return
    try:
        os.makedirs(log_dir, exist_ok=True)
        dump(os.path.join(log_dir, f"lock_order.{os.getpid()}.json"))
    except OSError:
        pass  # best-effort artifact: a read-only dir must not fail exit


if SANITIZE:
    atexit.register(_autodump)

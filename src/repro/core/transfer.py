"""KVCache transfer engine: gather-write / scatter-read (paper §6.1).

The KVCache in an inference engine is fragmented: a 16-token block of
Qwen3-32B is 128 non-contiguous (layer, K|V) fragments of ~32 KB living in
per-layer GPU tensors, while the pool wants them packed contiguous.

Two executable paths (both move real bytes; latency is fabric-modeled):

  * ``beluga`` — single fused gather/scatter kernel per batch of blocks
    (device-side twin: ``repro.kernels.kv_gather_write`` /
    ``kv_scatter_read``): one launch, unlimited fragments, no bounce buffer.
  * ``rdma``   — MoonCake-style CPU-driven path: GPU→host bounce copy, then
    sglist-limited (30 entries) RDMA requests; optional super-block batching
    (LMCache's 256-token blocks) to amortize the per-request overhead.

Sparse reads (Exp #10): top-k token gather at (layer, head, token)
granularity — thousands of ~(head_dim·dtype)-byte pieces; Beluga issues one
kernel, RDMA needs ceil(pieces/30) requests.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core import fabric
from repro.core.fabric import DEFAULT, FabricConstants
from repro.core.pool import BelugaPool


@dataclass
class TransferStats:
    writes: int = 0
    reads: int = 0
    bytes_written: int = 0
    bytes_read: int = 0
    modeled_write_s: float = 0.0
    modeled_read_s: float = 0.0
    requests_issued: int = 0  # RDMA request count / kernel launches


@dataclass
class TransferEngine:
    pool: BelugaPool
    mode: str = "beluga"  # beluga | rdma
    super_block_tokens: int = 0  # rdma batching (LMCache: 256); 0 = native
    constants: FabricConstants = DEFAULT
    stats: TransferStats = field(default_factory=TransferStats)

    # ------------------------------------------------------------------
    # Layout helpers (batched: one view/reshape for ALL blocks, no
    # per-block Python loop)
    # ------------------------------------------------------------------
    def _pack_batch(self, kv_blocks: np.ndarray) -> np.ndarray:
        """kv_blocks: (n, 2*L, block_tokens, hkv, hd) -> (n, block_bytes)."""
        lay = self.pool.layout
        assert kv_blocks.shape[1] == lay.n_fragments
        n = kv_blocks.shape[0]
        return np.ascontiguousarray(kv_blocks).reshape(n, -1).view(np.uint8)

    def _unpack_batch(self, payloads: np.ndarray, dtype=np.float16) -> np.ndarray:
        """(n, block_bytes) uint8 -> (n, 2*L, block_tokens, hkv, hd)."""
        lay = self.pool.layout
        itemsize = np.dtype(dtype).itemsize
        assert itemsize == lay.dtype_bytes
        return payloads.view(dtype).reshape(
            -1, lay.n_fragments, lay.block_tokens, lay.n_kv_heads, lay.head_dim
        )

    # ------------------------------------------------------------------
    # Gather write: fragmented per-layer KV -> contiguous pool blocks
    # ------------------------------------------------------------------
    def gather_write(self, block_ids: list[int], kv_blocks: np.ndarray) -> list[int]:
        """kv_blocks: (n_blocks, 2*L, block_tokens, hkv, hd). Returns epochs."""
        lay = self.pool.layout
        n = len(block_ids)
        assert kv_blocks is None or kv_blocks.shape[0] == n
        size = n * lay.block_bytes
        nfrag = n * lay.n_fragments

        if self.mode == "beluga":
            # one fused kernel moves every fragment of every block
            self.stats.modeled_write_s += fabric.gpu_transfer_latency(
                size, nfrag, method="fused_kernel", direction="write",
                c=self.constants,
            )
            self.stats.requests_issued += 1
        else:
            nfrag_eff, nreq_groups = self._rdma_batching(n, nfrag)
            self.stats.modeled_write_s += fabric.rdma_transfer_latency(
                size, nfrag_eff, gpu_side=True, c=self.constants
            )
            self.stats.requests_issued += math.ceil(
                nfrag_eff / self.constants.rdma_sgl_max
            )

        if self.pool.data is None:  # meta backing: bump epochs only
            epochs = self.pool.write_blocks(block_ids)
        else:
            # one fancy-indexed store for every fragment of every block
            epochs = self.pool.write_blocks(block_ids, self._pack_batch(kv_blocks))
        self.stats.writes += n
        self.stats.bytes_written += size
        return epochs

    # ------------------------------------------------------------------
    # Scatter read: contiguous pool blocks -> fragmented per-layer KV
    # ------------------------------------------------------------------
    def scatter_read(
        self, block_ids: list[int], epochs: list[int] | None = None,
        dtype=np.float16, out: np.ndarray | None = None,
    ) -> np.ndarray:
        """Returns (n_blocks, 2*L, block_tokens, hkv, hd).

        ``out``: optional preallocated destination of that shape (the
        fused-kernel analogue of reading straight into the engine's KV
        slots instead of a fresh buffer per fetch).
        """
        lay = self.pool.layout
        n = len(block_ids)
        size = n * lay.block_bytes
        nfrag = n * lay.n_fragments

        if self.mode == "beluga":
            self.stats.modeled_read_s += fabric.gpu_transfer_latency(
                size, nfrag, method="fused_kernel", direction="read",
                c=self.constants,
            )
            self.stats.requests_issued += 1
        else:
            nfrag_eff, _ = self._rdma_batching(n, nfrag)
            self.stats.modeled_read_s += fabric.rdma_transfer_latency(
                size, nfrag_eff, gpu_side=True, c=self.constants
            )
            self.stats.requests_issued += math.ceil(
                nfrag_eff / self.constants.rdma_sgl_max
            )

        shape = (n, lay.n_fragments, lay.block_tokens, lay.n_kv_heads, lay.head_dim)
        if self.pool.data is None:  # meta backing: validate epochs only
            if epochs is not None:
                ok = self.pool.validate_epochs(block_ids, epochs)
                if not ok.all():
                    from repro.core.coherence import CoherenceError

                    bad = block_ids[int(np.argmin(ok))]
                    raise CoherenceError(f"block {bad} epoch changed during read")
            self.stats.reads += n
            self.stats.bytes_read += size
            if out is not None:
                out[:] = 0
                return out
            return np.zeros(shape, dtype)
        # one batched gather for all blocks + one batched epoch check
        dst = None
        if out is not None:
            assert out.shape == shape and out.dtype == np.dtype(dtype)
            assert out.flags.c_contiguous
            dst = out.reshape(n, -1).view(np.uint8)
        payloads, eps_now = self.pool.read_blocks(block_ids, out=dst)
        if epochs is not None:
            mism = eps_now != np.asarray(epochs)
            if mism.any():
                from repro.core.coherence import CoherenceError

                bad = block_ids[int(np.argmax(mism))]
                raise CoherenceError(f"block {bad} epoch changed during read")
        result = out if out is not None else self._unpack_batch(payloads, dtype)
        self.stats.reads += n
        self.stats.bytes_read += size
        return result

    # ------------------------------------------------------------------
    # Sparse read: top-k token pieces (Exp #10)
    # ------------------------------------------------------------------
    def sparse_read_latency(self, n_tokens: int, contiguous_frac: float = 0.26) -> float:
        """Latency to load KV for n_tokens sparsely-selected tokens.

        pieces = n_layers * n_heads * 2 per token (paper: 1024 for Qwen-32B);
        contiguous neighbors can merge (paper Table 6 measured ~26% for
        Qwen3-32B), which only helps RDMA (fewer sgl entries).
        """
        lay = self.pool.layout
        piece = lay.head_dim * lay.dtype_bytes
        n_pieces = n_tokens * lay.n_layers_kv * lay.n_kv_heads * 2
        size = n_pieces * piece
        if self.mode == "beluga":
            return fabric.gpu_transfer_latency(
                size, n_pieces, method="fused_kernel", c=self.constants
            )
        merged = max(1, int(n_pieces * (1 - contiguous_frac)))
        return fabric.rdma_transfer_latency(size, merged, gpu_side=True, c=self.constants)

    # ------------------------------------------------------------------
    def _rdma_batching(self, n_blocks: int, nfrag: int) -> tuple[int, int]:
        """Super-block batching reduces *request* count but forces larger
        transfer granularity (LMCache's 256-token indexing)."""
        if self.super_block_tokens and self.super_block_tokens > self.pool.layout.block_tokens:
            group = self.super_block_tokens // self.pool.layout.block_tokens
            groups = math.ceil(n_blocks / group)
            return groups * self.pool.layout.n_fragments, groups
        return nfrag, n_blocks

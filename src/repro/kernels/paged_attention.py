"""Paged decode attention over the Beluga pool — Pallas TPU kernel.

This is the device-side embodiment of the paper's load/store thesis: decode
attention reads KV **directly out of the pool at block granularity through
the block table** — no staging copy into a contiguous cache, no per-fragment
transfer requests.  The block table is a scalar-prefetch operand, so the
pool block for each grid step is selected with a data-dependent BlockSpec
index_map (the TPU analogue of pointer-chasing through the CXL switch).

Layout: kv_pool (n_blocks, 2, block_tokens, hkv, d) — k/v interleaved per
block, exactly the pool payload written by ``kv_gather_write``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # scalar prefetch
    block_table_ref,  # (b, max_blocks) int32
    context_lens_ref,  # (b,) int32
    # blocks
    q_ref,  # (1, hq, d)
    kv_ref,  # (1, 2, bt, hkv, d): the pool block for this grid step
    o_ref,  # (1, hq, d)
    m_scr,  # (hq, 1) f32
    l_scr,  # (hq, 1) f32
    acc_scr,  # (hq, d) f32
    *,
    scale: float,
    block_tokens: int,
    max_blocks: int,
    n_groups: int,  # hq // hkv
):
    bi = pl.program_id(0)
    blk = pl.program_id(1)

    @pl.when(blk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ctx = context_lens_ref[bi]
    n_active = (ctx + block_tokens - 1) // block_tokens

    @pl.when(blk < n_active)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (hq, d)
        k = kv_ref[0, 0].astype(jnp.float32)  # (bt, hkv, d)
        v = kv_ref[0, 1].astype(jnp.float32)
        hq, d = q.shape
        bt, hkv, _ = k.shape
        # repeat kv heads to q heads (contiguous GQA grouping)
        k = jnp.repeat(k, n_groups, axis=1)  # (bt, hq, d)
        v = jnp.repeat(v, n_groups, axis=1)
        s = jnp.einsum("hd,thd->ht", q, k)  # (hq, bt)
        pos = blk * block_tokens + jax.lax.broadcasted_iota(
            jnp.int32, (hq, bt), 1
        )
        s = jnp.where(pos < ctx, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev[:, 0], s.max(axis=1))[:, None]
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + jnp.einsum("ht,thd->hd", p, v)
        m_scr[...] = m_new

    @pl.when(blk == max_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,  # (b, hq, d)
    kv_pool: jax.Array,  # (n_blocks, 2, bt, hkv, d)
    block_table: jax.Array,  # (b, max_blocks) int32 (-1 pad -> clamped)
    context_lens: jax.Array,  # (b,) int32
    *,
    interpret: bool = False,
) -> jax.Array:
    b, hq, d = q.shape
    n_blocks, _, bt, hkv, _ = kv_pool.shape
    max_blocks = block_table.shape[1]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)

    tbl = jnp.maximum(block_table, 0).astype(jnp.int32)

    kernel = functools.partial(
        _kernel,
        scale=scale,
        block_tokens=bt,
        max_blocks=max_blocks,
        n_groups=g,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, max_blocks),
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda bi, blk, tbl_ref, ctx_ref: (bi, 0, 0)),
            pl.BlockSpec(
                (1, 2, bt, hkv, d),
                # data-dependent pool block selection via the block table
                lambda bi, blk, tbl_ref, ctx_ref: (tbl_ref[bi, blk], 0, 0, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, hq, d), lambda bi, blk, tbl_ref, ctx_ref: (bi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        interpret=interpret,
    )(tbl, context_lens.astype(jnp.int32), q, kv_pool)

"""Pure-jnp oracles for every Pallas kernel (the numerics ground truth).

Each ``*_ref`` mirrors its kernel's exact signature and is used by
``tests/test_kernels.py`` for allclose sweeps over shapes/dtypes, and by
``ops.py`` as the fallback path on backends without Pallas support.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# flash_attention: causal GQA attention (prefill / train)
# ---------------------------------------------------------------------------


def flash_attention_ref(
    q: jax.Array,  # (b, sq, hq, d)
    k: jax.Array,  # (b, skv, hkv, d)
    v: jax.Array,
    causal: bool = True,
    q_offset: int = 0,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)
        kpos = jnp.arange(skv)
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged_attention: decode attention through a block table (Beluga pool read)
# ---------------------------------------------------------------------------


def paged_attention_ref(
    q: jax.Array,  # (b, hq, d)
    kv_pool: jax.Array,  # (n_blocks, 2, bt, hkv, d)  [k=0, v=1]
    block_table: jax.Array,  # (b, max_blocks) int32, -1 padded
    context_lens: jax.Array,  # (b,) int32
) -> jax.Array:
    b, hq, d = q.shape
    n_blocks, _, bt, hkv, _ = kv_pool.shape
    max_blocks = block_table.shape[1]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)

    tbl = jnp.maximum(block_table, 0)  # (b, mb)
    k = kv_pool[tbl, 0]  # (b, mb, bt, hkv, d)
    v = kv_pool[tbl, 1]
    k = k.reshape(b, max_blocks * bt, hkv, d)
    v = v.reshape(b, max_blocks * bt, hkv, d)
    pos = jnp.arange(max_blocks * bt)
    valid = pos[None, :] < context_lens[:, None]

    qg = (q * scale).reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# kv_gather_write: pack fragmented per-layer KV slots into pool blocks
# ---------------------------------------------------------------------------


def kv_gather_write_ref(
    k_cache: jax.Array,  # (L, T, hkv, hd) dense per-layer cache
    v_cache: jax.Array,  # (L, T, hkv, hd)
    slot_ids: jax.Array,  # (n_blocks,) int32: block-aligned slot index
    block_tokens: int,
) -> jax.Array:
    """Returns pool payload (n_blocks, 2L, block_tokens, hkv, hd)."""
    L = k_cache.shape[0]

    def one(slot):
        start = slot * block_tokens
        kf = jax.lax.dynamic_slice_in_dim(k_cache, start, block_tokens, 1)
        vf = jax.lax.dynamic_slice_in_dim(v_cache, start, block_tokens, 1)
        # interleave (k_l, v_l) fragments: [k0, v0, k1, v1, ...]
        kv = jnp.stack([kf, vf], axis=1)  # (L, 2, bt, hkv, hd)
        return kv.reshape(2 * L, block_tokens, *kf.shape[2:])

    return jax.vmap(one)(slot_ids)


def kv_scatter_read_ref(
    pool_blocks: jax.Array,  # (n_blocks, 2L, bt, hkv, hd)
    slot_ids: jax.Array,  # (n_blocks,) destination slots
    k_cache: jax.Array,  # (L, T, hkv, hd) to scatter into
    v_cache: jax.Array,
    block_tokens: int,
) -> tuple[jax.Array, jax.Array]:
    n_blocks, twoL = pool_blocks.shape[0], pool_blocks.shape[1]
    L = twoL // 2
    kv = pool_blocks.reshape(n_blocks, L, 2, block_tokens, *pool_blocks.shape[3:])

    def body(carry, i):
        kc, vc = carry
        start = slot_ids[i] * block_tokens
        kc = jax.lax.dynamic_update_slice_in_dim(kc, kv[i, :, 0].astype(kc.dtype), start, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, kv[i, :, 1].astype(vc.dtype), start, 1)
        return (kc, vc), None

    (k_cache, v_cache), _ = jax.lax.scan(
        body, (k_cache, v_cache), jnp.arange(n_blocks)
    )
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# sparse_kv_gather: top-k token gather (Exp #10 sparse reads)
# ---------------------------------------------------------------------------


def sparse_kv_gather_ref(
    kv: jax.Array,  # (N, hkv, hd) token-major pool view
    token_ids: jax.Array,  # (n_sel,) int32
) -> jax.Array:
    return jnp.take(kv, token_ids, axis=0)


# ---------------------------------------------------------------------------
# ssd_chunk: Mamba-2 intra-chunk SSD (one chunk, quadratic within chunk)
# ---------------------------------------------------------------------------


def ssd_chunk_ref(
    x: jax.Array,  # (L, nh, hp)  dt-scaled inputs, one chunk
    a_log: jax.Array,  # (L, nh) per-step log decay
    b_mat: jax.Array,  # (L, nh, n)
    c_mat: jax.Array,  # (L, nh, n)
) -> tuple[jax.Array, jax.Array]:
    """Returns (y_intra (L, nh, hp), chunk_state (nh, n, hp))."""
    Lc = x.shape[0]
    cum = jnp.cumsum(a_log.astype(jnp.float32), axis=0)  # (L, nh)
    seg = cum[:, None, :] - cum[None, :, :]  # (L, L, nh)
    li = jnp.arange(Lc)
    causal = li[:, None] >= li[None, :]
    decay = jnp.where(causal[..., None], jnp.exp(seg), 0.0)
    scores = jnp.einsum(
        "lhn,mhn->lmh", c_mat.astype(jnp.float32), b_mat.astype(jnp.float32)
    )
    y = jnp.einsum("lmh,lmh,mhp->lhp", scores, decay, x.astype(jnp.float32))
    decay_to_end = jnp.exp(cum[-1:, :] - cum)  # (L, nh)
    state = jnp.einsum(
        "lhn,lh,lhp->hnp", b_mat.astype(jnp.float32), decay_to_end,
        x.astype(jnp.float32),
    )
    return y, state

"""Causal GQA flash attention — Pallas TPU kernel.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
  * tiling is chosen for VMEM + the 128x128 MXU: block_q x d and
    block_kv x d tiles with d padded to a 128 multiple;
  * the grid is (batch*kv_heads, q_group, q_blocks, kv_blocks) with the kv
    dim innermost; running (m, l, acc) live in VMEM scratch across kv steps;
  * upper-triangle blocks are skipped STRUCTURALLY with ``pl.when`` — unlike
    the masked jnp path, no MXU work is issued above the diagonal (this is
    the kernel-level fix for the ~2x attention-FLOP inflation the roofline
    analyzer shows for the portable path).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, 1, block_q, d)
    k_ref,  # (1, block_kv, d)
    v_ref,  # (1, block_kv, d)
    o_ref,  # (1, 1, block_q, d)
    m_scr,  # (block_q, 1) f32
    l_scr,  # (block_q, 1) f32
    acc_scr,  # (block_q, d) f32
    *,
    causal: bool,
    scale: float,
    block_q: int,
    block_kv: int,
    n_kv_blocks: int,
    seq_kv: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv

    # structural skip: kv blocks entirely above the diagonal issue no MXU work
    @pl.when(jnp.logical_or(not causal, k_start <= q_start + block_q - 1))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bkv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bkv)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = kpos < seq_kv
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev[:, 0], s.max(axis=1))[:, None]
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = l_scr[...] * alpha + p.sum(axis=1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (b, sq, hq, d)
    k: jax.Array,  # (b, skv, hkv, d)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 256,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    sq_pad = -(-sq // block_q) * block_q
    skv_pad = -(-skv // block_kv) * block_kv
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
    nq = sq_pad // block_q
    nkv = skv_pad // block_kv

    # (b, s, h, d) -> (b*hkv, g, s, d): group q heads by their kv head
    qg = (
        q.reshape(b, sq_pad, hkv, g, d)
        .transpose(0, 2, 3, 1, 4)
        .reshape(b * hkv, g, sq_pad, d)
    )
    kg = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv_pad, d)
    vg = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv_pad, d)

    grid = (b * hkv, g, nq, nkv)
    kernel = functools.partial(
        _kernel,
        causal=causal,
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        n_kv_blocks=nkv,
        seq_kv=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bh, gi, qi, ki: (bh, gi, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, gi, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_kv, d), lambda bh, gi, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bh, gi, qi, ki: (bh, gi, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)

    out = (
        out.reshape(b, hkv, g, sq_pad, d)
        .transpose(0, 3, 1, 2, 4)
        .reshape(b, sq_pad, hq, d)
    )
    return out[:, :sq]

"""KV gather-write / scatter-read — Pallas TPU kernels (paper §6.1).

The paper's custom CUDA copy kernel collapses a block's 2L non-contiguous
fragments into ONE kernel launch; these are the TPU twins:

  * ``kv_gather_write``  — pack per-layer cache slots -> contiguous pool
    blocks (pool payload layout: (n_blocks, 2L, bt, hkv, hd), fragments
    interleaved [k0, v0, k1, v1, ...]);
  * ``kv_scatter_read``  — pool blocks -> per-layer cache slots;
  * ``sparse_kv_gather`` — top-k token rows out of a token-major pool view
    (Exp #10: thousands of tiny pieces, one launch).

Dynamic slot/block indices arrive via scalar prefetch; each grid step's
BlockSpec index_map dereferences them — data movement at memory semantics,
no per-fragment request list (the RDMA sglist pathology this replaces).

Grid shape: ONE step per pool block. Each step moves a fused
(L, 2, bt, hkv, hd) fragment-pair block over the collapsed layer axis —
a single fat DMA per pool block instead of an (n_blocks, L) grid of tiny
(1, 1, bt, hkv, hd) copies, so grid/launch overhead is O(blocks), not
O(blocks * layers).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# gather write: cache slots -> pool blocks
# ---------------------------------------------------------------------------


def _gather_write_body(slot_ref, k_ref, v_ref, o_ref):
    # k_ref/v_ref: (L, 1, bt, hkv, hd) — every layer of one cache slot;
    # o_ref: (1, L, 2, bt, hkv, hd) — one fused pool block, (k, v) paired
    o_ref[0, :, 0] = k_ref[:, 0]
    o_ref[0, :, 1] = v_ref[:, 0]


def kv_gather_write(
    k_cache: jax.Array,  # (L, T, hkv, hd), T = n_slots * bt
    v_cache: jax.Array,
    slot_ids: jax.Array,  # (n_blocks,) int32 block-aligned slots
    block_tokens: int,
    *,
    interpret: bool = False,
) -> jax.Array:
    L, T, hkv, hd = k_cache.shape
    n_blocks = slot_ids.shape[0]
    bt = block_tokens
    n_slots = T // bt
    kc = k_cache.reshape(L, n_slots, bt, hkv, hd)
    vc = v_cache.reshape(L, n_slots, bt, hkv, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(
                (L, 1, bt, hkv, hd),
                lambda bi, slot_ref: (0, slot_ref[bi], 0, 0, 0),
            ),
            pl.BlockSpec(
                (L, 1, bt, hkv, hd),
                lambda bi, slot_ref: (0, slot_ref[bi], 0, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, L, 2, bt, hkv, hd), lambda bi, slot_ref: (bi, 0, 0, 0, 0, 0)
        ),
    )
    out = pl.pallas_call(
        _gather_write_body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks, L, 2, bt, hkv, hd), k_cache.dtype),
        interpret=interpret,
    )(slot_ids.astype(jnp.int32), kc, vc)
    # (n_blocks, L, 2, ...) -> (n_blocks, 2L, ...) fragment-interleaved
    return out.reshape(n_blocks, 2 * L, bt, hkv, hd)


# ---------------------------------------------------------------------------
# scatter read: pool blocks -> cache slots
# ---------------------------------------------------------------------------


def _scatter_read_body(slot_ref, pool_ref, k_ref, v_ref):
    # pool_ref: (1, L, 2, bt, hkv, hd); k_ref/v_ref: (L, 1, bt, hkv, hd)
    k_ref[:, 0] = pool_ref[0, :, 0]
    v_ref[:, 0] = pool_ref[0, :, 1]


def kv_scatter_read(
    pool_blocks: jax.Array,  # (n_blocks, 2L, bt, hkv, hd)
    slot_ids: jax.Array,  # (n_blocks,) destination block-aligned slots
    n_slots: int,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (k_cache, v_cache) of shape (L, n_slots*bt, hkv, hd).

    Unwritten slots are zero (the engine only reads slots it mapped).
    """
    n_blocks, twoL, bt, hkv, hd = pool_blocks.shape
    L = twoL // 2
    pool = pool_blocks.reshape(n_blocks, L, 2, bt, hkv, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec(
                (1, L, 2, bt, hkv, hd),
                lambda bi, slot_ref: (bi, 0, 0, 0, 0, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec(
                (L, 1, bt, hkv, hd),
                lambda bi, slot_ref: (0, slot_ref[bi], 0, 0, 0),
            ),
            pl.BlockSpec(
                (L, 1, bt, hkv, hd),
                lambda bi, slot_ref: (0, slot_ref[bi], 0, 0, 0),
            ),
        ],
    )
    k, v = pl.pallas_call(
        _scatter_read_body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((L, n_slots, bt, hkv, hd), pool_blocks.dtype),
            jax.ShapeDtypeStruct((L, n_slots, bt, hkv, hd), pool_blocks.dtype),
        ],
        interpret=interpret,
    )(slot_ids.astype(jnp.int32), pool)
    return (
        k.reshape(L, n_slots * bt, hkv, hd),
        v.reshape(L, n_slots * bt, hkv, hd),
    )


# ---------------------------------------------------------------------------
# sparse gather: top-k token rows (one launch for thousands of pieces)
# ---------------------------------------------------------------------------


def _sparse_body(idx_ref, kv_ref, o_ref):
    o_ref[0] = kv_ref[0]


def sparse_kv_gather(
    kv: jax.Array,  # (N, hkv, hd) token-major
    token_ids: jax.Array,  # (n_sel,) int32
    *,
    interpret: bool = False,
) -> jax.Array:
    n, hkv, hd = kv.shape
    n_sel = token_ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_sel,),
        in_specs=[
            pl.BlockSpec((1, hkv, hd), lambda i, idx_ref: (idx_ref[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hkv, hd), lambda i, idx_ref: (i, 0, 0)),
    )
    return pl.pallas_call(
        _sparse_body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_sel, hkv, hd), kv.dtype),
        interpret=interpret,
    )(token_ids.astype(jnp.int32), kv)

"""Mamba-2 SSD intra-chunk kernel — Pallas TPU.

One grid step processes one (batch, chunk) tile entirely in VMEM:

    y[l]   = sum_{m<=l} C_l.B_m * exp(cum_l - cum_m) * x_m      (intra)
    state  = sum_l exp(cum_last - cum_l) * B_l (x) x_l           (chunk out)

Tiling: the (Lc, Lc) decay/score matrices live in VMEM per (nh-tile); the
MXU sees two dots per head tile (C.B^T and the masked-decay matmul against
x). The inter-chunk recurrence stays in jnp (`jax.lax.associative_scan`) —
it is O(nc) tiny state math and static (counted correctly by the roofline
analyzer), exactly the split recommended by the SSD paper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(
    x_ref,  # (1, Lc, nh_t, hp)   dt-scaled inputs
    a_ref,  # (1, Lc, nh_t)       per-step log decay
    b_ref,  # (1, Lc, nh_t, n)
    c_ref,  # (1, Lc, nh_t, n)
    y_ref,  # (1, Lc, nh_t, hp)
    s_ref,  # (1, nh_t, n, hp)    chunk-final state
    *,
    chunk: int,
):
    x = x_ref[0].astype(jnp.float32)  # (Lc, nh, hp)
    a = a_ref[0].astype(jnp.float32)  # (Lc, nh)
    b = b_ref[0].astype(jnp.float32)  # (Lc, nh, n)
    c = c_ref[0].astype(jnp.float32)

    cum = jnp.cumsum(a, axis=0)  # (Lc, nh)
    seg = cum[:, None, :] - cum[None, :, :]  # (Lc, Lc, nh)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    mi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = li >= mi
    decay = jnp.where(causal[..., None], jnp.exp(seg), 0.0)  # (Lc, Lc, nh)

    scores = jnp.einsum("lhn,mhn->lmh", c, b)  # (Lc, Lc, nh)
    y = jnp.einsum("lmh,mhp->lhp", scores * decay, x)
    y_ref[0] = y.astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1:, :] - cum)  # (Lc, nh)
    state = jnp.einsum("lhn,lh,lhp->hnp", b, decay_to_end, x)
    s_ref[0] = state.astype(s_ref.dtype)


def ssd_chunk(
    x: jax.Array,  # (nb, Lc, nh, hp)  (nb = batch*n_chunks tiles)
    a_log: jax.Array,  # (nb, Lc, nh)
    b_mat: jax.Array,  # (nb, Lc, nh, n)
    c_mat: jax.Array,  # (nb, Lc, nh, n)
    *,
    nh_tile: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y_intra (nb, Lc, nh, hp), states (nb, nh, n, hp))."""
    nb, lc, nh, hp = x.shape
    n = b_mat.shape[-1]
    nh_tile = min(nh_tile, nh)
    assert nh % nh_tile == 0, (nh, nh_tile)
    grid = (nb, nh // nh_tile)

    kernel = functools.partial(_kernel, chunk=lc)
    y, s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, lc, nh_tile, hp), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, lc, nh_tile), lambda i, h: (i, 0, h)),
            pl.BlockSpec((1, lc, nh_tile, n), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, lc, nh_tile, n), lambda i, h: (i, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, lc, nh_tile, hp), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, nh_tile, n, hp), lambda i, h: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, lc, nh, hp), jnp.float32),
            jax.ShapeDtypeStruct((nb, nh, n, hp), jnp.float32),
        ],
        interpret=interpret,
    )(x, a_log, b_mat, c_mat)
    return y, s

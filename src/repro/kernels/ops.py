"""Jit'd public wrappers for the Pallas kernels.

``kernel_mode``:
  * "pallas"  — force the Pallas path (interpret=True off-TPU, so the kernel
                body executes in Python on CPU: correctness, not speed);
  * "jnp"     — force the pure-jnp oracle (ref.py);
  * "auto"    — Pallas on TPU, oracle elsewhere (the dry-run/CPU default).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import kv_transfer as _kv
from repro.kernels import paged_attention as _pa
from repro.kernels import ref as _ref


def _use_pallas(mode: str) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    on_tpu = jax.default_backend() == "tpu"
    if mode == "pallas":
        return True, not on_tpu
    if mode == "jnp":
        return False, False
    return on_tpu, False


@functools.partial(jax.jit, static_argnames=("causal", "mode", "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal=True, mode="auto", block_q=256, block_kv=512):
    use, interp = _use_pallas(mode)
    if use:
        return _fa.flash_attention(
            q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
            interpret=interp,
        )
    return _ref.flash_attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("mode",))
def paged_attention(q, kv_pool, block_table, context_lens, *, mode="auto"):
    use, interp = _use_pallas(mode)
    if use:
        return _pa.paged_attention(
            q, kv_pool, block_table, context_lens, interpret=interp
        )
    return _ref.paged_attention_ref(q, kv_pool, block_table, context_lens)


@functools.partial(jax.jit, static_argnames=("block_tokens", "mode"))
def kv_gather_write(k_cache, v_cache, slot_ids, block_tokens, *, mode="auto"):
    use, interp = _use_pallas(mode)
    if use:
        return _kv.kv_gather_write(
            k_cache, v_cache, slot_ids, block_tokens, interpret=interp
        )
    return _ref.kv_gather_write_ref(k_cache, v_cache, slot_ids, block_tokens)


@functools.partial(jax.jit, static_argnames=("n_slots", "mode"))
def kv_scatter_read(pool_blocks, slot_ids, n_slots, *, mode="auto"):
    use, interp = _use_pallas(mode)
    if use:
        return _kv.kv_scatter_read(pool_blocks, slot_ids, n_slots, interpret=interp)
    bt = pool_blocks.shape[2]
    L = pool_blocks.shape[1] // 2
    hkv, hd = pool_blocks.shape[3], pool_blocks.shape[4]
    k0 = jnp.zeros((L, n_slots * bt, hkv, hd), pool_blocks.dtype)
    v0 = jnp.zeros_like(k0)
    return _ref.kv_scatter_read_ref(pool_blocks, slot_ids, k0, v0, bt)


@functools.partial(jax.jit, static_argnames=("mode",))
def sparse_kv_gather(kv, token_ids, *, mode="auto"):
    use, interp = _use_pallas(mode)
    if use:
        return _kv.sparse_kv_gather(kv, token_ids, interpret=interp)
    return _ref.sparse_kv_gather_ref(kv, token_ids)


@functools.partial(jax.jit, static_argnames=("nh_tile", "mode"))
def ssd_chunk(x, a_log, b_mat, c_mat, *, nh_tile=8, mode="auto"):
    """Intra-chunk SSD + chunk states; (nb, Lc, nh, hp) tiles."""
    use, interp = _use_pallas(mode)
    if use:
        from repro.kernels import ssd_chunk as _ssd

        return _ssd.ssd_chunk(
            x, a_log, b_mat, c_mat, nh_tile=nh_tile, interpret=interp
        )
    ys, ss = jax.vmap(_ref.ssd_chunk_ref)(x, a_log, b_mat, c_mat)
    return ys, ss

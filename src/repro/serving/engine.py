"""Engine instance: continuous batching over a two-tier Beluga KVCache.

Two runners share the same control plane (allocator, index, transfers,
scheduling):

  * ``SimRunner``  — virtual-clock latency model calibrated to the paper's
    testbed (H20-class instance running Qwen3-32B-scale models): used by the
    cluster benchmarks (Exp #5–#8) so paper-scale workloads run in seconds;
  * ``RealRunner`` — a reduced-config jax model actually generating tokens
    on CPU: used by the e2e example + integration tests.

The engine implements vLLM-V1-style continuous batching: prefills are
admitted between decode steps (prefill-priority), decode runs as one
batched step per iteration across all running sequences.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.kvcache.manager import KVCacheManager
from repro.serving.request import Request


@dataclass
class SimRunnerConfig:
    """Latency model for one instance (calibrated: Qwen-32B on 1xH20).

    prefill ~12.8k tok/s and decode step ~55 ms at batch 16 land the
    cache-populate TTFT/TPOT in the paper's Table 5 range under the
    closed-loop 256-client workload.
    """

    prefill_tok_per_s: float = 12800.0
    prefill_floor_s: float = 0.035
    decode_base_s: float = 0.030
    decode_per_seq_s: float = 0.0016
    max_batch: int = 16
    # (RDMA software staging cost lives in FabricConstants.
    #  rdma_sw_per_superblock, calibrated to Fig. 13c.)


class SimRunner:
    def __init__(self, cfg: SimRunnerConfig):
        self.cfg = cfg

    def prefill_time(self, n_new_tokens: int, n_ctx: int) -> float:
        return max(
            self.cfg.prefill_floor_s, n_new_tokens / self.cfg.prefill_tok_per_s
        )

    def decode_step_time(self, batch: int) -> float:
        return self.cfg.decode_base_s + self.cfg.decode_per_seq_s * batch


@dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    busy_s: float = 0.0
    fetch_s: float = 0.0
    writeback_s: float = 0.0


class EngineInstance:
    """One LLM instance (one server/GPU group) with a virtual clock."""

    def __init__(
        self,
        engine_id: int,
        manager: KVCacheManager,
        runner: SimRunner,
        max_batch: int | None = None,
    ):
        self.engine_id = engine_id
        self.manager = manager
        self.runner = runner
        self.max_batch = max_batch or runner.cfg.max_batch
        self.clock = 0.0
        self.waiting: list[Request] = []
        self.running: list[Request] = []
        self.stats = EngineStats()

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: float) -> None:
        self.clock = max(self.clock, now)
        req.engine_id = self.engine_id
        self.waiting.append(req)

    def load(self) -> float:
        """Scheduler load signal: backlog + busy horizon."""
        return len(self.waiting) + len(self.running) * 0.5

    def has_prefix_locally(self, req: Request) -> bool:
        keys = self.manager.index.keys_for(req.tokens)
        if not keys:
            return False
        return self.manager.hbm._by_key.get(keys[0]) is not None

    # ------------------------------------------------------------------
    def required_slots(self, req: Request) -> int:
        bt = self.manager.hbm.block_tokens
        return -(-(len(req.tokens) + req.n_output) // bt)

    def _admit_one(self) -> None:
        req = self.waiting.pop(0)
        t0 = max(self.clock, req.arrival)
        req.t_admitted = t0
        plan = self.manager.plan_fetch(req.tokens)
        req.hit_tokens = plan.n_hit_tokens
        fetch_t = 0.0
        if plan.hit_blocks:
            fetch_t = plan.fetch_latency  # includes RDMA sw staging (manager)
            try:
                self.manager.fetch_into_hbm(req.req_id, plan)
            except Exception:
                fetch_t = 0.0
                plan.n_miss_tokens = len(req.tokens)
        else:
            self.manager.hbm.register_sequence(req.req_id, [])
        # reserve the remaining slots (miss prefix + decode growth)
        table = self.manager.hbm.seq_tables[req.req_id]
        need = self.required_slots(req) - len(table)
        if need > 0:
            table.extend(self.manager.hbm.allocate(need))
        prefill_t = (
            self.runner.prefill_time(plan.n_miss_tokens, len(req.tokens))
            if plan.n_miss_tokens
            else 0.0
        )
        # writeback of fresh blocks (overlapped on the beluga path: the fused
        # kernel runs in-stream; RDMA pays it synchronously on the CPU path)
        wb_t = 0.0
        n_new = self.manager.writeback(req.req_id, req.tokens)
        if n_new:
            t_before = self.manager.transfer.stats.modeled_write_s
            wb = self.manager.transfer.stats.modeled_write_s - t_before
            lay = self.manager.pool.layout
            if self.manager.transfer.mode == "rdma":
                from repro.core import fabric

                wb_t = fabric.rdma_transfer_latency(
                    n_new * lay.block_bytes,
                    n_new * lay.n_fragments,
                    gpu_side=True,
                    c=self.manager.transfer.constants,
                )
            else:
                from repro.core import fabric

                wb_t = 0.3 * fabric.gpu_transfer_latency(
                    n_new * lay.block_bytes,
                    n_new * lay.n_fragments,
                    method="fused_kernel",
                    c=self.manager.transfer.constants,
                )  # 70% overlapped with compute
        self.clock = t0 + fetch_t + prefill_t + wb_t
        self.stats.fetch_s += fetch_t
        self.stats.writeback_s += wb_t
        self.stats.busy_s += fetch_t + prefill_t + wb_t
        self.stats.prefills += 1
        req.t_first_token = self.clock
        req.tokens_out = 1
        req.state = "running"
        if req.tokens_out >= req.n_output:
            self._finish(req)
        else:
            self.running.append(req)

    def _decode_step(self) -> None:
        dt = self.runner.decode_step_time(len(self.running))
        self.clock += dt
        self.stats.busy_s += dt
        self.stats.decode_steps += 1
        done = []
        for req in self.running:
            req.tokens_out += 1
            if req.tokens_out >= req.n_output:
                done.append(req)
        for req in done:
            self.running.remove(req)
            self._finish(req)

    def _finish(self, req: Request) -> None:
        req.t_done = self.clock
        req.state = "done"
        self.manager.finish(req.req_id)

    # ------------------------------------------------------------------
    def advance(self, until: float) -> None:
        """Run the engine's virtual clock forward to `until`."""
        while True:
            ready = [r for r in self.waiting if r.arrival <= self.clock]
            admissible = (
                ready
                and len(self.running) < self.max_batch
                # KV-capacity gate (vLLM watermark): don't admit a request
                # whose context + decode budget can't fit in HBM slots
                and self.manager.hbm.free_slots() >= self.required_slots(ready[0])
            )
            if admissible:
                # prefill-priority admission (vLLM default)
                self.waiting.remove(ready[0])
                self.waiting.insert(0, ready[0])
                if self.clock >= until:
                    break
                self._admit_one()
            elif self.running:
                if self.clock >= until:
                    break
                self._decode_step()
            else:
                nxt = min((r.arrival for r in self.waiting), default=None)
                if nxt is None or nxt >= until:
                    break  # idle: leave the clock at the last busy instant
                self.clock = max(self.clock, nxt)

    def drain(self) -> float:
        """Run until all submitted work completes; returns final clock."""
        while self.waiting or self.running:
            self.advance(self.clock + 3600.0)
        return self.clock

"""Engine instance: continuous batching over a two-tier Beluga KVCache.

Two runners share the same control plane (allocator, index, transfers,
scheduling):

  * ``SimRunner``  — virtual-clock latency model calibrated to the paper's
    testbed (H20-class instance running Qwen3-32B-scale models): used by the
    cluster benchmarks (Exp #5–#8) so paper-scale workloads run in seconds;
  * ``RealRunner`` — a reduced-config jax model actually generating tokens
    on CPU: used by the e2e example + integration tests.

The engine implements vLLM-V1-style continuous batching: prefills are
admitted between decode steps (prefill-priority), decode runs as one
batched step per iteration across all running sequences.

``advance()`` is event-driven: the waiting queue is an arrival-ordered
heap, so each loop iteration peeks the next admissible request in O(log n)
instead of rescanning the whole backlog — a 256-client closed loop is
linear in events, not quadratic in queue length.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass

from repro.core import diag
from repro.kvcache.manager import KVCacheManager
from repro.serving.request import Request


@dataclass
class SimRunnerConfig:
    """Latency model for one instance (calibrated: Qwen-32B on 1xH20).

    prefill ~12.8k tok/s and decode step ~55 ms at batch 16 land the
    cache-populate TTFT/TPOT in the paper's Table 5 range under the
    closed-loop 256-client workload.
    """

    prefill_tok_per_s: float = 12800.0
    prefill_floor_s: float = 0.035
    decode_base_s: float = 0.030
    decode_per_seq_s: float = 0.0016
    max_batch: int = 16
    # (RDMA software staging cost lives in FabricConstants.
    #  rdma_sw_per_superblock, calibrated to Fig. 13c.)


class SimRunner:
    def __init__(self, cfg: SimRunnerConfig):
        self.cfg = cfg

    def prefill_time(self, n_new_tokens: int, n_ctx: int) -> float:
        return max(
            self.cfg.prefill_floor_s, n_new_tokens / self.cfg.prefill_tok_per_s
        )

    def decode_step_time(self, batch: int) -> float:
        return self.cfg.decode_base_s + self.cfg.decode_per_seq_s * batch


@dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    busy_s: float = 0.0
    fetch_s: float = 0.0
    writeback_s: float = 0.0


class EngineInstance:
    """One LLM instance (one server/GPU group) with a virtual clock."""

    def __init__(
        self,
        engine_id: int,
        manager: KVCacheManager,
        runner: SimRunner,
        max_batch: int | None = None,
        migrator=None,
    ):
        self.engine_id = engine_id
        self.manager = manager
        self.runner = runner
        self.max_batch = max_batch or runner.cfg.max_batch
        # optional shared tiering.MigrationEngine: driven between decode
        # steps so background migration rides the serving virtual clock
        self.migrator = migrator
        self.clock = 0.0
        # arrival-ordered heap of (arrival, submit_seq, req). Ties resolve
        # in submission order, so for monotone arrival streams (all the
        # closed-loop benchmarks) admission order is identical to the seed
        # FIFO. Deliberate deviation: requests REsubmitted with old arrival
        # times (remove_engine orphans) are admitted by arrival, ahead of
        # newer requests — the seed appended them to the back.
        self._waiting: list[tuple[float, int, Request]] = []
        self._seq = itertools.count()
        self.running: list[Request] = []
        self.stats = EngineStats()

    # ------------------------------------------------------------------
    @property
    def waiting(self) -> list[Request]:
        """Queued requests in admission order — an O(n log n) SORTED view
        for tests and the orphan re-dispatch path. Hot callers that only
        need a count or the next arrival must use ``n_queued`` /
        ``next_arrival`` / ``has_backlog`` instead."""
        return [r for _, _, r in sorted(self._waiting, key=lambda t: t[:2])]

    @property
    def n_queued(self) -> int:
        """Backlog size, O(1) (no sort — see ``waiting``)."""
        return len(self._waiting)

    def next_arrival(self) -> float | None:
        """Arrival time of the next admissible request (heap peek), O(1)."""
        return self._waiting[0][0] if self._waiting else None

    def has_backlog(self) -> bool:
        """True while any submitted work is unfinished, O(1)."""
        return bool(self._waiting or self.running)

    def submit(self, req: Request, now: float) -> None:
        """Enqueue a request. ``now`` is accepted for call-site
        compatibility but ignored: submission time no longer moves the
        engine clock. The old ``clock = max(clock, now)`` barrier meant
        pre-dispatching an open-loop stream fast-forwarded the clock to
        the last arrival, inflating TTFT for every earlier request;
        ``advance`` jumps an idle engine to the next arrival instead,
        which is the only thing the barrier achieved in the closed loop."""
        req.engine_id = self.engine_id
        heapq.heappush(self._waiting, (req.arrival, next(self._seq), req))

    def load(self) -> float:
        """Scheduler load signal: backlog + busy horizon."""
        return len(self._waiting) + len(self.running) * 0.5

    def has_prefix_locally(self, req: Request) -> bool:
        keys = self.manager.index.keys_for(req.tokens)
        if not keys:
            return False
        return self.manager.hbm.has_key(keys[0])

    # ------------------------------------------------------------------
    def required_slots(self, req: Request) -> int:
        bt = self.manager.hbm.block_tokens
        return -(-(len(req.tokens) + req.n_output) // bt)

    def _writeback_latency(self, n_new: int) -> float:
        """Write-back cost of n_new fresh blocks (shared by both modes):
        RDMA pays the CPU-driven path synchronously; the beluga fused
        kernel runs in-stream, ~70% overlapped with compute."""
        from repro.core import fabric

        lay = self.manager.pool.layout
        size = n_new * lay.block_bytes
        nfrag = n_new * lay.n_fragments
        if self.manager.transfer.mode == "rdma":
            return fabric.rdma_transfer_latency(
                size, nfrag, gpu_side=True, c=self.manager.transfer.constants
            )
        return 0.3 * fabric.gpu_transfer_latency(
            size, nfrag, method="fused_kernel", c=self.manager.transfer.constants
        )

    def _admit_one(self, req: Request) -> None:
        t0 = max(self.clock, req.arrival)
        req.t_admitted = t0
        plan = self.manager.plan_fetch(req.tokens, now=t0)
        req.hit_tokens = plan.n_hit_tokens
        fetch_t = 0.0
        if plan.hit_blocks:
            fetch_t = plan.fetch_latency  # includes RDMA sw staging (manager)
            try:
                self.manager.fetch_into_hbm(req.req_id, plan)
            except Exception:  # noqa: BLE001
                # failed fetch (HBM pressure / epoch race): fall back to
                # full recompute. The manager already rolled back and
                # registered an empty sequence; keep a defensive register
                # here so the table lookup below can never KeyError.
                diag.note("engine.fetch_fallback_recompute")
                fetch_t = 0.0
                plan.n_miss_tokens = len(req.tokens)
                req.hit_tokens = 0  # nothing was actually fetched
                if req.req_id not in self.manager.hbm.seq_tables:
                    self.manager.hbm.register_sequence(req.req_id, [])
        else:
            self.manager.hbm.register_sequence(req.req_id, [])
        # reserve the remaining slots (miss prefix + decode growth)
        table = self.manager.hbm.seq_tables[req.req_id]
        need = self.required_slots(req) - len(table)
        if need > 0:
            table.extend(self.manager.hbm.allocate(need))
        prefill_t = (
            self.runner.prefill_time(plan.n_miss_tokens, len(req.tokens))
            if plan.n_miss_tokens
            else 0.0
        )
        wb_t = 0.0
        n_new = self.manager.writeback(
            req.req_id, req.tokens, keys=plan.keys, now=t0 + fetch_t + prefill_t
        )
        if n_new:
            wb_t = self._writeback_latency(n_new)
        self.clock = t0 + fetch_t + prefill_t + wb_t
        self.stats.fetch_s += fetch_t
        self.stats.writeback_s += wb_t
        self.stats.busy_s += fetch_t + prefill_t + wb_t
        self.stats.prefills += 1
        req.t_first_token = self.clock
        req.tokens_out = 1
        req.state = "running"
        if req.tokens_out >= req.n_output:
            self._finish(req)
        else:
            self.running.append(req)

    def _decode_step(self) -> None:
        dt = self.runner.decode_step_time(len(self.running))
        self.clock += dt
        self.stats.busy_s += dt
        self.stats.decode_steps += 1
        still_running = []
        for req in self.running:
            req.tokens_out += 1
            if req.tokens_out >= req.n_output:
                self._finish(req)
            else:
                still_running.append(req)
        self.running = still_running

    def _finish(self, req: Request) -> None:
        req.t_done = self.clock
        req.state = "done"
        self.manager.finish(req.req_id)

    # ------------------------------------------------------------------
    def advance(self, until: float) -> None:
        """Run the engine's virtual clock forward to `until`."""
        while True:
            head = self._waiting[0] if self._waiting else None
            ready = head is not None and head[0] <= self.clock
            admissible = (
                ready
                and len(self.running) < self.max_batch
                # KV-capacity gate (vLLM watermark): don't admit a request
                # whose context + decode budget can't fit in HBM slots
                and self.manager.hbm.free_slots() >= self.required_slots(head[2])
            )
            if admissible:
                # prefill-priority admission (vLLM default)
                if self.clock >= until:
                    break
                heapq.heappop(self._waiting)
                self._admit_one(head[2])
                if self.migrator is not None:
                    self.migrator.run_until(self.clock)
            elif self.running:
                if self.clock >= until:
                    break
                self._decode_step()
                if self.migrator is not None:
                    self.migrator.run_until(self.clock)
            elif head is not None:
                if ready or head[0] >= until:
                    # `ready` here means capacity-gated with nothing running:
                    # no event can unblock before `until`, so stop (the seed
                    # loop would spin on this state)
                    break
                self.clock = max(self.clock, head[0])
                if self.migrator is not None:
                    # idle gap: give the background engine its elapsed
                    # budget BEFORE the next admission plans against the
                    # tier state (demote-ahead-of-pressure)
                    self.migrator.run_until(self.clock)
            else:
                break  # idle: leave the clock at the last busy instant

    def drain(self) -> float:
        """Run until all submitted work completes; returns final clock."""
        while self._waiting or self.running:
            clock_before = self.clock
            n_before = len(self._waiting) + len(self.running)
            # the horizon must reach past the next queued arrival: with no
            # submit clock barrier an idle engine can hold a head request
            # arriving further out than clock+3600, and a horizon short of
            # it would break without progress and misread as deadlock
            na = self.next_arrival()
            horizon = max(self.clock, na if na is not None else self.clock)
            self.advance(horizon + 3600.0)
            if self.clock == clock_before and (
                len(self._waiting) + len(self.running) == n_before
            ):
                break  # capacity-deadlocked: no event can ever fire
        return self.clock

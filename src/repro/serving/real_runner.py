"""RealEngine: actual token generation through the Beluga KVCache stack.

CPU-runnable end-to-end driver (reduced configs): prompts are served with
real numerics and REAL pool reuse —

  miss: prefill -> per-layer KV packed into pool blocks (kv_gather_write
        kernel) -> blocks published in the GlobalIndex;
  hit : pool blocks fetched (kv_scatter_read kernel) straight into a decode
        cache — prefill for the hit prefix is SKIPPED; only the tail tokens
        (not covering a full block) are stepped through decode.

Restricted to homogeneous attention stacks (period-1 archs: olmo, qwen,
command-r, internlm2, musicgen, internvl2 backbones) — hybrid/ssm archs
pool their recurrent state snapshots instead (see DESIGN.md §5) and are
exercised via the simulated cluster.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import RuntimeConfig
from repro.configs.registry import reduced_config
from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.kernels import ops
from repro.models import Model
from repro.models import transformer as stack_lib


@dataclass
class RealEngine:
    cfg: object
    model: Model
    pool: BelugaPool
    index: GlobalIndex
    params: dict
    max_len: int
    kernel_mode: str = "auto"

    @classmethod
    def create(
        cls,
        arch: str = "olmo-1b",
        max_len: int = 128,
        pool_blocks: int = 256,
        seed: int = 0,
        kernel_mode: str = "auto",
    ) -> "RealEngine":
        cfg = reduced_config(arch)
        assert stack_lib.period_length(cfg) == 1 and cfg.n_heads > 0, (
            "RealEngine needs a homogeneous attention stack"
        )
        runtime = RuntimeConfig(
            remat="none", attn_chunk_q=32, attn_chunk_kv=32, decode_kv="replicated"
        )
        model = Model(cfg, runtime)
        params = model.init(jax.random.key(seed))
        layout = PoolLayout(
            block_tokens=16,
            n_layers_kv=cfg.n_layers,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
        )
        pool = BelugaPool(layout, n_blocks=pool_blocks, n_shards=8, backing="jax")
        return cls(
            cfg=cfg,
            model=model,
            pool=pool,
            index=GlobalIndex(pool),
            params=params,
            max_len=max_len,
            kernel_mode=kernel_mode,
        )

    # ------------------------------------------------------------------
    def _cache_to_layers(self, cache: dict) -> tuple[jax.Array, jax.Array]:
        """(L, 1, T, hkv, hd) stacked cache -> (L, T, hkv, hd)."""
        k = cache["pos_0"]["k"][:, 0]
        v = cache["pos_0"]["v"][:, 0]
        return k, v

    def _layers_to_cache(self, k: jax.Array, v: jax.Array) -> dict:
        return {"pos_0": {"k": k[:, None], "v": v[:, None]}}

    # ------------------------------------------------------------------
    def generate(self, prompt: list[int], max_new: int = 16) -> tuple[list[int], dict]:
        t_start = time.time()
        bt = self.pool.layout.block_tokens
        hits = self.index.match_prefix(prompt)
        n_hit = len(hits) * bt
        info = {"hit_tokens": n_hit}

        if n_hit:
            # --- pool fetch path: scatter-read hit blocks, skip prefill ---
            block_ids = [b for _, b, _ in hits]
            blocks = self.pool.data[jnp.asarray(block_ids)]
            n_slots = self.max_len // bt
            k_cache, v_cache = ops.kv_scatter_read(
                blocks, jnp.arange(len(block_ids), dtype=jnp.int32), n_slots,
                mode=self.kernel_mode,
            )
            cache = self._layers_to_cache(
                k_cache.astype(jnp.dtype(self.cfg.dtype)),
                v_cache.astype(jnp.dtype(self.cfg.dtype)),
            )
            # pad cache seq dim up to max_len if needed
            pad = self.max_len - cache["pos_0"]["k"].shape[2]
            if pad > 0:
                cache = jax.tree.map(
                    lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
                    cache,
                )
            # step the tail through decode; if the prompt is fully covered,
            # re-feed the last token (overwrites identical KV, yields logits)
            start = min(n_hit, len(prompt) - 1)
            logits = None
            for t in range(start, len(prompt)):
                logits, cache = self._decode(
                    cache, jnp.asarray([prompt[t]]), jnp.asarray([t])
                )
        else:
            # --- prefill path + pool writeback ---
            batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
            logits, cache = self._prefill(batch)
            self._writeback(prompt, cache)

        info["ttft_s"] = time.time() - t_start
        out = [int(jnp.argmax(logits[0]))]
        pos = len(prompt)
        while len(out) < max_new and pos + 1 < self.max_len:
            logits, cache = self._decode(
                cache, jnp.asarray([out[-1]]), jnp.asarray([pos])
            )
            out.append(int(jnp.argmax(logits[0])))
            pos += 1
        info["total_s"] = time.time() - t_start
        return out, info

    # ------------------------------------------------------------------
    @functools.cached_property
    def _prefill(self):
        return jax.jit(functools.partial(self.model.prefill_fn, self.params,
                                         max_len=self.max_len))

    @functools.cached_property
    def _decode(self):
        return jax.jit(functools.partial(self.model.decode_fn, self.params))

    def _writeback(self, prompt: list[int], cache: dict) -> None:
        bt = self.pool.layout.block_tokens
        n_blocks = len(prompt) // bt
        if not n_blocks:
            return
        k, v = self._cache_to_layers(cache)
        blocks = ops.kv_gather_write(
            k, v, jnp.arange(n_blocks, dtype=jnp.int32), bt, mode=self.kernel_mode
        )
        block_ids = self.pool.allocate(n_blocks)
        self.pool.data = self.pool.data.at[jnp.asarray(block_ids)].set(
            blocks.astype(self.pool.data.dtype)
        )
        keys = self.index.keys_for(prompt)
        # commit AFTER the payload write (§5.1): one batched epoch bump,
        # one batched publish (single lock, one scatter per column)
        epochs = self.pool.write_blocks(block_ids)
        self.index.publish_many(list(keys[: len(block_ids)]), block_ids, epochs, bt)

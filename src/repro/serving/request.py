"""Request lifecycle objects + metrics."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass
class Request:
    req_id: str
    tokens: list[int]
    n_output: int
    arrival: float = 0.0
    # lifecycle
    state: str = "queued"  # queued | running | done
    t_admitted: float | None = None
    t_first_token: float | None = None
    t_done: float | None = None
    tokens_out: int = 0
    engine_id: int | None = None
    hit_tokens: int = 0

    @property
    def ttft(self) -> float | None:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    @property
    def tpot(self) -> float | None:
        if self.t_done is None or self.t_first_token is None or self.tokens_out <= 1:
            return None
        return (self.t_done - self.t_first_token) / max(1, self.tokens_out - 1)


def percentile(xs: list[float], p: float) -> float:
    if not xs:
        return float("nan")
    xs = sorted(xs)
    i = min(len(xs) - 1, int(round(p / 100 * (len(xs) - 1))))
    return xs[i]


def summarize(reqs: list[Request], span: float) -> dict:
    done = [r for r in reqs if r.state == "done"]
    ttfts = [r.ttft for r in done if r.ttft is not None]
    tpots = [r.tpot for r in done if r.tpot is not None]
    return {
        "n_done": len(done),
        "avg_ttft_s": sum(ttfts) / max(1, len(ttfts)),
        "p99_ttft_s": percentile(ttfts, 99),
        "avg_tpot_s": sum(tpots) / max(1, len(tpots)),
        "p99_tpot_s": percentile(tpots, 99),
        "qps": len(done) / max(span, 1e-9),
        "hit_tokens": sum(r.hit_tokens for r in done),
        "total_prompt_tokens": sum(len(r.tokens) for r in done),
    }

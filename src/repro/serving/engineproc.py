"""Engine worker OS processes over the zero-copy data plane (ROADMAP #2).

PR 5 moved the METADATA plane out of the client interpreter; this module
moves the ENGINES out.  One worker process per modeled "GPU" hosts the
full serving stack — ``EngineInstance`` + ``KVCacheManager`` + HBM cache +
``TransferEngine`` — and runs it against:

  * the shared pool DATA segment (``repro.core.shmpool.SharedPoolData``):
    KV block scatter/gather is native load/store on memory every process
    maps — zero payload copies through the parent (the paper's core
    claim, across real OS process boundaries);
  * the pool ALLOCATOR ring back to the pool-owning parent
    (``repro.core.wire.PoolRpcClient``), slot-partitioned so N workers
    share one ring without colliding;
  * the METADATA rings of the shard service processes, the same
    slot-partitioning trick letting parent + workers share each shard's
    ring (``CxlRpcClient(slot_range=...)``).

The parent drives a worker over a tiny COMMAND ring (same ShmRing slot
protocol, own binary codec below): submit requests, run the virtual
clock, page results and stats back.  Request payloads never travel on the
command ring — only token ids, timings and counters; KV bytes exist
solely in the shared segment.

Idle workers park on a ``Doorbell`` exactly like the metadata services
(arm ``CTRL_DOORBELL``, re-scan, bounded FIFO wait), so an N-worker
cluster at rest costs no busy-poll CPU.

The worker import chain is deliberately jax-free (same discipline as
``repro.core.procserver``): fork is safe on a bare interpreter, and spawn
re-imports in ~0.4 s.
"""

from __future__ import annotations

import atexit
import math
import struct
import time
from dataclasses import dataclass

import numpy as np

from repro.core.procserver import _mp_context
from repro.core.rpc import (
    CTRL_DOORBELL,
    CTRL_READY,
    CTRL_STOP,
    RESP_ERROR,
    RESP_READY,
    CxlRpcClient,
    ShmRing,
    drain_ready,
)
from repro.core.shm import Doorbell
from repro.core.wire import WireError
from repro.serving.engine import SimRunnerConfig
from repro.serving.request import Request

# ---------------------------------------------------------------------------
# command codec (parent -> worker, little-endian)
# ---------------------------------------------------------------------------
#     SUBMIT  := op:u8 n:u32 arrival:f64 n_output:i32 req_idx:u32 tokens[n*i32]
#             -> n_queued:u32
#     RUN     := op:u8 mode:u8 until:f64      (mode 0 = drain, 1 = advance)
#             -> clock:f64 n_done:u32
#     RESULTS := op:u8 start:u32 max:u32
#             -> total:u32 m:u32 m * (req_idx:u32 t_admitted:f64 t_first:f64
#                t_done:f64 tokens_out:i32 hit_tokens:i32 state:u8)
#                (NaN encodes a None timestamp)
#     STATS   := op:u8 -> fixed _STATS_RESP struct (engine + manager +
#                transfer counters and the worker's virtual clock)
WCMD_SUBMIT, WCMD_RUN, WCMD_RESULTS, WCMD_STATS = 1, 2, 3, 4

_U32 = struct.Struct("<I")
_SUB_HDR = struct.Struct("<BIdiI")
_RUN = struct.Struct("<BBd")
_RUN_RESP = struct.Struct("<dI")
_RES_REQ = struct.Struct("<BII")
_RES_REC = struct.Struct("<IdddiiB")
_STATS_REQ = struct.Struct("<B")
# clock | prefills decode_steps | busy fetch writeback | 7 manager counters
# | writes reads bytes_w bytes_r requests_issued | modeled_write modeled_read
_STATS_RESP = struct.Struct("<dQQdddQQQQQQQQQQQQdd")

_STATE_CODE = {"queued": 0, "running": 1, "done": 2}
_STATE_NAME = ["queued", "running", "done"]


def _opt(v: float | None) -> float:
    return float("nan") if v is None else float(v)


def _unopt(v: float) -> float | None:
    return None if math.isnan(v) else v


def partition_slots(n_slots: int, n_parts: int) -> list[tuple[int, int]]:
    """Carve one ring's slots into ``n_parts`` disjoint ``[lo, hi)`` shares
    (the last part absorbs the remainder).  Each share needs >= 2 slots so
    every owner can keep a call outstanding while one slot sits
    quarantined."""
    per = n_slots // n_parts
    if per < 2:
        raise ValueError(
            f"{n_slots} slots cannot be split {n_parts} ways (need >= 2 each)"
        )
    return [
        (i * per, (i + 1) * per if i < n_parts - 1 else n_slots)
        for i in range(n_parts)
    ]


@dataclass(frozen=True)
class EngineWorkerSpec:
    """Everything a worker needs to build its stack — plain data only
    (names, numbers, the picklable pool attach spec); no live objects
    cross the boundary, same discipline as ``ShardServiceSpec``."""

    engine_id: int
    pool_spec: dict  # BelugaPool.share_data() attach spec
    cmd_ring_name: str
    cmd_slots: int
    cmd_payload: int
    cmd_doorbell_name: str | None
    pool_ring_name: str
    pool_slots: int
    pool_payload: int
    pool_doorbell_name: str | None
    pool_slot_range: tuple[int, int]
    index_ring_names: tuple[str, ...]
    index_slots: int
    index_payload: int
    index_doorbell_names: tuple[str | None, ...]
    index_slot_range: tuple[int, int]
    hbm_slots: int
    transfer_mode: str  # beluga | rdma | none
    super_block_tokens: int
    straggler_cutover: float | None
    runner: SimRunnerConfig
    idle_spin_passes: int = 200
    idle_backoff_s: float = 100e-6
    doorbell_wait_s: float = 0.05


# ---------------------------------------------------------------------------
# worker side
# ---------------------------------------------------------------------------
def _no_offload_plan():
    from repro.kvcache.manager import FetchPlan

    def plan(tokens, now=0.0):
        return FetchPlan(0, len(tokens), [], 0.0, False)

    return plan


def _build_worker_stack(spec: EngineWorkerSpec):
    """Attach segments/rings and construct the full serving stack.

    Returns (engine, closeables); closing every closeable (views, rings,
    attach-side doorbells) is the worker's teardown duty."""
    from repro.core.index import PrefixHasher
    from repro.core.shmpool import SharedPoolData, WorkerPoolView
    from repro.core.transfer import TransferEngine
    from repro.core.wire import (
        PoolRpcClient,
        RpcIndexClient,
        ShardedRpcIndexClient,
    )
    from repro.kvcache.hbm_cache import HbmPagedCache
    from repro.kvcache.manager import KVCacheManager
    from repro.serving.engine import EngineInstance, SimRunner

    closeables = []
    shared = SharedPoolData(spec.pool_spec)
    closeables.append(shared)
    pool_ring = ShmRing.attach(
        spec.pool_ring_name, spec.pool_slots, spec.pool_payload
    )
    closeables.append(pool_ring)
    pool_db = (
        None if spec.pool_doorbell_name is None
        else Doorbell.attach(spec.pool_doorbell_name)
    )
    if pool_db is not None:
        closeables.append(pool_db)
    pool_rpc = CxlRpcClient(
        pool_ring, doorbell=pool_db, slot_range=spec.pool_slot_range
    )
    alloc = PoolRpcClient(
        pool_rpc, spec.pool_spec["n_blocks"], max_payload=spec.pool_payload
    )
    pool_view = WorkerPoolView(shared, alloc)
    bt = spec.pool_spec["block_tokens"]
    hasher = PrefixHasher(bt)
    index_rpcs = []
    for name, db_name in zip(spec.index_ring_names, spec.index_doorbell_names):
        ring = ShmRing.attach(name, spec.index_slots, spec.index_payload)
        closeables.append(ring)
        idx_db = None if db_name is None else Doorbell.attach(db_name)
        if idx_db is not None:
            closeables.append(idx_db)
        index_rpcs.append(CxlRpcClient(
            ring, doorbell=idx_db, slot_range=spec.index_slot_range,
        ))
    # evictions served by a shard process defer the pool release; in a
    # WORKER the release itself is one more hop over the allocator ring
    # back to the owning parent (on_freed -> PoolRpcClient.release)
    if len(index_rpcs) > 1:
        index = ShardedRpcIndexClient(
            index_rpcs, bt, max_payload=spec.index_payload, hasher=hasher,
            on_freed=alloc.release,
        )
    else:
        index = RpcIndexClient(
            index_rpcs[0], bt, max_payload=spec.index_payload, hasher=hasher,
            on_freed=alloc.release,
        )
    transfer = TransferEngine(
        pool_view,
        mode="beluga" if spec.transfer_mode == "none" else spec.transfer_mode,
        super_block_tokens=spec.super_block_tokens,
    )
    hbm = HbmPagedCache(spec.hbm_slots, bt)
    mgr = KVCacheManager(
        pool_view, index, hbm, transfer,
        recompute_cutover=spec.straggler_cutover,
        prefill_tok_per_s=spec.runner.prefill_tok_per_s,
    )
    if spec.transfer_mode == "none":
        mgr.plan_fetch_orig = mgr.plan_fetch
        mgr.plan_fetch = _no_offload_plan()
        mgr.writeback = lambda *a, **k: 0
    engine = EngineInstance(
        spec.engine_id, mgr, SimRunner(spec.runner)
    )
    return engine, closeables


def _make_worker_handler(engine, reqs: list):
    """Command-ring dispatcher (runs inside the worker's serve loop)."""

    def handler(payload: bytes) -> bytes:
        if not payload:
            raise WireError("empty worker command")
        op = payload[0]
        if op == WCMD_SUBMIT:
            _, n, arrival, n_output, req_idx = _SUB_HDR.unpack_from(payload)
            tokens = np.frombuffer(
                payload, np.int32, n, _SUB_HDR.size
            ).tolist()
            req = Request(
                req_id=f"w{engine.engine_id}-{req_idx}",
                tokens=tokens, n_output=n_output, arrival=arrival,
            )
            reqs.append((req_idx, req))
            engine.submit(req, arrival)
            return _U32.pack(engine.n_queued)
        if op == WCMD_RUN:
            _, mode, until = _RUN.unpack_from(payload)
            if mode == 0:
                engine.drain()
            else:
                engine.advance(until)
            n_done = sum(1 for _, r in reqs if r.state == "done")
            return _RUN_RESP.pack(engine.clock, n_done)
        if op == WCMD_RESULTS:
            _, start, max_items = _RES_REQ.unpack_from(payload)
            page = reqs[start : start + max_items]
            out = [_U32.pack(len(reqs)), _U32.pack(len(page))]
            for idx, r in page:
                out.append(_RES_REC.pack(
                    idx, _opt(r.t_admitted), _opt(r.t_first_token),
                    _opt(r.t_done), r.tokens_out, r.hit_tokens,
                    _STATE_CODE[r.state],
                ))
            return b"".join(out)
        if op == WCMD_STATS:
            es, ms = engine.stats, engine.manager.stats
            ts = engine.manager.transfer.stats
            return _STATS_RESP.pack(
                engine.clock,
                es.prefills, es.decode_steps,
                es.busy_s, es.fetch_s, es.writeback_s,
                ms.prefix_hits_tokens, ms.prefix_miss_tokens, ms.fetches,
                ms.writebacks, ms.recompute_cutovers, ms.pool_evictions,
                ms.degraded_ops,
                ts.writes, ts.reads, ts.bytes_written, ts.bytes_read,
                ts.requests_issued,
                ts.modeled_write_s, ts.modeled_read_s,
            )
        raise WireError(f"unknown worker command {op}")

    return handler


def _engine_worker_main(spec: EngineWorkerSpec) -> None:
    """Worker entry: attach everything, serve the command ring until
    CTRL_STOP (the same arm/re-scan/park idle loop as ``_service_main``)."""
    cmd_ring = ShmRing.attach(spec.cmd_ring_name, spec.cmd_slots, spec.cmd_payload)
    engine, closeables = _build_worker_stack(spec)
    reqs: list = []
    handler = _make_worker_handler(engine, reqs)
    doorbell = None
    if spec.cmd_doorbell_name is not None:
        doorbell = Doorbell.attach(spec.cmd_doorbell_name)
        doorbell.open_read()
    cmd_ring.ctrl[CTRL_READY] = 1
    idle = 0
    try:
        while not cmd_ring.ctrl[CTRL_STOP]:
            if drain_ready(cmd_ring, handler):
                idle = 0
                continue
            idle += 1
            if idle < spec.idle_spin_passes:
                time.sleep(0)
            elif doorbell is None:
                time.sleep(spec.idle_backoff_s)
            else:
                cmd_ring.ctrl[CTRL_DOORBELL] = 1
                try:
                    if drain_ready(cmd_ring, handler):
                        idle = 0
                        continue
                    doorbell.wait(spec.doorbell_wait_s)
                finally:
                    cmd_ring.ctrl[CTRL_DOORBELL] = 0
    finally:
        handler = None  # noqa: F841 — drop ring views before close
        if doorbell is not None:
            doorbell.close()
        engine = None  # noqa: F841
        for c in closeables:
            try:
                c.close()
            except Exception:  # noqa: BLE001
                pass
        cmd_ring.close()


# ---------------------------------------------------------------------------
# parent side
# ---------------------------------------------------------------------------
class EngineWorkerHost:
    """Parent-side handle on one engine worker process.

    Owns the command ring + its doorbell (and unlinks both); the data
    segment, pool ring and metadata rings are owned elsewhere and only
    their NAMES are handed to the worker.  Mirrors the
    ``ProcessRpcServer`` lifecycle: in-band CTRL_STOP shutdown escalating
    to terminate/kill, idempotent ``close``, atexit hygiene hook.
    """

    def __init__(
        self,
        spec_kwargs: dict,
        *,
        cmd_slots: int = 8,
        cmd_payload: int = 1 << 16,
        use_doorbell: bool = True,
    ):
        self.ring = ShmRing.create_shared(cmd_slots, cmd_payload)
        self.doorbell = Doorbell.create() if use_doorbell else None
        self.spec = EngineWorkerSpec(
            cmd_ring_name=self.ring.shm_name,
            cmd_slots=cmd_slots,
            cmd_payload=cmd_payload,
            cmd_doorbell_name=(
                None if self.doorbell is None else self.doorbell.path
            ),
            **spec_kwargs,
        )
        self.engine_id = self.spec.engine_id
        self.client = CxlRpcClient(
            self.ring,
            liveness=self.alive,
            doorbell=(
                None if self.doorbell is None
                else Doorbell.attach(self.doorbell.path)
            ),
        )
        self.proc = _mp_context().Process(
            target=_engine_worker_main, args=(self.spec,), daemon=True
        )
        self.n_submitted = 0
        self.n_done = 0
        self.clock = 0.0
        self._closed = False
        atexit.register(self.close)

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "EngineWorkerHost":
        self.proc.start()
        return self

    @property
    def ready(self) -> bool:
        ctrl = self.ring.ctrl
        return ctrl is not None and bool(ctrl[CTRL_READY])

    def wait_ready(self, timeout: float = 20.0) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.ready:
                return True
            if self.proc.pid is not None and not self.proc.is_alive():
                return False
            time.sleep(1e-3)
        return self.ready

    def alive(self) -> bool:
        proc = self.proc
        return proc is not None and proc.is_alive()

    def kill(self) -> None:
        """Crash the worker ungracefully (hygiene/chaos hook)."""
        if self.proc is not None and self.proc.pid is not None:
            self.proc.kill()
            self.proc.join(timeout=5)

    def stop(self, timeout: float = 5.0) -> None:
        proc = self.proc
        if proc is None or proc.pid is None:
            return
        if proc.is_alive() and self.ring.ctrl is not None:
            self.ring.ctrl[CTRL_STOP] = 1
            if self.doorbell is not None:
                self.doorbell.ring()
            proc.join(timeout)
        if proc.is_alive():
            proc.terminate()
            proc.join(1.0)
        if proc.is_alive():
            proc.kill()
            proc.join(1.0)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self.stop()
        finally:
            self.ring.close()
            if self.doorbell is not None:
                self.doorbell.close()  # owner: unlinks the FIFO
            try:
                atexit.unregister(self.close)
            except Exception:  # noqa: BLE001
                pass

    # -- commands --------------------------------------------------------
    def submit_indexed(self, req: Request, req_idx: int) -> None:
        """Ship one request to the worker; ``req_idx`` is the parent's
        global index (the worker echoes it back with the results)."""
        body = np.asarray(req.tokens, np.int32).tobytes()
        if _SUB_HDR.size + len(body) > self.spec.cmd_payload:
            raise WireError(
                f"prompt of {len(req.tokens)} tokens exceeds the "
                f"{self.spec.cmd_payload} B command slot"
            )
        req.engine_id = self.engine_id
        self.client.call(
            _SUB_HDR.pack(
                WCMD_SUBMIT, len(req.tokens), req.arrival,
                req.n_output, req_idx,
            ) + body
        )
        self.n_submitted += 1

    def submit(self, req: Request, now: float = 0.0) -> None:  # noqa: ARG002
        """Engine-shaped convenience (tests): parent index == local order."""
        self.submit_indexed(req, self.n_submitted)

    def load(self) -> float:
        """Scheduler signal between runs: requests not yet seen done."""
        return float(self.n_submitted - self.n_done)

    def post_run(self, until: float | None = None) -> int:
        """Post (don't wait): lets the parent start ALL workers' clocks
        before collecting any — the N drains run concurrently."""
        mode, horizon = (0, 0.0) if until is None else (1, until)
        return self.client.post(_RUN.pack(WCMD_RUN, mode, horizon))

    def collect_run(self, slot: int, timeout: float = 600.0) -> float:
        """Wait out a (long) drain WITHOUT busy-spinning the parent core:
        gentle 2 ms poll on the slot word, then the client's collect for
        the usual bookkeeping/error paths.  Returns the worker clock."""
        ring = self.client.ring
        deadline = time.perf_counter() + timeout
        while int(ring.status[slot]) not in (RESP_READY, RESP_ERROR):
            if not self.alive() or time.perf_counter() > deadline:
                break  # let collect() classify (died / timed out)
            time.sleep(2e-3)
        clock, n_done = _RUN_RESP.unpack(self.client.collect(slot, timeout))
        self.clock = clock
        self.n_done = n_done
        return clock

    def run(self, until: float | None = None, timeout: float = 600.0) -> float:
        return self.collect_run(self.post_run(until), timeout)

    def fetch_results(self) -> list[tuple]:
        """Page every request record back:
        [(req_idx, t_admitted, t_first, t_done, tokens_out, hit, state)]."""
        page = max(1, (self.spec.cmd_payload - 16) // _RES_REC.size)
        out: list[tuple] = []
        start = 0
        while True:
            resp = self.client.call(
                _RES_REQ.pack(WCMD_RESULTS, start, page)
            )
            (total,) = _U32.unpack_from(resp)
            (m,) = _U32.unpack_from(resp, 4)
            off = 8
            for _ in range(m):
                idx, ta, tf, td, tout, hit, st = _RES_REC.unpack_from(resp, off)
                out.append((
                    idx, _unopt(ta), _unopt(tf), _unopt(td), tout, hit,
                    _STATE_NAME[st],
                ))
                off += _RES_REC.size
            start += m
            if start >= total or m == 0:
                return out

    def apply_results(self, requests: list[Request]) -> None:
        """Fold the worker's timings back into the parent's own Request
        objects (matched by the echoed global index)."""
        for idx, ta, tf, td, tout, hit, state in self.fetch_results():
            r = requests[idx]
            r.t_admitted, r.t_first_token, r.t_done = ta, tf, td
            r.tokens_out, r.hit_tokens, r.state = tout, hit, state
            r.engine_id = self.engine_id

    def stats_dict(self) -> dict:
        v = _STATS_RESP.unpack(self.client.call(_STATS_REQ.pack(WCMD_STATS)))
        (clock, prefills, decode_steps, busy_s, fetch_s, writeback_s,
         hit_tok, miss_tok, fetches, writebacks, cutovers, evictions,
         degraded, t_writes, t_reads, t_bw, t_br, t_reqs,
         t_mw, t_mr) = v
        self.clock = clock
        return {
            "clock": clock,
            "engine": {
                "prefills": prefills, "decode_steps": decode_steps,
                "busy_s": busy_s, "fetch_s": fetch_s,
                "writeback_s": writeback_s,
            },
            "manager": {
                "prefix_hits_tokens": hit_tok,
                "prefix_miss_tokens": miss_tok,
                "fetches": fetches, "writebacks": writebacks,
                "recompute_cutovers": cutovers,
                "pool_evictions": evictions, "degraded_ops": degraded,
            },
            "transfer": {
                "writes": t_writes, "reads": t_reads,
                "bytes_written": t_bw, "bytes_read": t_br,
                "requests_issued": t_reqs,
                "modeled_write_s": t_mw, "modeled_read_s": t_mr,
            },
        }

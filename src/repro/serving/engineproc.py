"""Engine worker OS processes over the zero-copy data plane (ROADMAP #2).

PR 5 moved the METADATA plane out of the client interpreter; this module
moves the ENGINES out.  One worker process per modeled "GPU" hosts the
full serving stack — ``EngineInstance`` + ``KVCacheManager`` + HBM cache +
``TransferEngine`` — and runs it against:

  * the shared pool DATA segment (``repro.core.shmpool.SharedPoolData``):
    KV block scatter/gather is native load/store on memory every process
    maps — zero payload copies through the parent (the paper's core
    claim, across real OS process boundaries);
  * the pool ALLOCATOR ring back to the pool-owning parent
    (``repro.core.wire.PoolRpcClient``), slot-partitioned so N workers
    share one ring without colliding;
  * the METADATA rings of the shard service processes, the same
    slot-partitioning trick letting parent + workers share each shard's
    ring (``CxlRpcClient(slot_range=...)``).

The parent drives a worker over a tiny COMMAND ring (same ShmRing slot
protocol, own binary codec below): submit requests, run the virtual
clock, page results and stats back.  Request payloads never travel on the
command ring — only token ids, timings and counters; KV bytes exist
solely in the shared segment.

Idle workers park on a ``Doorbell`` exactly like the metadata services
(arm ``CTRL_DOORBELL``, re-scan, bounded FIFO wait), so an N-worker
cluster at rest costs no busy-poll CPU.

The worker import chain is deliberately jax-free (same discipline as
``repro.core.procserver``): fork is safe on a bare interpreter, and spawn
re-imports in ~0.4 s.
"""

from __future__ import annotations

import atexit
import math
import struct
import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core import diag
from repro.core.locks import make_lock
from repro.core.procserver import _mp_context
from repro.core.rpc import (
    CTRL_DOORBELL,
    CTRL_READY,
    CTRL_STOP,
    RESP_ERROR,
    RESP_READY,
    CxlRpcClient,
    ServiceDiedError,
    ShmRing,
    drain_ready,
)
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.core.shm import Doorbell
from repro.core.wire import WireError
from repro.serving.engine import SimRunnerConfig
from repro.serving.request import Request

# ---------------------------------------------------------------------------
# command codec (parent -> worker, little-endian)
# ---------------------------------------------------------------------------
#     SUBMIT  := op:u8 n:u32 arrival:f64 n_output:i32 req_idx:u32 tokens[n*i32]
#             -> n_queued:u32
#     RUN     := op:u8 mode:u8 until:f64      (mode 0 = drain, 1 = advance)
#             -> clock:f64 n_done:u32
#     RESULTS := op:u8 start:u32 max:u32
#             -> total:u32 m:u32 m * (req_idx:u32 t_admitted:f64 t_first:f64
#                t_done:f64 tokens_out:i32 hit_tokens:i32 state:u8)
#                (NaN encodes a None timestamp)
#     STATS   := op:u8 -> fixed _STATS_RESP struct (engine + manager +
#                transfer counters and the worker's virtual clock)
#     ADOPT   := op:u8 plane:u8 shard:u32 n_slots:u32 payload:u32
#                ring_len:u32 ring_name  db_len:u32 doorbell_path
#             -> ok:u32
#                (ring-generation cutover INTO the worker: plane 0 = the
#                index shard ``shard``'s ring, plane 1 = the pool
#                allocator ring; the worker attaches the named segment
#                and ``adopt_ring``s its own client onto it)
WCMD_SUBMIT, WCMD_RUN, WCMD_RESULTS, WCMD_STATS, WCMD_ADOPT = 1, 2, 3, 4, 5

_U32 = struct.Struct("<I")
_SUB_HDR = struct.Struct("<BIdiI")
_ADOPT_HDR = struct.Struct("<BBIII")
_RUN = struct.Struct("<BBd")
_RUN_RESP = struct.Struct("<dI")
_RES_REQ = struct.Struct("<BII")
_RES_REC = struct.Struct("<IdddiiB")
_STATS_REQ = struct.Struct("<B")
# clock | prefills decode_steps | busy fetch writeback | 7 manager counters
# | writes reads bytes_w bytes_r requests_issued | modeled_write modeled_read
_STATS_RESP = struct.Struct("<dQQdddQQQQQQQQQQQQdd")

_STATE_CODE = {"queued": 0, "running": 1, "done": 2}
_STATE_NAME = ["queued", "running", "done"]


def _opt(v: float | None) -> float:
    return float("nan") if v is None else float(v)


def _unopt(v: float) -> float | None:
    return None if math.isnan(v) else v


def partition_slots(n_slots: int, n_parts: int) -> list[tuple[int, int]]:
    """Carve one ring's slots into ``n_parts`` disjoint ``[lo, hi)`` shares
    (the last part absorbs the remainder).  Each share needs >= 2 slots so
    every owner can keep a call outstanding while one slot sits
    quarantined."""
    per = n_slots // n_parts
    if per < 2:
        raise ValueError(
            f"{n_slots} slots cannot be split {n_parts} ways (need >= 2 each)"
        )
    return [
        (i * per, (i + 1) * per if i < n_parts - 1 else n_slots)
        for i in range(n_parts)
    ]


def encode_adopt(
    plane: int,
    shard: int,
    n_slots: int,
    payload_bytes: int,
    ring_name: str,
    doorbell_path: str = "",
) -> bytes:
    """ADOPT command: cut one of the worker's service clients over onto a
    new ring generation (``plane`` 0 = index shard ``shard``, 1 = pool
    allocator).  An empty ``doorbell_path`` means poll-only."""
    rn = ring_name.encode()
    dp = doorbell_path.encode()
    return (
        _ADOPT_HDR.pack(WCMD_ADOPT, plane, shard, n_slots, payload_bytes)
        + _U32.pack(len(rn)) + rn + _U32.pack(len(dp)) + dp
    )


def _ring_liveness(client):
    """Liveness for clients with no process handle on the service: a
    retired ring generation has CTRL_STOP flipped by the supervisor, so
    reading the CURRENT ring's stop word through the client fails fast
    instead of burning the full collect timeout against a dead ring."""

    def live() -> bool:
        ctrl = client.ring.ctrl
        return ctrl is not None and not ctrl[CTRL_STOP]

    return live


@dataclass(frozen=True)
class EngineWorkerSpec:
    """Everything a worker needs to build its stack — plain data only
    (names, numbers, the picklable pool attach spec); no live objects
    cross the boundary, same discipline as ``ShardServiceSpec``."""

    engine_id: int
    pool_spec: dict  # BelugaPool.share_data() attach spec
    cmd_ring_name: str
    cmd_slots: int
    cmd_payload: int
    cmd_doorbell_name: str | None
    pool_ring_name: str
    pool_slots: int
    pool_payload: int
    pool_doorbell_name: str | None
    pool_slot_range: tuple[int, int]
    index_ring_names: tuple[str, ...]
    index_slots: int
    index_payload: int
    index_doorbell_names: tuple[str | None, ...]
    index_slot_range: tuple[int, int]
    hbm_slots: int
    transfer_mode: str  # beluga | rdma | none
    super_block_tokens: int
    straggler_cutover: float | None
    runner: SimRunnerConfig
    idle_spin_passes: int = 200
    idle_backoff_s: float = 100e-6
    doorbell_wait_s: float = 0.05
    # selfheal mode: survive service restarts (ring-generation cutover via
    # ADOPT, CTRL_STOP liveness, retry/degrade on the index plane, journal
    # writes proxied to the parent over the allocator ring)
    selfheal: bool = False
    retry: object | None = None  # RetryPolicy (picklable dataclass)


# ---------------------------------------------------------------------------
# worker side
# ---------------------------------------------------------------------------
def _no_offload_plan():
    from repro.kvcache.manager import FetchPlan

    def plan(tokens, now=0.0):
        return FetchPlan(0, len(tokens), [], 0.0, False)

    return plan


def _build_worker_stack(spec: EngineWorkerSpec):
    """Attach segments/rings and construct the full serving stack.

    Returns (engine, clients, closeables): ``clients`` maps the worker's
    service-facing RPC clients ({"pool": CxlRpcClient, "index": [CxlRpcClient,
    ...]}) so the ADOPT command can cut them over onto a respawned
    service's fresh ring; closing every closeable (views, rings,
    attach-side doorbells) is the worker's teardown duty."""
    from repro.core.index import PrefixHasher
    from repro.core.shmpool import (
        SharedPoolData,
        TieredWorkerPoolView,
        WorkerPoolView,
    )
    from repro.core.transfer import TransferEngine
    from repro.core.wire import (
        PoolRpcClient,
        RemoteJournal,
        RpcIndexClient,
        ShardedRpcIndexClient,
    )
    from repro.kvcache.hbm_cache import HbmPagedCache
    from repro.kvcache.manager import KVCacheManager
    from repro.serving.engine import EngineInstance, SimRunner

    closeables = []
    shared = SharedPoolData(spec.pool_spec)
    closeables.append(shared)
    pool_ring = ShmRing.attach(
        spec.pool_ring_name, spec.pool_slots, spec.pool_payload
    )
    closeables.append(pool_ring)
    pool_db = (
        None if spec.pool_doorbell_name is None
        else Doorbell.attach(spec.pool_doorbell_name)
    )
    if pool_db is not None:
        closeables.append(pool_db)
    pool_rpc = CxlRpcClient(
        pool_ring, doorbell=pool_db, slot_range=spec.pool_slot_range
    )
    if spec.selfheal:
        pool_rpc.liveness = _ring_liveness(pool_rpc)
    alloc = PoolRpcClient(
        pool_rpc, spec.pool_spec["n_blocks"], max_payload=spec.pool_payload
    )
    tiering = spec.pool_spec.get("tiering")
    if tiering is not None:
        # tiered parent pool: same concatenated data plane, plus the
        # keyed-alloc/touch control ops over the allocator ring
        pool_view = TieredWorkerPoolView(shared, alloc, tiering)
    else:
        pool_view = WorkerPoolView(shared, alloc)
    bt = spec.pool_spec["block_tokens"]
    hasher = PrefixHasher(bt)
    index_rpcs = []
    for name, db_name in zip(spec.index_ring_names, spec.index_doorbell_names):
        ring = ShmRing.attach(name, spec.index_slots, spec.index_payload)
        closeables.append(ring)
        idx_db = None if db_name is None else Doorbell.attach(db_name)
        if idx_db is not None:
            closeables.append(idx_db)
        rpc = CxlRpcClient(
            ring, doorbell=idx_db, slot_range=spec.index_slot_range,
        )
        if spec.selfheal:
            rpc.liveness = _ring_liveness(rpc)
        index_rpcs.append(rpc)
    # evictions served by a shard process defer the pool release; in a
    # WORKER the release itself is one more hop over the allocator ring
    # back to the owning parent (on_freed -> PoolRpcClient.release)
    if spec.selfheal:
        # the sharded client even for one shard: it carries the
        # retry/degrade machinery a restarting shard needs, and its
        # publishes are mirrored into the PARENT-held journals via the
        # journal proxy on the allocator ring — a respawned shard
        # rebuilds from a journal that includes worker publishes
        index = ShardedRpcIndexClient(
            index_rpcs, bt, max_payload=spec.index_payload, hasher=hasher,
            on_freed=alloc.release,
            journals=[
                RemoteJournal(
                    pool_rpc, s, max_payload=spec.pool_payload,
                    retry=spec.retry,
                )
                for s in range(len(index_rpcs))
            ],
            retry=spec.retry, degrade=True,
        )
    elif len(index_rpcs) > 1:
        index = ShardedRpcIndexClient(
            index_rpcs, bt, max_payload=spec.index_payload, hasher=hasher,
            on_freed=alloc.release,
        )
    else:
        index = RpcIndexClient(
            index_rpcs[0], bt, max_payload=spec.index_payload, hasher=hasher,
            on_freed=alloc.release,
        )
    transfer = TransferEngine(
        pool_view,
        mode="beluga" if spec.transfer_mode == "none" else spec.transfer_mode,
        super_block_tokens=spec.super_block_tokens,
    )
    hbm = HbmPagedCache(spec.hbm_slots, bt)
    mgr = KVCacheManager(
        pool_view, index, hbm, transfer,
        recompute_cutover=spec.straggler_cutover,
        prefill_tok_per_s=spec.runner.prefill_tok_per_s,
        degraded_ok=spec.selfheal,
    )
    if spec.transfer_mode == "none":
        mgr.plan_fetch_orig = mgr.plan_fetch
        mgr.plan_fetch = _no_offload_plan()
        mgr.writeback = lambda *a, **k: 0
    engine = EngineInstance(
        spec.engine_id, mgr, SimRunner(spec.runner)
    )
    clients = {"pool": pool_rpc, "index": index_rpcs}
    return engine, clients, closeables


def _make_worker_handler(engine, reqs: list, clients=None, closeables=None):
    """Command-ring dispatcher (runs inside the worker's serve loop)."""

    def handler(payload: bytes) -> bytes:
        if not payload:
            raise WireError("empty worker command")
        op = payload[0]
        if op == WCMD_ADOPT:
            _, plane, shard, n_slots, payload_bytes = _ADOPT_HDR.unpack_from(
                payload
            )
            off = _ADOPT_HDR.size
            (ln,) = _U32.unpack_from(payload, off)
            off += 4
            ring_name = payload[off : off + ln].decode()
            off += ln
            (ln,) = _U32.unpack_from(payload, off)
            off += 4
            db_path = payload[off : off + ln].decode()
            if clients is None:
                raise WireError("adopt: worker built without client registry")
            if plane == 1:
                target = clients["pool"]
            else:
                rpcs = clients["index"]
                if shard >= len(rpcs):
                    raise WireError(f"adopt: index shard {shard} out of range")
                target = rpcs[shard]
            new_ring = ShmRing.attach(ring_name, n_slots, payload_bytes)
            closeables.append(new_ring)
            db = Doorbell.attach(db_path) if db_path else None
            if db is not None:
                closeables.append(db)
            old_ring = target.ring
            target.adopt_ring(
                new_ring, liveness=_ring_liveness(target), doorbell=db
            )
            # the worker is single-threaded: no in-flight collect can hold
            # the retired mapping, so close it now instead of at teardown
            try:
                closeables.remove(old_ring)
            except ValueError:
                pass
            old_ring.close()
            return _U32.pack(1)
        if op == WCMD_SUBMIT:
            _, n, arrival, n_output, req_idx = _SUB_HDR.unpack_from(payload)
            tokens = np.frombuffer(
                payload, np.int32, n, _SUB_HDR.size
            ).tolist()
            req = Request(
                req_id=f"w{engine.engine_id}-{req_idx}",
                tokens=tokens, n_output=n_output, arrival=arrival,
            )
            reqs.append((req_idx, req))
            engine.submit(req, arrival)
            return _U32.pack(engine.n_queued)
        if op == WCMD_RUN:
            _, mode, until = _RUN.unpack_from(payload)
            if mode == 0:
                engine.drain()
            else:
                engine.advance(until)
            n_done = sum(1 for _, r in reqs if r.state == "done")
            return _RUN_RESP.pack(engine.clock, n_done)
        if op == WCMD_RESULTS:
            _, start, max_items = _RES_REQ.unpack_from(payload)
            page = reqs[start : start + max_items]
            out = [_U32.pack(len(reqs)), _U32.pack(len(page))]
            for idx, r in page:
                out.append(_RES_REC.pack(
                    idx, _opt(r.t_admitted), _opt(r.t_first_token),
                    _opt(r.t_done), r.tokens_out, r.hit_tokens,
                    _STATE_CODE[r.state],
                ))
            return b"".join(out)
        if op == WCMD_STATS:
            es, ms = engine.stats, engine.manager.stats
            ts = engine.manager.transfer.stats
            return _STATS_RESP.pack(
                engine.clock,
                es.prefills, es.decode_steps,
                es.busy_s, es.fetch_s, es.writeback_s,
                ms.prefix_hits_tokens, ms.prefix_miss_tokens, ms.fetches,
                ms.writebacks, ms.recompute_cutovers, ms.pool_evictions,
                ms.degraded_ops,
                ts.writes, ts.reads, ts.bytes_written, ts.bytes_read,
                ts.requests_issued,
                ts.modeled_write_s, ts.modeled_read_s,
            )
        raise WireError(f"unknown worker command {op}")

    return handler


def _engine_worker_main(spec: EngineWorkerSpec) -> None:
    """Worker entry: attach everything, serve the command ring until
    CTRL_STOP (the same arm/re-scan/park idle loop as ``_service_main``)."""
    cmd_ring = ShmRing.attach(spec.cmd_ring_name, spec.cmd_slots, spec.cmd_payload)
    engine, clients, closeables = _build_worker_stack(spec)
    reqs: list = []
    handler = _make_worker_handler(engine, reqs, clients, closeables)
    doorbell = None
    if spec.cmd_doorbell_name is not None:
        doorbell = Doorbell.attach(spec.cmd_doorbell_name)
        doorbell.open_read()
    cmd_ring.ctrl[CTRL_READY] = 1
    idle = 0
    try:
        while not cmd_ring.ctrl[CTRL_STOP]:
            if drain_ready(cmd_ring, handler):
                idle = 0
                continue
            idle += 1
            if idle < spec.idle_spin_passes:
                time.sleep(0)
            elif doorbell is None:
                time.sleep(spec.idle_backoff_s)
            else:
                cmd_ring.ctrl[CTRL_DOORBELL] = 1
                try:
                    if drain_ready(cmd_ring, handler):
                        idle = 0
                        continue
                    doorbell.wait(spec.doorbell_wait_s)
                finally:
                    cmd_ring.ctrl[CTRL_DOORBELL] = 0
    finally:
        handler = None  # noqa: F841 — drop ring views before close
        if doorbell is not None:
            doorbell.close()
        engine = None  # noqa: F841
        for c in closeables:
            try:
                c.close()
            except Exception:  # noqa: BLE001
                diag.note("engineproc.worker_teardown.close_failed")
        cmd_ring.close()


# ---------------------------------------------------------------------------
# parent side
# ---------------------------------------------------------------------------
class EngineWorkerHost:
    """Parent-side handle on one engine worker process.

    Owns the command ring + its doorbell (and unlinks both); the data
    segment, pool ring and metadata rings are owned elsewhere and only
    their NAMES are handed to the worker.  Mirrors the
    ``ProcessRpcServer`` lifecycle: in-band CTRL_STOP shutdown escalating
    to terminate/kill, idempotent ``close``, atexit hygiene hook.
    """

    def __init__(
        self,
        spec_kwargs: dict,
        *,
        cmd_slots: int = 8,
        cmd_payload: int = 1 << 16,
        use_doorbell: bool = True,
    ):
        self.ring = ShmRing.create_shared(cmd_slots, cmd_payload)
        self.doorbell = Doorbell.create() if use_doorbell else None
        self.spec = EngineWorkerSpec(
            cmd_ring_name=self.ring.shm_name,
            cmd_slots=cmd_slots,
            cmd_payload=cmd_payload,
            cmd_doorbell_name=(
                None if self.doorbell is None else self.doorbell.path
            ),
            **spec_kwargs,
        )
        self.engine_id = self.spec.engine_id
        self.client = CxlRpcClient(
            self.ring,
            liveness=self.alive,
            doorbell=(
                None if self.doorbell is None
                else Doorbell.attach(self.doorbell.path)
            ),
        )
        self.proc = _mp_context().Process(
            target=_engine_worker_main, args=(self.spec,), daemon=True
        )
        self.n_submitted = 0
        self.n_done = 0
        self.clock = 0.0
        self._closed = False
        atexit.register(self.close)

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "EngineWorkerHost":
        self.proc.start()
        return self

    @property
    def ready(self) -> bool:
        ctrl = self.ring.ctrl
        return ctrl is not None and bool(ctrl[CTRL_READY])

    def wait_ready(self, timeout: float = 20.0) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.ready:
                return True
            if self.proc.pid is not None and not self.proc.is_alive():
                return False
            time.sleep(1e-3)
        return self.ready

    def alive(self) -> bool:
        proc = self.proc
        return proc is not None and proc.is_alive()

    def kill(self) -> None:
        """Crash the worker ungracefully (hygiene/chaos hook)."""
        if self.proc is not None and self.proc.pid is not None:
            self.proc.kill()
            self.proc.join(timeout=5)

    def stop(self, timeout: float = 5.0) -> None:
        proc = self.proc
        if proc is None or proc.pid is None:
            return
        if proc.is_alive() and self.ring.ctrl is not None:
            self.ring.ctrl[CTRL_STOP] = 1
            if self.doorbell is not None:
                self.doorbell.ring()
            proc.join(timeout)
        if proc.is_alive():
            proc.terminate()
            proc.join(1.0)
        if proc.is_alive():
            proc.kill()
            proc.join(1.0)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self.stop()
        finally:
            self.ring.close()
            if self.doorbell is not None:
                self.doorbell.close()  # owner: unlinks the FIFO
            try:
                atexit.unregister(self.close)
            except Exception:  # noqa: BLE001
                diag.note("engineproc.host_close.unregister_failed")

    # -- commands --------------------------------------------------------
    def submit_indexed(self, req: Request, req_idx: int) -> None:
        """Ship one request to the worker; ``req_idx`` is the parent's
        global index (the worker echoes it back with the results)."""
        body = np.asarray(req.tokens, np.int32).tobytes()
        if _SUB_HDR.size + len(body) > self.spec.cmd_payload:
            raise WireError(
                f"prompt of {len(req.tokens)} tokens exceeds the "
                f"{self.spec.cmd_payload} B command slot"
            )
        req.engine_id = self.engine_id
        self.client.call(
            _SUB_HDR.pack(
                WCMD_SUBMIT, len(req.tokens), req.arrival,
                req.n_output, req_idx,
            ) + body
        )
        self.n_submitted += 1

    def submit(self, req: Request, now: float = 0.0) -> None:  # noqa: ARG002
        """Engine-shaped convenience (tests): parent index == local order."""
        self.submit_indexed(req, self.n_submitted)

    def load(self) -> float:
        """Scheduler signal between runs: requests not yet seen done."""
        return float(self.n_submitted - self.n_done)

    def post_run(self, until: float | None = None) -> int:
        """Post (don't wait): lets the parent start ALL workers' clocks
        before collecting any — the N drains run concurrently."""
        mode, horizon = (0, 0.0) if until is None else (1, until)
        return self.client.post(_RUN.pack(WCMD_RUN, mode, horizon))

    def collect_run(self, slot: int, timeout: float = 600.0) -> float:
        """Wait out a (long) drain WITHOUT busy-spinning the parent core:
        gentle 2 ms poll on the slot word, then the client's collect for
        the usual bookkeeping/error paths.  Returns the worker clock."""
        ring = self.client.ring
        deadline = time.perf_counter() + timeout
        while int(ring.status[slot]) not in (RESP_READY, RESP_ERROR):
            if not self.alive() or time.perf_counter() > deadline:
                break  # let collect() classify (died / timed out)
            time.sleep(2e-3)
        clock, n_done = _RUN_RESP.unpack(self.client.collect(slot, timeout))
        self.clock = clock
        self.n_done = n_done
        return clock

    def run(self, until: float | None = None, timeout: float = 600.0) -> float:
        return self.collect_run(self.post_run(until), timeout)

    def fetch_results(self) -> list[tuple]:
        """Page every request record back:
        [(req_idx, t_admitted, t_first, t_done, tokens_out, hit, state)]."""
        page = max(1, (self.spec.cmd_payload - 16) // _RES_REC.size)
        out: list[tuple] = []
        start = 0
        while True:
            resp = self.client.call(
                _RES_REQ.pack(WCMD_RESULTS, start, page)
            )
            (total,) = _U32.unpack_from(resp)
            (m,) = _U32.unpack_from(resp, 4)
            off = 8
            for _ in range(m):
                idx, ta, tf, td, tout, hit, st = _RES_REC.unpack_from(resp, off)
                out.append((
                    idx, _unopt(ta), _unopt(tf), _unopt(td), tout, hit,
                    _STATE_NAME[st],
                ))
                off += _RES_REC.size
            start += m
            if start >= total or m == 0:
                return out

    def apply_results(self, requests: list[Request]) -> None:
        """Fold the worker's timings back into the parent's own Request
        objects (matched by the echoed global index)."""
        for idx, ta, tf, td, tout, hit, state in self.fetch_results():
            r = requests[idx]
            r.t_admitted, r.t_first_token, r.t_done = ta, tf, td
            r.tokens_out, r.hit_tokens, r.state = tout, hit, state
            r.engine_id = self.engine_id

    def stats_dict(self) -> dict:
        v = _STATS_RESP.unpack(self.client.call(_STATS_REQ.pack(WCMD_STATS)))
        (clock, prefills, decode_steps, busy_s, fetch_s, writeback_s,
         hit_tok, miss_tok, fetches, writebacks, cutovers, evictions,
         degraded, t_writes, t_reads, t_bw, t_br, t_reqs,
         t_mw, t_mr) = v
        self.clock = clock
        return {
            "clock": clock,
            "engine": {
                "prefills": prefills, "decode_steps": decode_steps,
                "busy_s": busy_s, "fetch_s": fetch_s,
                "writeback_s": writeback_s,
            },
            "manager": {
                "prefix_hits_tokens": hit_tok,
                "prefix_miss_tokens": miss_tok,
                "fetches": fetches, "writebacks": writebacks,
                "recompute_cutovers": cutovers,
                "pool_evictions": evictions, "degraded_ops": degraded,
            },
            "transfer": {
                "writes": t_writes, "reads": t_reads,
                "bytes_written": t_bw, "bytes_read": t_br,
                "requests_issued": t_reqs,
                "modeled_write_s": t_mw, "modeled_read_s": t_mr,
            },
        }


# ---------------------------------------------------------------------------
# worker supervision (data-plane selfheal)
# ---------------------------------------------------------------------------
class EngineWorkerSupervisor:
    """Keep one engine worker alive across crashes.

    The same supervision loop as ``ShardSupervisor`` — probe thread +
    ``HeartbeatMonitor`` grace window, synchronous ``check()`` for tests —
    but healing a WORKER is more than a respawn: the worker's in-flight
    requests died with its interpreter.  The parent therefore keeps a
    request LEDGER (``_pending``: every submitted request not yet seen
    ``done`` by ``apply_results``) and replays it, in submit order, into
    the respawned worker.  The engines are deterministic virtual-time
    sims, so the replayed worker converges with a no-fault run on
    everything the data plane can observe (requests done, free blocks,
    index contents) — the differential chaos test pins exactly that.

    ``spec_factory`` rebuilds the worker's spec kwargs at respawn time so
    the new worker attaches the CURRENT ring generations (a metadata
    shard or the allocator may itself have been respawned while the
    worker was down).  ``on_worker_death(engine_id)`` runs after the old
    process is confirmed dead and before the new one starts — the
    cluster hooks pool-lease reconciliation here so the dead worker's
    retained blocks are released exactly once.
    """

    def __init__(
        self,
        spec_factory,
        *,
        cmd_slots: int = 8,
        cmd_payload: int = 1 << 16,
        use_doorbell: bool = True,
        probe_interval: float = 0.02,
        grace: float | None = None,
        max_restarts: int = 16,
        on_worker_death=None,
    ):
        self._spec_factory = spec_factory
        self._host_kwargs = dict(
            cmd_slots=cmd_slots, cmd_payload=cmd_payload,
            use_doorbell=use_doorbell,
        )
        self.probe_interval = probe_interval
        self.grace = 2 * probe_interval if grace is None else grace
        self.max_restarts = max_restarts
        self.on_worker_death = on_worker_death
        self.restarts = 0
        self.reconciled: list = []  # one reconcile summary per restart
        self.host = EngineWorkerHost(spec_factory(), **self._host_kwargs)
        self.engine_id = self.host.engine_id
        self._retired: list[EngineWorkerHost] = []
        self._pending: dict[int, Request] = {}
        self.clock = 0.0
        self._monitor = HeartbeatMonitor(n_hosts=1, timeout_s=self.grace)
        # blocking_ok: the supervisor lock's whole job is serializing the
        # blocking heal section (stop/join the dead worker, wait_ready
        # the successor, replay _pending) against check()/close(); the
        # submit/run data path only takes it when healing
        self._lock = make_lock(
            "engineproc.EngineWorkerSupervisor._lock", blocking_ok=True
        )
        self._halt = threading.Event()
        self._probe: threading.Thread | None = None
        self._closed = False
        atexit.register(self.close)

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "EngineWorkerSupervisor":
        self.host.start()
        self._monitor.beat(0)
        self._halt.clear()
        self._probe = threading.Thread(
            target=self._probe_loop, name="worker-supervisor", daemon=True
        )
        self._probe.start()
        return self

    def wait_ready(self, timeout: float = 20.0) -> bool:
        return self.host.wait_ready(timeout)

    def alive(self) -> bool:
        return self.host.alive()

    @property
    def spec(self) -> EngineWorkerSpec:
        return self.host.spec

    @property
    def client(self) -> CxlRpcClient:
        return self.host.client

    @property
    def n_submitted(self) -> int:
        return self.host.n_submitted

    @property
    def n_done(self) -> int:
        return self.host.n_done

    def kill(self) -> None:
        """Chaos hook: SIGKILL the current worker process."""
        self.host.kill()

    def stop(self) -> None:
        self.host.stop()

    def close(self) -> None:
        if self._closed:
            return
        with self._lock:
            self._closed = True
        self._halt.set()
        if self._probe is not None and self._probe.is_alive():
            self._probe.join(timeout=5)
        self.host.close()
        for h in self._retired:
            h.close()
        self._retired.clear()
        try:
            atexit.unregister(self.close)
        except Exception:  # noqa: BLE001
            diag.note("engineproc.supervisor_close.unregister_failed")

    # hygiene accounting spans every generation this supervisor created
    def segment_names(self) -> list[str]:
        return [h.ring.shm_name for h in (self.host, *self._retired)]

    def doorbell_paths(self) -> list[str]:
        return [
            h.doorbell.path
            for h in (self.host, *self._retired)
            if h.doorbell is not None
        ]

    # -- supervision -----------------------------------------------------
    def _probe_loop(self) -> None:
        while not self._halt.wait(self.probe_interval):
            with self._lock:
                if self._closed:
                    return
                if self.host.alive():
                    self._monitor.beat(0)
                elif self._monitor.dead_hosts():
                    self._restart_locked()
                    self._monitor.beat(0)

    def check(self) -> None:
        """Synchronous probe (tests drive healing deterministically)."""
        with self._lock:
            if self._closed:
                return
            if self.host.alive():
                self._monitor.beat(0)
            elif self._monitor.dead_hosts():
                self._restart_locked()
                self._monitor.beat(0)

    def _heal(self, seen_gen: int) -> None:
        """Op-failure-driven healing: the failed op IS the detection, so
        skip the grace window — but only if nobody else healed first."""
        with self._lock:
            if self._closed or self.restarts != seen_gen:
                return
            if not self.host.alive():
                self._restart_locked()
                self._monitor.beat(0)

    def _restart_locked(self) -> None:
        if self.restarts >= self.max_restarts:
            return
        old = self.host
        old.stop()
        # the killed worker never saw its stop word; flip it so anything
        # still holding the retired command ring fails fast
        if old.ring.ctrl is not None:
            old.ring.ctrl[CTRL_STOP] = 1
        self._retired.append(old)
        if self.on_worker_death is not None:
            try:
                self.reconciled.append(self.on_worker_death(self.engine_id))
            except Exception:  # noqa: BLE001
                diag.note("engineproc.reconcile_hook.failed")
                self.reconciled.append(None)  # best-effort: healing proceeds
        host = EngineWorkerHost(self._spec_factory(), **self._host_kwargs)
        host.start()
        self.host = host
        self.restarts += 1
        if not host.wait_ready(timeout=20.0):
            return
        try:
            for idx in sorted(self._pending):
                host.submit_indexed(self._pending[idx], idx)
        except (ServiceDiedError, TimeoutError):
            pass  # replay resumes on the next heal — _pending is intact

    # -- engine-shaped surface (heal-and-retry wrappers) -----------------
    def submit_indexed(self, req: Request, req_idx: int) -> None:
        # ledger FIRST: a crash mid-submit must still replay this request
        self._pending[req_idx] = req
        gen = self.restarts
        try:
            self.host.submit_indexed(req, req_idx)
        except (ServiceDiedError, TimeoutError):
            self._heal(gen)
            if self.restarts == gen:
                raise  # no heal happened (still alive, or restart cap)
            # else: the restart replayed _pending, this request included

    def submit(self, req: Request, now: float = 0.0) -> None:  # noqa: ARG002
        self.submit_indexed(req, self.host.n_submitted)

    def load(self) -> float:
        return float(len(self._pending))

    def post_run(self, until: float | None = None):
        gen = self.restarts
        try:
            slot = self.host.post_run(until)
        except (ServiceDiedError, TimeoutError, RuntimeError):
            self._heal(gen)
            slot = self.host.post_run(until)
        return (self.restarts, self.host, slot, until)

    def collect_run(self, token, timeout: float = 600.0) -> float:
        gen, host, slot, until = token
        try:
            clock = host.collect_run(slot, timeout)
        except (ServiceDiedError, TimeoutError, RuntimeError):
            # the worker died (or was already healed) under this drain —
            # heal, then RE-RUN on the current generation: its replayed
            # submits make the rerun cover everything the lost run did.
            # RuntimeError also covers an in-band RpcError from a LIVE
            # worker whose metadata ring died mid-drain; by the re-run
            # it has drained the queued WCMD_ADOPT onto the fresh ring.
            self._heal(gen)
            clock = self.host.run(until, timeout)
        self.clock = clock
        return clock

    def run(self, until: float | None = None, timeout: float = 600.0) -> float:
        return self.collect_run(self.post_run(until), timeout)

    def _with_heal(self, op, attempts: int = 3):
        last: Exception | None = None
        for _ in range(attempts):
            gen = self.restarts
            try:
                return op(self.host)
            except (ServiceDiedError, TimeoutError) as e:
                last = e
                self._heal(gen)
        raise last

    def fetch_results(self) -> list[tuple]:
        return self._with_heal(lambda h: h.fetch_results())

    def apply_results(self, requests: list[Request]) -> None:
        for idx, ta, tf, td, tout, hit, state in self.fetch_results():
            r = requests[idx]
            r.t_admitted, r.t_first_token, r.t_done = ta, tf, td
            r.tokens_out, r.hit_tokens, r.state = tout, hit, state
            r.engine_id = self.engine_id
            if state == "done":
                self._pending.pop(idx, None)  # acked: out of the ledger

    def stats_dict(self) -> dict:
        d = self._with_heal(lambda h: h.stats_dict())
        self.clock = d["clock"]
        return d


class _WorkerCutoverForwarder:
    """Ring-generation cutover INTO a worker process.

    Duck-typed like a registered RPC client: ``ShardSupervisor`` (and the
    allocator rolling restart) call ``adopt_ring(ring, ...)`` on every
    registered client after a respawn; this forwarder translates that
    into a ``WCMD_ADOPT`` on the worker's command ring so the client
    INSIDE the worker re-attaches the fresh segment itself.  Only names
    cross the boundary — the handed producer-side doorbell handle is
    closed here, the worker attaches its own.

    A dead/mid-restart worker is tolerated (errors swallowed): its
    respawn spec is built from the CURRENT ring names, so it boots
    already cut over.
    """

    def __init__(self, worker, plane: int, shard: int = 0,
                 timeout: float = 60.0):
        self.worker = worker  # EngineWorkerHost or EngineWorkerSupervisor
        self.plane = plane  # 0 = index shard, 1 = pool allocator
        self.shard = shard
        self.timeout = timeout

    def adopt_ring(self, ring, liveness=None, doorbell=None) -> None:  # noqa: ARG002
        db_path = ""
        if doorbell is not None:
            db_path = doorbell.path
            doorbell.close()
        msg = encode_adopt(
            self.plane, self.shard, ring.n_slots, ring.payload_bytes,
            ring.shm_name, db_path,
        )
        try:
            self.worker.client.call(msg, timeout=self.timeout)
        except Exception:  # noqa: BLE001
            # dead/mid-restart worker: its respawn spec already carries
            # the new ring names, so a lost ADOPT is recoverable — but
            # count it so a silently-failing cutover is visible
            diag.note("engineproc.cutover_forward.failed")

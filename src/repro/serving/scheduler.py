"""Cluster scheduler: cache-oblivious (Beluga §6.3) vs cache-aware (MoonCake).

The paper's §6.3 claim: with a pool at near-local latency, the scheduler can
ignore KV locality and pure load balancing wins — no skewed KV distribution,
no rebalancing on elastic scale in/out. The cache-aware baseline routes
requests toward the instance whose HBM already holds the prefix (locality
first, load second), which is what RDMA-latency systems are forced to do.

Both policies share the SAME pool + global index; elastic add/remove of
engines needs no KV migration in either mode (the pool is shared), which is
the serving-side fault-tolerance story.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.fabric import DEFAULT, DeviceQueues
from repro.core.index import GlobalIndex, ShardedIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.transfer import TransferEngine
from repro.kvcache.hbm_cache import HbmPagedCache
from repro.kvcache.manager import KVCacheManager
from repro.serving.engine import EngineInstance, SimRunner, SimRunnerConfig
from repro.serving.request import Request, summarize
from repro.tiering import MigrationEngine, TieredPool, TieringConfig


@dataclass
class ClusterConfig:
    n_engines: int = 16
    policy: str = "cache_oblivious"  # cache_oblivious | cache_aware | round_robin
    transfer_mode: str = "beluga"  # beluga | rdma | none (no offload)
    super_block_tokens: int = 0  # rdma batching granularity (LMCache: 256)
    pool_blocks: int = 65536
    pool_shards: int = 32
    interleave: bool = True
    # H20 (96 GB): 60 GB model -> ~28.3 GB usable KV (paper §7.1) at ~262
    # KB/token for Qwen3-32B = ~6750 16-token slots
    hbm_slots_per_engine: int = 6750
    block_tokens: int = 16
    straggler_cutover: float | None = None  # fetch-vs-recompute ratio
    # index-behind-RPC mode (paper deployment shape): engines reach the
    # centralized GlobalIndex over the CXL-RPC shared-memory ring with the
    # repro.core.wire binary codec — one batched round-trip per metadata
    # op — instead of calling it in-process. Off by default: the in-process
    # path is the bit-identical exp05 reference.
    index_rpc: bool = False
    index_rpc_slots: int = 64
    index_rpc_payload: int = 1 << 16
    # ring transport, orthogonal to index_shards (index_rpc mode only):
    #   "thread"  — rings are private arrays served by poll THREADS in
    #               this interpreter (PR-3/PR-4 shape; wall throughput is
    #               GIL-capped, virtual-time stats are the reference);
    #   "process" — every shard's ring lives in a NAMED shared-memory
    #               segment served by its own OS PROCESS
    #               (repro.core.procserver): the paper's deployment —
    #               the metadata service owns its cores, the pool's
    #               epoch/refcount state is shared load/store memory,
    #               and nothing but framed bytes crosses the boundary.
    index_transport: str = "thread"
    # self-healing metadata plane (process transport only): every shard
    # service runs under a ShardSupervisor — crash detection via
    # HeartbeatMonitor, respawn on a fresh ring, GlobalIndex rebuilt from
    # the per-shard publish journal — while clients retry with bounded
    # backoff and the manager degrades (recompute instead of raise) for
    # the duration of an outage.  With engine_processes > 0 the DATA
    # plane heals too: workers run under EngineWorkerSupervisors (lease
    # reconciliation + un-acked submit replay on respawn) and shard /
    # allocator ring-generation cutovers reach into the workers over the
    # command ring (WCMD_ADOPT).
    selfheal: bool = False
    journal_capacity: int = 8192  # records per shard journal
    supervisor_probe_interval: float = 0.02  # crash-detection cadence (s)
    # warm-snapshot cadence (selfheal only): every interval the
    # supervisor pages the live shard's LRU-ordered entries + hit/miss
    # counters; a respawned shard restores recency and counters instead
    # of falling back to journal insertion order. None = journal-only.
    snapshot_interval: float | None = None
    # service-child idle backoff (decoupled from the probe interval —
    # restart detection latency is bounded by the supervisor alone)
    service_idle_spin: int = 200  # empty ring passes before any sleep
    service_idle_backoff: float = 100e-6  # sleep ceiling once cold
    # metadata-plane sharding (paper §6: the metadata service scales
    # horizontally): keys partition by digest across S independent
    # GlobalIndex shards; in index_rpc mode each shard gets its OWN
    # ShmRing + service thread and clients keep the S sub-requests of an
    # op outstanding in parallel. 1 (default) = today's single metadata
    # plane, bit-identical to the unsharded path.
    index_shards: int = 1
    # zero-copy data plane (ROADMAP #2):
    #   "private" — block payloads live in this interpreter's arrays (the
    #               bit-identical reference path);
    #   "shared"  — BelugaPool.share_data() re-homes the payload array
    #               into one named shared-memory segment, so OTHER OS
    #               processes scatter/gather KV blocks by native
    #               load/store (requires backing="numpy").
    data_plane: str = "private"
    # engine WORKER processes (one per modeled GPU, data_plane="shared" +
    # process transport only): each worker hosts the full serving stack
    # against the shared segment; allocate/retain/release and index ops
    # cross slot-partitioned rings. 0 = engines stay in-process.
    engine_processes: int = 0
    # Doorbell (FIFO) wakeups for idle metadata services, the pool
    # allocator service and engine workers: an empty ring parks its
    # consumer instead of spin/backoff. False restores the pure
    # service_idle_spin/service_idle_backoff fallback.
    service_doorbell: bool = True
    runner: SimRunnerConfig = field(default_factory=SimRunnerConfig)
    # tiered pool memory (Exp #13): disabled -> flat BelugaPool, the exact
    # PR-1 code path; enabled -> pool_blocks become the FAST tier and a
    # spill tier (+ background migration engine) sits below it
    tiering: TieringConfig = field(default_factory=TieringConfig)


class Cluster:
    def __init__(self, cfg: ClusterConfig, layout: PoolLayout, backing: str = "meta"):
        self.cfg = cfg
        # pre-seed every field close() touches so a constructor failure
        # can still tear down cleanly (lifecycle hygiene: a half-built
        # process-mode cluster must not leak service processes or
        # /dev/shm segments)
        self._rpc_servers = []
        self._rpc_clients = []
        self._supervisors = []
        self._shm_names: list[str] = []
        self.workers = []  # EngineWorkerHost list (engine_processes mode)
        self._pool_server = None  # allocator service thread (worker mode)
        self._pool_ring = None
        self._pool_doorbell = None
        self._lease_ledger = None  # per-worker retained-block ledger
        self._parent_index = None  # parent-side index view (worker mode)
        self._meta_lock = None  # serializes parent index-client use
        self.allocator_restarts = 0
        self.index = None
        self.migrator = None
        self.engines: list[EngineInstance] = []
        self.requests: list[Request] = []
        self._rr = 0
        try:
            self._build(cfg, layout, backing)
        except BaseException:
            self.close()
            raise

    def _build(self, cfg: ClusterConfig, layout: PoolLayout, backing: str):
        tcfg = cfg.tiering
        if cfg.index_transport not in ("thread", "process"):
            raise ValueError(
                f"index_transport must be 'thread' or 'process', "
                f"got {cfg.index_transport!r}"
            )
        process_mode = cfg.index_rpc and cfg.index_transport == "process"
        if cfg.index_transport == "process" and not cfg.index_rpc:
            raise ValueError("index_transport='process' requires index_rpc=True")
        if cfg.data_plane not in ("private", "shared"):
            raise ValueError(
                f"data_plane must be 'private' or 'shared', "
                f"got {cfg.data_plane!r}"
            )
        if cfg.data_plane == "shared" and backing != "numpy":
            raise ValueError(
                "data_plane='shared' requires backing='numpy' "
                "(payload bytes must exist to be shared)"
            )
        if cfg.engine_processes:
            if cfg.data_plane != "shared":
                raise ValueError(
                    "engine_processes requires data_plane='shared'"
                )
            if not process_mode:
                raise ValueError(
                    "engine_processes requires index_rpc=True and "
                    "index_transport='process'"
                )
            if cfg.engine_processes != cfg.n_engines:
                raise ValueError(
                    "engine_processes must equal n_engines "
                    "(one worker per modeled GPU)"
                )
            if cfg.policy != "round_robin":
                raise NotImplementedError(
                    "engine workers support policy='round_robin' only "
                    "(load/clock live inside the worker processes)"
                )
        if tcfg.enabled:
            spill = tcfg.spill_blocks or 4 * cfg.pool_blocks
            spill = -(-spill // cfg.pool_shards) * cfg.pool_shards
            self.pool = TieredPool(
                layout,
                fast_blocks=cfg.pool_blocks,
                spill_blocks=spill,
                n_shards=cfg.pool_shards,
                interleave=cfg.interleave,
                backing=backing,
                cfg=tcfg,
            )
            # process transport: the shard services build their indexes
            # from the TieredPool's concatenated metadata segment (the
            # spec shape is identical to a flat pool's) — same rule as
            # the flat branch below, no in-process index exists at all
            self.index = None if process_mode else self._make_index()
            if self.index is not None:
                # destroyed keys arm the ghost-LRU admission filter (on
                # EVERY metadata shard: ring-served evictions run against
                # the shard objects, so the hook fires for them too).
                # In process transport the keys instead ride the eviction
                # REPLIES and each client view arms the filter
                # (``_index_view``).
                self.index.on_evict = self.pool.policy.ghost_add
            self.queues = (
                DeviceQueues(n_devices=DEFAULT.n_devices)
                if tcfg.model_contention
                else None
            )
        else:
            self.pool = BelugaPool(
                layout,
                n_blocks=cfg.pool_blocks,
                n_shards=cfg.pool_shards,
                interleave=cfg.interleave,
                backing=backing,
            )
            # process transport: no in-process index exists AT ALL — each
            # shard's GlobalIndex is constructed inside its own service
            # process (building one here would be pure startup waste)
            self.index = None if process_mode else self._make_index()
            self.queues = None
        if process_mode:
            # the metadata plane leaves this interpreter: pool metadata
            # becomes named shared memory, and each shard's GlobalIndex
            # is CONSTRUCTED inside its own service process from a plain
            # spec — no index object exists here at all (stats and the
            # eviction-pressure signal come back over the wire)
            from repro.core.index import PrefixHasher
            from repro.core.procserver import ProcessRpcServer, ShardSupervisor
            from repro.core.rpc import CxlRpcClient

            self.hasher = PrefixHasher(self.pool.layout.block_tokens)
            pool_spec = self.pool.share_meta()
            self._shm_names.append(pool_spec["shm_name"])
            # with engine workers, every shard ring is SHARED by N+1
            # client processes: the parent keeps partition 0, worker i
            # takes partition i+1 (disjoint slot free lists, one ring)
            parent_range = None
            if cfg.engine_processes:
                from repro.serving.engineproc import partition_slots

                parent_range = partition_slots(
                    cfg.index_rpc_slots, cfg.engine_processes + 1
                )[0]
            for _ in range(cfg.index_shards):
                if cfg.selfheal:
                    sup = ShardSupervisor(
                        pool_spec,
                        journal_capacity=cfg.journal_capacity,
                        probe_interval=cfg.supervisor_probe_interval,
                        snapshot_interval=cfg.snapshot_interval,
                        n_slots=cfg.index_rpc_slots,
                        payload_bytes=cfg.index_rpc_payload,
                        idle_spin_passes=cfg.service_idle_spin,
                        idle_backoff_s=cfg.service_idle_backoff,
                        use_doorbell=cfg.service_doorbell,
                    ).start()
                    self._supervisors.append(sup)
                    client = CxlRpcClient(
                        sup.ring, liveness=sup.server.alive,
                        doorbell=sup.client_doorbell(),
                        slot_range=parent_range,
                    )
                    sup.register_client(client)
                    self._rpc_clients.append(client)
                else:
                    srv = ProcessRpcServer(
                        pool_spec,
                        n_slots=cfg.index_rpc_slots,
                        payload_bytes=cfg.index_rpc_payload,
                        idle_spin_passes=cfg.service_idle_spin,
                        idle_backoff_s=cfg.service_idle_backoff,
                        use_doorbell=cfg.service_doorbell,
                    ).start()
                    self._rpc_servers.append(srv)
                    self._shm_names.append(srv.ring.shm_name)
                    self._rpc_clients.append(
                        CxlRpcClient(
                            srv.ring, liveness=srv.alive,
                            doorbell=srv.client_doorbell(),
                            slot_range=parent_range,
                        )
                    )
        elif cfg.index_rpc:
            from repro.core.rpc import CxlRpcClient, CxlRpcServer, ShmRing
            from repro.core.wire import make_index_handler

            self.hasher = self.index.hasher
            # one ring + one metadata service thread PER SHARD
            shards = (
                self.index.shards if cfg.index_shards > 1 else [self.index]
            )
            for shard in shards:
                ring = ShmRing(
                    n_slots=cfg.index_rpc_slots,
                    payload_bytes=cfg.index_rpc_payload,
                )
                self._rpc_servers.append(
                    CxlRpcServer(
                        ring,
                        make_index_handler(shard, max_reply=ring.payload_bytes),
                    ).start()
                )
                self._rpc_clients.append(CxlRpcClient(ring))
        else:
            self.hasher = self.index.hasher
        if tcfg.enabled:
            # in index_rpc mode the migrator's metadata ops (owners_of /
            # remap_many / evict_blocks) go over the ring like everything
            # else — the migration daemon no longer has to be co-located
            # with the index; only the payload copies touch the pool
            self.migrator = MigrationEngine(
                self.pool, self._index_view(), tcfg, queues=self.queues
            )
        else:
            self.migrator = None
        if cfg.data_plane == "shared":
            # re-home block payloads into one named segment; in-process
            # engines keep using the pool object (whose .data is now the
            # shared view) — bit-identical, which is what the parity
            # tests pin before any worker enters the picture
            data_spec = self.pool.share_data()
            self._shm_names.append(data_spec["data_shm_name"])
            if data_spec["meta"]["shm_name"] not in self._shm_names:
                self._shm_names.append(data_spec["meta"]["shm_name"])
        if cfg.engine_processes:
            self._build_workers(cfg, data_spec)
        else:
            for i in range(cfg.n_engines):
                self.engines.append(self._make_engine(i))

    def _make_pool_handler(self):
        """Allocator-ring handler; in selfheal mode it is lease- and
        journal-aware: pool traffic mirrors into the per-worker lease
        ledger (keyed by the posting slot's partition) and journal-proxy
        ops land in the parent-held shard journals."""
        from repro.core.wire import make_pool_handler

        cfg = self.cfg
        if not cfg.selfheal:
            return make_pool_handler(self.pool, max_reply=cfg.index_rpc_payload)
        parts = self._pool_parts

        def slot_owner(slot: int) -> int | None:
            for w, (lo, hi) in enumerate(parts):
                if lo <= slot < hi:
                    return w
            return None

        return make_pool_handler(
            self.pool, max_reply=cfg.index_rpc_payload,
            ledger=self._lease_ledger, slot_owner=slot_owner,
            journals=[s.journal for s in self._supervisors],
        )

    def _worker_spec_kwargs(self, i: int, data_spec: dict) -> dict:
        """Worker attach spec from the CURRENT ring generations — called
        at boot AND at every respawn (a metadata shard or the allocator
        may have moved to a fresh ring while the worker was down)."""
        cfg = self.cfg
        if self._supervisors:
            index_rings = tuple(s.ring.shm_name for s in self._supervisors)
            index_dbs = tuple(
                None if s.server.doorbell is None else s.server.doorbell.path
                for s in self._supervisors
            )
        else:
            index_rings = tuple(s.ring.shm_name for s in self._rpc_servers)
            index_dbs = tuple(
                None if s.doorbell is None else s.doorbell.path
                for s in self._rpc_servers
            )
        db = self._pool_doorbell
        retry = None
        if cfg.selfheal:
            from repro.core.rpc import RetryPolicy

            retry = RetryPolicy()
        return dict(
            engine_id=i,
            pool_spec=data_spec,
            pool_ring_name=self._pool_ring.shm_name,
            pool_slots=cfg.index_rpc_slots,
            pool_payload=cfg.index_rpc_payload,
            pool_doorbell_name=None if db is None else db.path,
            pool_slot_range=self._pool_parts[i],
            index_ring_names=index_rings,
            index_slots=cfg.index_rpc_slots,
            index_payload=cfg.index_rpc_payload,
            index_doorbell_names=index_dbs,
            index_slot_range=self._idx_parts[i + 1],
            hbm_slots=cfg.hbm_slots_per_engine,
            transfer_mode=cfg.transfer_mode,
            super_block_tokens=cfg.super_block_tokens,
            straggler_cutover=cfg.straggler_cutover,
            runner=cfg.runner,
            idle_spin_passes=cfg.service_idle_spin,
            idle_backoff_s=cfg.service_idle_backoff,
            selfheal=cfg.selfheal,
            retry=retry,
        )

    def _reconcile_worker_leases(self, engine_id: int) -> dict:
        """on_worker_death hook: release the dead worker's pool leases
        exactly once, under the epoch-validity rule (published blocks
        whose alloc-ref transferred to the index are kept)."""
        with self._meta_lock:
            return self._lease_ledger.reconcile(
                engine_id, self.pool,
                owners_of=self._parent_index.owners_of,
            )

    def _build_workers(self, cfg: ClusterConfig, data_spec: dict) -> None:
        """Boot the allocator service + one engine worker per modeled GPU.

        The allocator stays HERE (the pool-owning interpreter) behind its
        own ring: free-stack mutation keeps exactly one owner while the
        payload bytes live in the shared segment every worker maps.

        selfheal mode stacks three recovery layers on top:
          * each worker runs under an ``EngineWorkerSupervisor`` —
            crash detection, lease reconciliation (the parent-held
            ``WorkerLeaseLedger``), respawn on a fresh command ring and
            replay of the un-acked request ledger;
          * each metadata ``ShardSupervisor`` gets a cutover FORWARDER
            per worker, so a shard respawn ADOPTs the worker's in-process
            client onto the fresh ring (after the parent's own client);
          * ``restart_allocator()`` drills the allocator-outage path with
            the same forwarder machinery (plane 1)."""
        from repro.core.locks import make_lock
        from repro.core.rpc import CxlRpcServer, ShmRing
        from repro.core.shm import Doorbell
        from repro.serving.engineproc import (
            EngineWorkerHost,
            EngineWorkerSupervisor,
            _WorkerCutoverForwarder,
            partition_slots,
        )

        n = cfg.engine_processes
        self._idx_parts = partition_slots(cfg.index_rpc_slots, n + 1)
        self._pool_parts = partition_slots(cfg.index_rpc_slots, n)
        if cfg.selfheal:
            from repro.core.shmpool import WorkerLeaseLedger

            self._lease_ledger = WorkerLeaseLedger()
            # blocking_ok: serializes use of the one parent-side index
            # client (stats vs the reconcile owners_of probe), so RPC
            # round-trips under it are the point, not an accident
            self._meta_lock = make_lock(
                "scheduler.Cluster._meta_lock", blocking_ok=True
            )
        ring = ShmRing.create_shared(cfg.index_rpc_slots, cfg.index_rpc_payload)
        self._pool_ring = ring
        self._shm_names.append(ring.shm_name)
        db = Doorbell.create() if cfg.service_doorbell else None
        self._pool_doorbell = db
        self._pool_server = CxlRpcServer(
            ring,
            self._make_pool_handler(),
            doorbell=db,
            idle_spin_passes=cfg.service_idle_spin,
            idle_backoff_s=cfg.service_idle_backoff,
        ).start()
        if cfg.selfheal:
            # parent-side index view for the reconcile owners_of probe
            # (parent slot partition; shared with _index_stats, hence
            # the _meta_lock)
            self._parent_index = self._index_view()
        for i in range(n):
            if cfg.selfheal:
                worker = EngineWorkerSupervisor(
                    lambda i=i: self._worker_spec_kwargs(i, data_spec),
                    use_doorbell=cfg.service_doorbell,
                    probe_interval=cfg.supervisor_probe_interval,
                    on_worker_death=self._reconcile_worker_leases,
                ).start()
            else:
                worker = EngineWorkerHost(
                    self._worker_spec_kwargs(i, data_spec),
                    use_doorbell=cfg.service_doorbell,
                ).start()
                self._shm_names.append(worker.ring.shm_name)
            self.workers.append(worker)
        for worker in self.workers:
            if not worker.wait_ready(30):
                raise RuntimeError(
                    f"engine worker {worker.engine_id} failed to boot"
                )
        if cfg.selfheal:
            # shard-respawn cutover reaches INTO each worker: forwarders
            # translate adopt_ring into WCMD_ADOPT on the command ring.
            # Registered after the parent's own client so the parent is
            # already on the fresh ring when the workers cut over.
            for s, sup in enumerate(self._supervisors):
                for worker in self.workers:
                    sup.register_client(
                        _WorkerCutoverForwarder(worker, plane=0, shard=s)
                    )
        # scheduler surface: the hosts ARE the cluster's engines
        self.engines = self.workers

    def restart_allocator(self) -> None:
        """Allocator-outage recovery: rolling restart of the allocator
        ring.  A fresh ring + service boot FIRST, every worker ADOPTs
        onto it, then the old generation is stopped and retired — the
        pool's free-stack state never leaves this interpreter, so no
        rebuild is needed; only the transport moves."""
        from repro.core.rpc import CTRL_STOP, CxlRpcServer, ShmRing
        from repro.core.shm import Doorbell
        from repro.serving.engineproc import _WorkerCutoverForwarder

        cfg = self.cfg
        if self._pool_ring is None:
            raise RuntimeError("no allocator service to restart")
        ring = ShmRing.create_shared(cfg.index_rpc_slots, cfg.index_rpc_payload)
        self._shm_names.append(ring.shm_name)
        db = Doorbell.create() if cfg.service_doorbell else None
        server = CxlRpcServer(
            ring,
            self._make_pool_handler(),
            doorbell=db,
            idle_spin_passes=cfg.service_idle_spin,
            idle_backoff_s=cfg.service_idle_backoff,
        ).start()
        old_server, old_ring = self._pool_server, self._pool_ring
        old_db = self._pool_doorbell
        # publish the new generation before the cutover so any worker
        # respawn that races this restart attaches the fresh ring
        self._pool_server, self._pool_ring, self._pool_doorbell = (
            server, ring, db
        )
        for worker in self.workers:
            fwd = _WorkerCutoverForwarder(worker, plane=1)
            fwd.adopt_ring(
                ring,
                doorbell=None if db is None else Doorbell.attach(db.path),
            )
        self.allocator_restarts += 1
        if old_server is not None:
            old_server.stop()
        if old_ring.ctrl is not None:
            # any client that missed the cutover fails fast (CTRL_STOP
            # liveness) instead of timing out against a dead ring
            old_ring.ctrl[CTRL_STOP] = 1
        old_ring.close()  # owner: unlinks (attached views stay mapped)
        if old_db is not None:
            old_db.close()

    def _make_index(self):
        if self.cfg.index_shards > 1:
            return ShardedIndex(self.pool, self.cfg.index_shards)
        return GlobalIndex(self.pool)

    def _index_view(self):
        """The metadata plane as engines/migrator must reach it: the
        co-located object in-process, an RPC proxy in index_rpc mode.
        Hashing stays shared cluster-wide either way (one PrefixHasher).

        In PROCESS transport the service must not touch allocator state
        it doesn't own, so ring-served evictions defer the pool release:
        the proxy reclaims the freed ids here, in the pool-owning
        process (``on_freed``)."""
        if not self._rpc_clients:
            return self.index
        from repro.core.wire import RpcIndexClient, ShardedRpcIndexClient

        bt = self.pool.layout.block_tokens
        on_freed = self.pool.release if self.index is None else None
        # tiered + process transport: destroyed keys come back IN the
        # eviction replies; the parent-side views arm the ghost-LRU
        # admission filter from them.  With an in-process index the shard
        # objects' own on_evict hook already fired (set in _build), so
        # wiring the client too would double-count every key.
        on_evict = (
            self.pool.policy.ghost_add
            if self.index is None and self.cfg.tiering.enabled
            else None
        )
        retry = None
        journals = None
        if self._supervisors:
            from repro.core.rpc import RetryPolicy

            retry = RetryPolicy()
            journals = [s.journal for s in self._supervisors]
        if len(self._rpc_clients) > 1:
            return ShardedRpcIndexClient(
                self._rpc_clients, block_tokens=bt, hasher=self.hasher,
                on_freed=on_freed, on_evict=on_evict, journals=journals,
                retry=retry, degrade=bool(self._supervisors),
            )
        return RpcIndexClient(
            self._rpc_clients[0], block_tokens=bt, hasher=self.hasher,
            on_freed=on_freed, on_evict=on_evict,
            journal=journals[0] if journals else None, retry=retry,
        )

    def _index_stats(self) -> dict:
        """Index counters for ``run``: local object, or over the wire
        when the plane lives in service processes (same dict shape)."""
        if self.index is not None:
            return self.index.stats()
        if self._parent_index is not None:
            # worker+selfheal mode: one parent view, shared with the
            # lease-reconcile probe (which may run on a supervisor
            # thread) — serialize slot use
            with self._meta_lock:
                return self._parent_index.stats()
        return self._index_view().stats()

    def shm_segment_names(self) -> list[str]:
        """Named shared-memory segments this cluster currently owns
        (process transport; empty otherwise/after close) — the hygiene
        tests assert every one of them is unlinked on exit.  Supervised
        shards are queried live: restarts retire rings, and every
        generation's segment must still be unlinked at close."""
        names = list(self._shm_names)
        for sup in self._supervisors:
            names.extend(sup.segment_names())
        for w in self.workers:
            if hasattr(w, "segment_names"):  # supervised: every generation
                names.extend(w.segment_names())
        return names

    def doorbell_paths(self) -> list[str]:
        """Doorbell FIFO paths this cluster currently owns (hygiene
        tests assert each is unlinked on exit, like the segments)."""
        paths = []
        for srv in self._rpc_servers:
            db = getattr(srv, "doorbell", None)
            if db is not None:
                paths.append(db.path)
        for sup in self._supervisors:
            paths.extend(sup.doorbell_paths())
        if self._pool_doorbell is not None:
            paths.append(self._pool_doorbell.path)
        for w in self.workers:
            if hasattr(w, "doorbell_paths"):  # supervised: every generation
                paths.extend(w.doorbell_paths())
            elif w.doorbell is not None:
                paths.append(w.doorbell.path)
        return paths

    @property
    def _rpc_server(self):
        """First shard's server (compat probe; see ``_rpc_servers``)."""
        return self._rpc_servers[0] if self._rpc_servers else None

    @property
    def _rpc_client(self):
        """First shard's transport (compat probe; see ``_rpc_clients``)."""
        return self._rpc_clients[0] if self._rpc_clients else None

    def close(self) -> None:
        """Release the metadata plane (idempotent; safe half-built).

        Thread transport: stop the busy-spinning poll threads (daemon,
        die with the process, but left running they skew any in-process
        measurement that follows).  Process transport: stop every service
        process AND unlink every named shared-memory segment (rings +
        pool metadata) — on normal exit, on ``with`` scope exit, and on
        an exception thrown mid-construction alike; nothing may survive
        in /dev/shm."""
        # workers go FIRST: they hold attachments to every other plane
        # (data segment, pool ring, metadata rings) and may have RPCs in
        # flight against the services stopped below
        for w in self.workers:
            w.close()  # stop worker, unlink its cmd ring + doorbell
        self.workers = []
        if self._pool_server is not None:
            self._pool_server.stop()
            self._pool_server = None
        if self._pool_ring is not None:
            self._pool_ring.close()  # owner: unlinks the allocator ring
            self._pool_ring = None
        if self._pool_doorbell is not None:
            self._pool_doorbell.close()  # owner: unlinks the FIFO
            self._pool_doorbell = None
        for server in self._rpc_servers:
            server.close()  # thread: stop; process: stop + unlink ring
        self._rpc_servers = []
        for sup in self._supervisors:
            sup.close()  # stop probe, all ring generations + journal
        self._supervisors = []
        # clients stay: their RpcStats remain inspectable post-close
        pool = getattr(self, "pool", None)
        if pool is not None and hasattr(pool, "unshare_data"):
            pool.unshare_data()  # copies payloads back, unlinks segment
        if pool is not None and hasattr(pool, "unshare_meta"):
            pool.unshare_meta()
        self._shm_names = []

    def __enter__(self) -> "Cluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _make_engine(self, engine_id: int) -> EngineInstance:
        cfg = self.cfg
        transfer = TransferEngine(
            self.pool,
            mode="beluga" if cfg.transfer_mode == "none" else cfg.transfer_mode,
            super_block_tokens=cfg.super_block_tokens,
        )
        hbm = HbmPagedCache(cfg.hbm_slots_per_engine, cfg.block_tokens)
        # engine-side proxy in index_rpc mode: hashing stays local,
        # metadata ops cross the ring(s) as batched binary messages (the
        # cluster's stats keep the co-located index object). One hasher is
        # shared by all proxies so a request is chain-hashed once per
        # cluster, not once per engine's routing probe.
        engine_index = self._index_view()
        mgr = KVCacheManager(
            self.pool, engine_index, hbm, transfer,
            recompute_cutover=cfg.straggler_cutover,
            prefill_tok_per_s=cfg.runner.prefill_tok_per_s,
            queues=self.queues,
            degraded_ok=bool(self._supervisors),
        )
        if cfg.transfer_mode == "none":
            # no pool offload: disable prefix reuse entirely
            mgr.plan_fetch_orig = mgr.plan_fetch
            mgr.plan_fetch = _no_offload_plan(mgr)
            mgr.writeback = lambda *a, **k: 0
        return EngineInstance(
            engine_id, mgr, SimRunner(cfg.runner), migrator=self.migrator
        )

    # ------------------------------------------------------------------
    def _select_engine(self, req: Request) -> EngineInstance:
        """Routing policy only — no bookkeeping (shared by dispatch and
        the orphan re-dispatch path, which must not re-append)."""
        policy = self.cfg.policy
        if policy == "round_robin":
            eng = self.engines[self._rr % len(self.engines)]
            self._rr += 1
        elif policy == "cache_oblivious":
            eng = min(self.engines, key=lambda e: (e.load(), e.clock))
        elif policy == "cache_aware":
            local = [e for e in self.engines if e.has_prefix_locally(req)]
            if local:
                eng = min(local, key=lambda e: (e.load(), e.clock))
            else:
                eng = min(self.engines, key=lambda e: (e.load(), e.clock))
        else:
            raise ValueError(policy)
        return eng

    def dispatch(self, req: Request) -> EngineInstance:
        eng = self._select_engine(req)
        if self.workers:
            # workers need the parent's GLOBAL request index echoed back
            # with the results (the worker builds its own Request copy)
            eng.submit_indexed(req, len(self.requests))
        else:
            eng.submit(req, req.arrival)
        self.requests.append(req)
        return eng

    # ------------------------------------------------------------------
    def run(self, until: float | None = None) -> dict:
        if self.workers:
            # post the clock command to EVERY worker before collecting
            # any reply: the N drains run concurrently, each against the
            # one shared segment
            slots = [w.post_run(until) for w in self.workers]
            clocks = [
                w.collect_run(s) for w, s in zip(self.workers, slots)
            ]
            end = until if until is not None else max(clocks, default=0.0)
            for w in self.workers:
                w.apply_results(self.requests)
            if self.migrator is not None:
                # the migration daemon stays in the pool-owning parent
                # (the workers only signal demand over the ring); drive
                # it to the round's end between worker rounds
                self.migrator.run_until(end)
        elif until is None:
            end = max(e.drain() for e in self.engines)
        else:
            for e in self.engines:
                e.advance(until)
            end = until
        start = min((r.arrival for r in self.requests), default=0.0)
        stats = summarize(self.requests, end - start)
        stats["index"] = self._index_stats()
        stats["pool_free"] = self.pool.free_blocks()
        stats["shard_occupancy_max"] = max(self.pool.shard_occupancy() or [0])
        if self._supervisors:
            if self.workers:
                # the managers live inside the worker processes: page
                # their counters back over the command ring
                mgr_degraded = sum(
                    w.stats_dict()["manager"]["degraded_ops"]
                    for w in self.workers
                )
            else:
                mgr_degraded = sum(
                    e.manager.stats.degraded_ops for e in self.engines
                )
            stats["selfheal"] = {
                "restarts": sum(s.restarts for s in self._supervisors),
                "rpc_retries": sum(
                    c.stats.retries for c in self._rpc_clients
                ),
                "rpc_degraded_ops": sum(
                    c.stats.degraded_ops for c in self._rpc_clients
                ),
                "manager_degraded_ops": mgr_degraded,
            }
            if self.workers:
                stats["selfheal"]["worker_restarts"] = sum(
                    getattr(w, "restarts", 0) for w in self.workers
                )
                stats["selfheal"]["allocator_restarts"] = (
                    self.allocator_restarts
                )
                stats["selfheal"]["leases_released"] = sum(
                    r["released"]
                    for w in self.workers
                    for r in getattr(w, "reconciled", [])
                    if r is not None
                )
        if self.migrator is not None:
            stats["tiering"] = self.pool.stats_dict()
            stats["tiering"]["migrator_steps"] = self.migrator.steps
        return stats

    # ------------------------------------------------------------------
    # Elastic scaling (serving-side fault tolerance): engines join/leave
    # with NO KV rebalancing — the pool is shared (paper §6.3).
    # ------------------------------------------------------------------
    def remove_engine(self, engine_id: int) -> list[Request]:
        """Simulate an instance failure: requeue its in-flight requests.

        Each of the k orphans is routed and resubmitted exactly once —
        O(k) dispatches, with no duplicate append + O(n)
        ``requests.remove`` scan — and ``self.requests`` keeps its
        original order."""
        if self.workers:
            raise NotImplementedError(
                "elastic scaling with engine worker processes (ROADMAP)"
            )
        eng = self.engines[engine_id]
        orphans = list(eng.waiting) + list(eng.running)
        for r in orphans:
            r.state = "queued"
            r.t_admitted = r.t_first_token = None
            r.tokens_out = 0
        self.engines.pop(engine_id)
        for i, e in enumerate(self.engines):
            e.engine_id = i
        for r in orphans:
            self._select_engine(r).submit(r, r.arrival)
        return orphans

    def add_engine(self) -> EngineInstance:
        if self.workers:
            raise NotImplementedError(
                "elastic scaling with engine worker processes (ROADMAP)"
            )
        eng = self._make_engine(len(self.engines))
        eng.clock = max((e.clock for e in self.engines), default=0.0)
        self.engines.append(eng)
        return eng


def _no_offload_plan(mgr):
    from repro.kvcache.manager import FetchPlan

    def plan(tokens, now=0.0):
        return FetchPlan(0, len(tokens), [], 0.0, False)

    return plan

"""Cluster scheduler: cache-oblivious (Beluga §6.3) vs cache-aware (MoonCake).

The paper's §6.3 claim: with a pool at near-local latency, the scheduler can
ignore KV locality and pure load balancing wins — no skewed KV distribution,
no rebalancing on elastic scale in/out. The cache-aware baseline routes
requests toward the instance whose HBM already holds the prefix (locality
first, load second), which is what RDMA-latency systems are forced to do.

Both policies share the SAME pool + global index; elastic add/remove of
engines needs no KV migration in either mode (the pool is shared), which is
the serving-side fault-tolerance story.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.fabric import DEFAULT, DeviceQueues
from repro.core.index import GlobalIndex, ShardedIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.transfer import TransferEngine
from repro.kvcache.hbm_cache import HbmPagedCache
from repro.kvcache.manager import KVCacheManager
from repro.serving.engine import EngineInstance, SimRunner, SimRunnerConfig
from repro.serving.request import Request, summarize
from repro.tiering import MigrationEngine, TieredPool, TieringConfig


@dataclass
class ClusterConfig:
    n_engines: int = 16
    policy: str = "cache_oblivious"  # cache_oblivious | cache_aware | round_robin
    transfer_mode: str = "beluga"  # beluga | rdma | none (no offload)
    super_block_tokens: int = 0  # rdma batching granularity (LMCache: 256)
    pool_blocks: int = 65536
    pool_shards: int = 32
    interleave: bool = True
    # H20 (96 GB): 60 GB model -> ~28.3 GB usable KV (paper §7.1) at ~262
    # KB/token for Qwen3-32B = ~6750 16-token slots
    hbm_slots_per_engine: int = 6750
    block_tokens: int = 16
    straggler_cutover: float | None = None  # fetch-vs-recompute ratio
    # index-behind-RPC mode (paper deployment shape): engines reach the
    # centralized GlobalIndex over the CXL-RPC shared-memory ring with the
    # repro.core.wire binary codec — one batched round-trip per metadata
    # op — instead of calling it in-process. Off by default: the in-process
    # path is the bit-identical exp05 reference.
    index_rpc: bool = False
    index_rpc_slots: int = 64
    index_rpc_payload: int = 1 << 16
    # metadata-plane sharding (paper §6: the metadata service scales
    # horizontally): keys partition by digest across S independent
    # GlobalIndex shards; in index_rpc mode each shard gets its OWN
    # ShmRing + service thread and clients keep the S sub-requests of an
    # op outstanding in parallel. 1 (default) = today's single metadata
    # plane, bit-identical to the unsharded path.
    index_shards: int = 1
    runner: SimRunnerConfig = field(default_factory=SimRunnerConfig)
    # tiered pool memory (Exp #13): disabled -> flat BelugaPool, the exact
    # PR-1 code path; enabled -> pool_blocks become the FAST tier and a
    # spill tier (+ background migration engine) sits below it
    tiering: TieringConfig = field(default_factory=TieringConfig)


class Cluster:
    def __init__(self, cfg: ClusterConfig, layout: PoolLayout, backing: str = "meta"):
        self.cfg = cfg
        tcfg = cfg.tiering
        if tcfg.enabled:
            spill = tcfg.spill_blocks or 4 * cfg.pool_blocks
            spill = -(-spill // cfg.pool_shards) * cfg.pool_shards
            self.pool = TieredPool(
                layout,
                fast_blocks=cfg.pool_blocks,
                spill_blocks=spill,
                n_shards=cfg.pool_shards,
                interleave=cfg.interleave,
                backing=backing,
                cfg=tcfg,
            )
            self.index = self._make_index()
            # destroyed keys arm the ghost-LRU admission filter (on EVERY
            # metadata shard: ring-served evictions run against the shard
            # objects, so the hook fires for them too)
            self.index.on_evict = self.pool.policy.ghost_add
            self.queues = (
                DeviceQueues(n_devices=DEFAULT.n_devices)
                if tcfg.model_contention
                else None
            )
        else:
            self.pool = BelugaPool(
                layout,
                n_blocks=cfg.pool_blocks,
                n_shards=cfg.pool_shards,
                interleave=cfg.interleave,
                backing=backing,
            )
            self.index = self._make_index()
            self.queues = None
        self._rpc_servers = []
        self._rpc_clients = []
        if cfg.index_rpc:
            from repro.core.rpc import CxlRpcClient, CxlRpcServer, ShmRing
            from repro.core.wire import make_index_handler

            # one ring + one metadata service thread PER SHARD
            shards = (
                self.index.shards if cfg.index_shards > 1 else [self.index]
            )
            for shard in shards:
                ring = ShmRing(
                    n_slots=cfg.index_rpc_slots,
                    payload_bytes=cfg.index_rpc_payload,
                )
                self._rpc_servers.append(
                    CxlRpcServer(
                        ring,
                        make_index_handler(shard, max_reply=ring.payload_bytes),
                    ).start()
                )
                self._rpc_clients.append(CxlRpcClient(ring))
        if tcfg.enabled:
            # in index_rpc mode the migrator's metadata ops (owners_of /
            # remap_many / evict_blocks) go over the ring like everything
            # else — the migration daemon no longer has to be co-located
            # with the index; only the payload copies touch the pool
            self.migrator = MigrationEngine(
                self.pool, self._index_view(), tcfg, queues=self.queues
            )
        else:
            self.migrator = None
        self.engines: list[EngineInstance] = []
        self._rr = 0
        for i in range(cfg.n_engines):
            self.engines.append(self._make_engine(i))
        self.requests: list[Request] = []

    def _make_index(self):
        if self.cfg.index_shards > 1:
            return ShardedIndex(self.pool, self.cfg.index_shards)
        return GlobalIndex(self.pool)

    def _index_view(self):
        """The metadata plane as engines/migrator must reach it: the
        co-located object in-process, an RPC proxy in index_rpc mode.
        Hashing stays shared cluster-wide either way (one PrefixHasher)."""
        if not self._rpc_clients:
            return self.index
        from repro.core.wire import RpcIndexClient, ShardedRpcIndexClient

        bt = self.pool.layout.block_tokens
        if len(self._rpc_clients) > 1:
            return ShardedRpcIndexClient(
                self._rpc_clients, block_tokens=bt, hasher=self.index.hasher
            )
        return RpcIndexClient(
            self._rpc_clients[0], block_tokens=bt, hasher=self.index.hasher
        )

    @property
    def _rpc_server(self):
        """First shard's server (compat probe; see ``_rpc_servers``)."""
        return self._rpc_servers[0] if self._rpc_servers else None

    @property
    def _rpc_client(self):
        """First shard's transport (compat probe; see ``_rpc_clients``)."""
        return self._rpc_clients[0] if self._rpc_clients else None

    def close(self) -> None:
        """Stop the metadata-service threads (index_rpc mode; no-op else).

        The poll threads busy-spin (daemon, die with the process), so an
        index_rpc cluster left open skews any in-process measurement that
        follows — use ``with Cluster(...) as c:`` to scope it."""
        for server in self._rpc_servers:
            server.stop()
        self._rpc_servers = []

    def __enter__(self) -> "Cluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _make_engine(self, engine_id: int) -> EngineInstance:
        cfg = self.cfg
        transfer = TransferEngine(
            self.pool,
            mode="beluga" if cfg.transfer_mode == "none" else cfg.transfer_mode,
            super_block_tokens=cfg.super_block_tokens,
        )
        hbm = HbmPagedCache(cfg.hbm_slots_per_engine, cfg.block_tokens)
        # engine-side proxy in index_rpc mode: hashing stays local,
        # metadata ops cross the ring(s) as batched binary messages (the
        # cluster's stats keep the co-located index object). One hasher is
        # shared by all proxies so a request is chain-hashed once per
        # cluster, not once per engine's routing probe.
        engine_index = self._index_view()
        mgr = KVCacheManager(
            self.pool, engine_index, hbm, transfer,
            recompute_cutover=cfg.straggler_cutover,
            prefill_tok_per_s=cfg.runner.prefill_tok_per_s,
            queues=self.queues,
        )
        if cfg.transfer_mode == "none":
            # no pool offload: disable prefix reuse entirely
            mgr.plan_fetch_orig = mgr.plan_fetch
            mgr.plan_fetch = _no_offload_plan(mgr)
            mgr.writeback = lambda *a, **k: 0
        return EngineInstance(
            engine_id, mgr, SimRunner(cfg.runner), migrator=self.migrator
        )

    # ------------------------------------------------------------------
    def _select_engine(self, req: Request) -> EngineInstance:
        """Routing policy only — no bookkeeping (shared by dispatch and
        the orphan re-dispatch path, which must not re-append)."""
        policy = self.cfg.policy
        if policy == "round_robin":
            eng = self.engines[self._rr % len(self.engines)]
            self._rr += 1
        elif policy == "cache_oblivious":
            eng = min(self.engines, key=lambda e: (e.load(), e.clock))
        elif policy == "cache_aware":
            local = [e for e in self.engines if e.has_prefix_locally(req)]
            if local:
                eng = min(local, key=lambda e: (e.load(), e.clock))
            else:
                eng = min(self.engines, key=lambda e: (e.load(), e.clock))
        else:
            raise ValueError(policy)
        return eng

    def dispatch(self, req: Request) -> EngineInstance:
        eng = self._select_engine(req)
        eng.submit(req, req.arrival)
        self.requests.append(req)
        return eng

    # ------------------------------------------------------------------
    def run(self, until: float | None = None) -> dict:
        if until is None:
            end = max(e.drain() for e in self.engines)
        else:
            for e in self.engines:
                e.advance(until)
            end = until
        start = min((r.arrival for r in self.requests), default=0.0)
        stats = summarize(self.requests, end - start)
        stats["index"] = self.index.stats()
        stats["pool_free"] = self.pool.free_blocks()
        stats["shard_occupancy_max"] = max(self.pool.shard_occupancy() or [0])
        if self.migrator is not None:
            stats["tiering"] = self.pool.stats_dict()
            stats["tiering"]["migrator_steps"] = self.migrator.steps
        return stats

    # ------------------------------------------------------------------
    # Elastic scaling (serving-side fault tolerance): engines join/leave
    # with NO KV rebalancing — the pool is shared (paper §6.3).
    # ------------------------------------------------------------------
    def remove_engine(self, engine_id: int) -> list[Request]:
        """Simulate an instance failure: requeue its in-flight requests.

        Each of the k orphans is routed and resubmitted exactly once —
        O(k) dispatches, with no duplicate append + O(n)
        ``requests.remove`` scan — and ``self.requests`` keeps its
        original order."""
        eng = self.engines[engine_id]
        orphans = list(eng.waiting) + list(eng.running)
        for r in orphans:
            r.state = "queued"
            r.t_admitted = r.t_first_token = None
            r.tokens_out = 0
        self.engines.pop(engine_id)
        for i, e in enumerate(self.engines):
            e.engine_id = i
        for r in orphans:
            self._select_engine(r).submit(r, r.arrival)
        return orphans

    def add_engine(self) -> EngineInstance:
        eng = self._make_engine(len(self.engines))
        eng.clock = max((e.clock for e in self.engines), default=0.0)
        self.engines.append(eng)
        return eng


def _no_offload_plan(mgr):
    from repro.kvcache.manager import FetchPlan

    def plan(tokens, now=0.0):
        return FetchPlan(0, len(tokens), [], 0.0, False)

    return plan

"""TieredPool: fast CXL tier + spill tier behind the BelugaPool API.

Composes two ``BelugaPool`` instances in one global block-id space:

    fast tier (CXL pool media)     ids [0, fast_blocks)
    spill tier (RDMA-DRAM / SSD)   ids [fast_blocks, fast_blocks + spill)

so ``TransferEngine``, ``GlobalIndex``, ``KVCacheManager`` and
``CoherentReader/Writer`` work unchanged — every operation dispatches by id
range and merges results in caller order.  The spill tier stores real
payloads through the same allocator/epoch machinery; only its *modeled*
latency differs (``fabric.spill_transfer_latency``).

Placement policy (write admission) lives here because allocation is where
a block's tier is decided:

  * below the high watermark every fresh block lands in the fast tier;
  * above it, fresh blocks go straight to spill — EXCEPT keys the
    ghost-LRU filter recognizes as recently-destroyed-and-returned, which
    are forced fast (admission filter vs cache pollution);
  * either tier overflows into the other before the pool reports OOM.

Background demotion/promotion between the tiers is the migrator's job
(``repro.tiering.migrator``); hotness bookkeeping is O(blocks touched).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.fabric import DEFAULT, FabricConstants
from repro.core.pool import BelugaPool, OutOfPoolMemory, PoolLayout
from repro.tiering.policy import HotnessTracker
from repro.tiering.stats import TierStats


@dataclass
class TieringConfig:
    """Knobs for the tiered pool (``ClusterConfig.tiering``)."""

    enabled: bool = False
    spill_blocks: int = 0  # 0 -> 4x the fast tier
    spill_media: str = "rdma_dram"  # rdma_dram | ssd
    high_watermark: float = 0.90  # demote when fast occupancy exceeds this
    demote_target: float = 0.75  # ... down to this occupancy
    migrate_interval_s: float = 0.05  # background engine step period
    migrate_batch_blocks: int = 64  # per-step migration budget
    half_life_s: float = 30.0  # hotness decay half-life (virtual s)
    promote_min_heat: float = 2.0  # spill block heat to earn promotion
    ghost_capacity: int = 8192  # admission-filter memory (keys)
    model_contention: bool = True  # migration contends via DeviceQueues


class _TierView:
    """Read-only per-block metadata view over both tiers (global ids).

    ``GlobalIndex`` pokes ``pool.refcounts[block_id]`` directly; this keeps
    that O(1) without materializing a concatenated copy per access.
    """

    __slots__ = ("_fast", "_spill", "_offset")

    def __init__(self, fast: np.ndarray, spill: np.ndarray, offset: int):
        self._fast = fast
        self._spill = spill
        self._offset = offset

    def __getitem__(self, i):
        if isinstance(i, (int, np.integer)):
            if i < self._offset:
                return self._fast[i]
            return self._spill[i - self._offset]
        ids = np.asarray(i, np.intp)
        out = np.empty(len(ids), self._fast.dtype)
        fm = ids < self._offset
        out[fm] = self._fast[ids[fm]]
        out[~fm] = self._spill[ids[~fm] - self._offset]
        return out

    def __len__(self):
        return len(self._fast) + len(self._spill)


class TieredPool:
    """Two-tier pool in one global block-id space (fast first)."""

    is_tiered = True

    def __init__(
        self,
        layout: PoolLayout,
        fast_blocks: int,
        spill_blocks: int,
        n_shards: int = 32,
        backing: str = "numpy",
        interleave: bool = True,
        cfg: TieringConfig | None = None,
        constants: FabricConstants = DEFAULT,
    ):
        self.layout = layout
        self.cfg = cfg or TieringConfig(enabled=True)
        self.constants = constants
        self.fast = BelugaPool(layout, fast_blocks, n_shards, backing, interleave)
        self.spill = BelugaPool(layout, spill_blocks, n_shards, backing, interleave)
        self.offset = fast_blocks
        self.n_blocks = fast_blocks + spill_blocks
        self.n_shards = n_shards
        self.interleave = interleave
        self.backing = backing
        self.spill_media = self.cfg.spill_media
        self.policy = HotnessTracker(
            self.n_blocks,
            half_life_s=self.cfg.half_life_s,
            ghost_capacity=self.cfg.ghost_capacity,
        )
        self.tier_stats = TierStats()
        self.now = 0.0  # virtual time high-water mark (hotness decay clock)
        # spill blocks whose heat crossed the promotion threshold (fed by
        # touch_demand, drained by the migrator): keeps promotion O(blocks
        # touched) instead of an every-step O(spill) sweep
        self.promote_pending: set[int] = set()
        self.refcounts = _TierView(self.fast.refcounts, self.spill.refcounts, fast_blocks)
        self.epochs = _TierView(self.fast.epochs, self.spill.epochs, fast_blocks)
        self.committed = _TierView(self.fast.committed, self.spill.committed, fast_blocks)

    # ------------------------------------------------------------------
    @property
    def data(self):
        """Backing-kind probe only (``pool.data is None`` == meta); block
        payloads must go through read/write methods, which dispatch."""
        return self.fast.data

    @property
    def alloc_count(self) -> int:
        return self.fast.alloc_count + self.spill.alloc_count

    def tier_of(self, block_id: int) -> int:
        return 0 if block_id < self.offset else 1

    def tick(self, now: float) -> None:
        self.now = max(self.now, now)

    def free_blocks(self) -> int:
        return self.fast.free_blocks() + self.spill.free_blocks()

    def shard_occupancy(self) -> list[int]:
        return self.fast.shard_occupancy() + self.spill.shard_occupancy()

    def fast_occupancy(self) -> float:
        return (self.fast.n_blocks - self.fast.free_blocks()) / self.fast.n_blocks

    def _split(self, block_ids) -> tuple[np.ndarray, np.ndarray]:
        ids = np.asarray(block_ids, np.intp)
        return ids, ids < self.offset

    # ------------------------------------------------------------------
    # Allocation (write admission)
    # ------------------------------------------------------------------
    def allocate(self, n: int, keys: list[bytes] | None = None) -> list[int]:
        """Allocate n blocks, choosing each block's tier.

        ``keys`` (optional, from the writeback path) feeds the ghost-LRU
        admission filter; without keys the policy is purely watermark-based.
        """
        fast_free = self.fast.free_blocks()
        spill_free = self.spill.free_blocks()
        if fast_free + spill_free < n:
            raise OutOfPoolMemory(
                f"need {n}, have {fast_free} fast + {spill_free} spill"
            )
        pressured = self.fast_occupancy() >= self.cfg.high_watermark
        ghost_hot = [False] * n
        if keys is not None and pressured:
            # peek only: the entry is consumed below, and only for blocks
            # the capacity clamp actually lets into the fast tier — a
            # returning key must not lose its one-shot admission to a
            # full fast tier it never reached
            ghost_hot = [self.policy.ghost_contains(k) for k in keys]
        # tier per position: fast unless pressured (ghost-hot always fast)
        want_fast = [(not pressured) or ghost_hot[i] for i in range(n)]
        n_fast = sum(want_fast)
        # clamp to capacity, overflowing into the other tier (non-ghost
        # fast-wishers yield their fast slot before ghost-hot ones do)
        if n_fast > fast_free:
            flip = n_fast - fast_free
            for only_ghost in (False, True):
                for i in range(n - 1, -1, -1):
                    if not flip:
                        break
                    if want_fast[i] and ghost_hot[i] == only_ghost:
                        want_fast[i] = False
                        flip -= 1
            n_fast = fast_free
        n_spill = n - n_fast
        if n_spill > spill_free:
            flip = n_spill - spill_free  # overflow back into fast
            for i in range(n):
                if not flip:
                    break
                if not want_fast[i]:
                    want_fast[i] = True
                    flip -= 1
            n_fast, n_spill = n - spill_free, spill_free
        fast_ids = iter(self.fast.allocate(n_fast) if n_fast else [])
        spill_ids = iter(
            [b + self.offset for b in self.spill.allocate(n_spill)]
            if n_spill
            else []
        )
        out = [next(fast_ids) if wf else next(spill_ids) for wf in want_fast]
        n_ghost = 0
        if keys is not None:
            for i, wf in enumerate(want_fast):
                if wf and ghost_hot[i] and self.policy.admit_hot(keys[i]):
                    n_ghost += 1
        self.tier_stats.fast_writes += n_fast
        self.tier_stats.spill_writes += n_spill
        self.tier_stats.ghost_admits += n_ghost
        self.policy.reset(out)  # recycled blocks start cold
        return out

    def retain(self, block_ids: list[int]) -> None:
        if not len(block_ids):
            return
        ids, fm = self._split(block_ids)
        if fm.any():
            self.fast.retain(ids[fm].tolist())
        if not fm.all():
            self.spill.retain((ids[~fm] - self.offset).tolist())

    def release(self, block_ids: list[int]) -> None:
        if not len(block_ids):
            return
        ids, fm = self._split(block_ids)
        if fm.any():
            self.fast.release(ids[fm].tolist())
        if not fm.all():
            self.spill.release((ids[~fm] - self.offset).tolist())

    # ------------------------------------------------------------------
    # Data plane + epochs (dispatch, merge in caller order)
    # ------------------------------------------------------------------
    def write_block(self, block_id: int, payload: np.ndarray | None) -> int:
        self.policy.touch([block_id], self.now)
        if block_id < self.offset:
            return self.fast.write_block(block_id, payload)
        return self.spill.write_block(block_id - self.offset, payload)

    def write_blocks(
        self, block_ids: list[int], payloads: np.ndarray | None = None
    ) -> list[int]:
        ids, fm = self._split(block_ids)
        self.policy.touch(ids, self.now)
        eps = np.empty(len(ids), np.int64)
        if fm.any():
            sub = payloads[fm] if payloads is not None else None
            eps[fm] = self.fast.write_blocks(ids[fm].tolist(), sub)
        if not fm.all():
            sub = payloads[~fm] if payloads is not None else None
            eps[~fm] = self.spill.write_blocks(
                (ids[~fm] - self.offset).tolist(), sub
            )
        return eps.tolist()

    def read_block(self, block_id: int) -> tuple[np.ndarray, int]:
        if block_id < self.offset:
            return self.fast.read_block(block_id)
        return self.spill.read_block(block_id - self.offset)

    def read_blocks(
        self, block_ids, out: np.ndarray | None = None
    ) -> tuple[np.ndarray | None, np.ndarray]:
        ids, fm = self._split(block_ids)
        eps = np.empty(len(ids), np.int64)
        meta = self.fast.data is None
        dst = None
        if not meta:
            dst = (
                out
                if out is not None
                else np.empty((len(ids), self.layout.block_bytes), np.uint8)
            )
        if fm.any():
            p, e = self.fast.read_blocks(ids[fm])
            eps[fm] = e
            if dst is not None:
                dst[fm] = p
        if not fm.all():
            p, e = self.spill.read_blocks(ids[~fm] - self.offset)
            eps[~fm] = e
            if dst is not None:
                dst[~fm] = p
        return dst, eps

    def read_fragments(self, block_id: int, frag_ids: list[int]) -> np.ndarray:
        if block_id < self.offset:
            return self.fast.read_fragments(block_id, frag_ids)
        return self.spill.read_fragments(block_id - self.offset, frag_ids)

    def validate_epoch(self, block_id: int, epoch: int) -> bool:
        if block_id < self.offset:
            return self.fast.validate_epoch(block_id, epoch)
        return self.spill.validate_epoch(block_id - self.offset, epoch)

    def validate_epochs(self, block_ids, epochs) -> np.ndarray:
        ids, fm = self._split(block_ids)
        exp = np.asarray(epochs)
        out = np.empty(len(ids), bool)
        if fm.any():
            out[fm] = self.fast.validate_epochs(ids[fm], exp[fm])
        if not fm.all():
            out[~fm] = self.spill.validate_epochs(ids[~fm] - self.offset, exp[~fm])
        return out

    # ------------------------------------------------------------------
    # Hotness hooks (manager fetch path)
    # ------------------------------------------------------------------
    def touch_demand(self, block_ids, now: float) -> tuple[int, int]:
        """Bump heat for a *planned* access (demand signal: fires even
        when the cutover later recomputes, so spill blocks that keep
        getting planned-over can still earn promotion and escape a
        permanent-cutover loop). Spill blocks whose heat crosses the
        promotion threshold enter ``promote_pending`` — the migrator
        consumes that set instead of sweeping the whole tier.

        Returns (n_fast, n_spill) so the caller can model latency."""
        self.tick(now)
        ids, fm = self._split(block_ids)
        self.policy.touch(ids, self.now)
        spill_ids = ids[~fm]
        if len(spill_ids):
            hot = spill_ids[
                self.policy.heat[spill_ids] >= self.cfg.promote_min_heat
            ]
            self.promote_pending.update(hot.tolist())
        return int(fm.sum()), len(ids) - int(fm.sum())

    def count_tier_hits(self, block_ids) -> None:
        """Account an *actual* fetch (after scatter_read succeeds) —
        planned-but-recomputed or failed fetches don't inflate hit stats."""
        ids, fm = self._split(block_ids)
        n_fast = int(fm.sum())
        self.tier_stats.fast_hit_blocks += n_fast
        self.tier_stats.spill_hit_blocks += len(ids) - n_fast

    def stats_dict(self) -> dict:
        d = self.tier_stats.as_dict()
        d["fast_blocks"] = self.fast.n_blocks
        d["spill_blocks"] = self.spill.n_blocks
        d["fast_occupancy"] = self.fast_occupancy()
        d["spill_occupancy"] = (
            self.spill.n_blocks - self.spill.free_blocks()
        ) / self.spill.n_blocks
        d["ghost_entries"] = self.policy.ghost_len()
        return d

"""TieredPool: an ordered chain of BelugaPool tiers behind the pool API.

Composes N ``BelugaPool`` instances in one global block-id space:

    tier 0  fast CXL pool media       ids [0, fast_blocks)
    tier 1  spill (RDMA-DRAM / SSD)   ids [fast_blocks, fast+spill)
    tier 2+ optional deeper media     ids stacked after the spill tier

so ``TransferEngine``, ``GlobalIndex``, ``KVCacheManager`` and
``CoherentReader/Writer`` work unchanged — every operation dispatches by id
range and merges results in caller order.  Every tier stores real payloads
through the same allocator/epoch machinery; only its *modeled* latency
differs (``fabric.spill_transfer_latency`` priced per medium).

Placement policy (write admission) lives here because allocation is where
a block's tier is decided:

  * below the high watermark every fresh block lands in the fast tier;
  * above it, fresh blocks go straight down-chain — EXCEPT keys the
    ghost-LRU filter recognizes as recently-destroyed-and-returned, which
    are forced fast (admission filter vs cache pollution), and the first
    ``prefix_admit_blocks`` positions of a keyed allocation (the shared
    chain prefix stays fast even under pressure);
  * down-chain blocks fill tiers in chain order (nearest medium first),
    and either end overflows into the other before the pool reports OOM.

Background demotion/promotion along the chain is the migrator's job
(``repro.tiering.migrator``); hotness bookkeeping is O(blocks touched).

Cross-process export mirrors ``BelugaPool.share_meta``/``share_data``: ONE
named segment laid out over the *global* id space (epochs | refcounts |
committed at the same offsets a flat pool would use; payload rows in
global-id order), with each tier's arrays re-homed onto its slice.  An
attacher (``SharedPoolMeta`` / ``SharedPoolData``) therefore needs no
tier awareness at all — the fast/spill offset split is already baked into
the ids it is handed.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import diag
from repro.core.fabric import DEFAULT, FabricConstants
from repro.core.pool import BelugaPool, OutOfPoolMemory, PoolLayout
from repro.tiering.policy import HotnessTracker
from repro.tiering.stats import TierStats


@dataclass
class TieringConfig:
    """Knobs for the tiered pool (``ClusterConfig.tiering``)."""

    enabled: bool = False
    spill_blocks: int = 0  # 0 -> 4x the fast tier
    spill_media: str = "rdma_dram"  # rdma_dram | ssd
    high_watermark: float = 0.90  # demote when fast occupancy exceeds this
    demote_target: float = 0.75  # ... down to this occupancy
    migrate_interval_s: float = 0.05  # background engine step period
    migrate_batch_blocks: int = 64  # per-step migration budget
    half_life_s: float = 30.0  # hotness decay half-life (virtual s)
    promote_min_heat: float = 2.0  # spill block heat to earn promotion
    ghost_capacity: int = 8192  # admission-filter memory (keys)
    model_contention: bool = True  # migration contends via DeviceQueues
    # -- 3-level chain ------------------------------------------------
    # extra tiers BELOW the spill tier, ordered fast-to-slow:
    # ((blocks, media), ...) e.g. ((65536, "ssd"),) for CXL->DRAM->SSD
    extra_tiers: tuple = ()
    # optional per-boundary high watermarks (tier k demotes into k+1 when
    # its occupancy crosses watermark k); empty -> high_watermark for all
    tier_watermarks: tuple = ()
    # optional per-boundary demote targets; empty -> demote_target
    tier_demote_targets: tuple = ()
    # partial-prefix admission: under pressure the first k positions of a
    # keyed allocation (the request chain's shared prefix) still go fast
    prefix_admit_blocks: int = 0
    # positional touch decay: position i of a touched chain earns weight
    # 1 - decay*i/(n-1), so chain *suffixes* cool faster than the shared
    # prefix and demotion naturally peels the cold tail.  0.0 = off.
    suffix_touch_decay: float = 0.0


class _TierView:
    """Read-only per-block metadata view over the chain (global ids).

    ``GlobalIndex`` pokes ``pool.refcounts[block_id]`` directly; this keeps
    that O(1)/O(k) without materializing a concatenated copy per access.
    Accepts scalars, fancy index arrays (including empty), and boolean
    masks over the global id space — everything a flat ndarray would.
    """

    __slots__ = ("_arrays", "_starts")

    def __init__(self, arrays, starts):
        self._arrays = list(arrays)
        self._starts = np.asarray(starts, np.intp)  # first id of each tier

    def _tier_of(self, i: int) -> int:
        return int(np.searchsorted(self._starts, i, side="right")) - 1

    def __getitem__(self, i):
        if isinstance(i, (int, np.integer)):
            t = self._tier_of(i)
            return self._arrays[t][i - self._starts[t]]
        ids = np.asarray(i)
        if ids.dtype == np.bool_:
            # a mask over the global id space selects, never indexes
            ids = np.flatnonzero(ids)
        elif ids.ndim == 0:
            t = self._tier_of(int(ids))
            return self._arrays[t][int(ids) - self._starts[t]]
        ids = ids.astype(np.intp, copy=False)
        out = np.empty(len(ids), self._arrays[0].dtype)
        t = np.searchsorted(self._starts, ids, side="right") - 1
        for k, arr in enumerate(self._arrays):
            m = t == k
            if m.any():
                out[m] = arr[ids[m] - self._starts[k]]
        return out

    def __len__(self):
        return sum(len(a) for a in self._arrays)


class TieredPool:
    """N-tier pool chain in one global block-id space (fast first)."""

    is_tiered = True

    def __init__(
        self,
        layout: PoolLayout,
        fast_blocks: int,
        spill_blocks: int,
        n_shards: int = 32,
        backing: str = "numpy",
        interleave: bool = True,
        cfg: TieringConfig | None = None,
        constants: FabricConstants = DEFAULT,
    ):
        self.layout = layout
        self.cfg = cfg or TieringConfig(enabled=True)
        self.constants = constants
        sizes = [fast_blocks, spill_blocks]
        media = ["cxl", self.cfg.spill_media]
        for eb, em in self.cfg.extra_tiers:
            # deep-tier capacities are modeling knobs: round up to the
            # shard multiple the BelugaPool allocator requires
            sizes.append(-(-int(eb) // n_shards) * n_shards)
            media.append(em)
        self.tiers = [
            BelugaPool(layout, nb, n_shards, backing, interleave)
            for nb in sizes
        ]
        self.tier_media = tuple(media)
        self._starts = np.cumsum([0] + sizes[:-1]).astype(np.intp)
        self.n_blocks = int(sum(sizes))
        self.n_shards = n_shards
        self.interleave = interleave
        self.backing = backing
        # 2-tier compatibility aliases (tests, migrator fast paths)
        self.fast = self.tiers[0]
        self.spill = self.tiers[1]
        self.offset = fast_blocks
        self.spill_media = self.cfg.spill_media
        self.policy = HotnessTracker(
            self.n_blocks,
            half_life_s=self.cfg.half_life_s,
            ghost_capacity=self.cfg.ghost_capacity,
        )
        self.tier_stats = TierStats()
        self.tier_writes = [0] * len(self.tiers)
        self.now = 0.0  # virtual time high-water mark (hotness decay clock)
        # down-chain blocks whose heat crossed the promotion threshold (fed
        # by touch_demand, drained by the migrator): keeps promotion
        # O(blocks touched) instead of an every-step O(chain) sweep
        self.promote_pending: set[int] = set()
        self._meta_segment = None
        self._meta_spec: dict | None = None
        self._data_segment = None
        self._data_spec: dict | None = None
        self._rebuild_views()

    def _rebuild_views(self) -> None:
        self.refcounts = _TierView(
            [t.refcounts for t in self.tiers], self._starts
        )
        self.epochs = _TierView([t.epochs for t in self.tiers], self._starts)
        self.committed = _TierView(
            [t.committed for t in self.tiers], self._starts
        )

    # ------------------------------------------------------------------
    # Cross-process export (share_meta/share_data over the global space)
    # ------------------------------------------------------------------
    def share_meta(self) -> dict:
        """Re-home every tier's epochs/refcounts/committed into ONE named
        segment laid out over the global id space — byte-identical layout
        to a flat ``BelugaPool.share_meta`` of ``self.n_blocks`` blocks,
        so ``SharedPoolMeta`` attachers (metadata shard children) resolve
        global ids with zero tier awareness.  Idempotent; returns the
        attach spec (plain data, picklable)."""
        if self._meta_spec is not None:
            return self._meta_spec
        from repro.core.shm import create_segment

        n = self.n_blocks
        seg = create_segment(13 * n)  # 8 B epoch + 4 B refcount + 1 B flag
        eps = np.frombuffer(seg.buf, np.int64, n, 0)
        rcs = np.frombuffer(seg.buf, np.int32, n, 8 * n)
        com = np.frombuffer(seg.buf, np.bool_, n, 12 * n)
        for t, o in zip(self.tiers, self._starts.tolist()):
            tn = t.n_blocks
            with t._lock:
                eps[o : o + tn] = t.epochs
                rcs[o : o + tn] = t.refcounts
                com[o : o + tn] = t.committed
                t.epochs = eps[o : o + tn]
                t.refcounts = rcs[o : o + tn]
                t.committed = com[o : o + tn]
        # the pool-level views become the shared arrays themselves
        self.epochs, self.refcounts, self.committed = eps, rcs, com
        self._meta_segment = seg
        self._meta_spec = {
            "shm_name": seg.name,
            "n_blocks": n,
            "block_tokens": self.layout.block_tokens,
        }
        import atexit

        atexit.register(self.unshare_meta)  # no leaked /dev/shm entries
        return self._meta_spec

    def unshare_meta(self) -> None:
        """Copy metadata back to private per-tier arrays and unlink.

        Safe to call repeatedly / when never shared; the pool stays fully
        functional afterwards (values preserved)."""
        seg = self._meta_segment
        if seg is None:
            return
        from repro.core.shm import close_segment

        for t in self.tiers:
            with t._lock:
                t.epochs = np.array(t.epochs, np.int64)
                t.refcounts = np.array(t.refcounts, np.int32)
                t.committed = np.array(t.committed, bool)
        self._rebuild_views()
        self._meta_segment = None
        self._meta_spec = None
        close_segment(seg, unlink=True)
        import atexit

        try:
            atexit.unregister(self.unshare_meta)
        except Exception:  # noqa: BLE001
            diag.note("tiers.unshare_meta.unregister_failed")

    def share_data(self) -> dict:
        """Re-home every tier's payload rows into ONE named segment in
        global-id order — shape-identical to a flat pool's ``share_data``,
        so ``SharedPoolData`` attachers (engine workers) scatter/gather by
        global id with no per-tier segments to juggle.  The spec carries a
        ``"tiering"`` sub-dict (tier starts + media) the worker-side view
        uses for tier accounting.  Implies ``share_meta``.  Idempotent."""
        if self._data_spec is not None:
            return self._data_spec
        if self.backing != "numpy":
            raise ValueError(
                f"share_data requires backing='numpy', not {self.backing!r}"
            )
        meta = self.share_meta()
        from repro.core.shm import create_segment

        lay = self.layout
        seg = create_segment(self.n_blocks * lay.block_bytes)
        view = np.frombuffer(seg.buf, np.uint8).reshape(
            self.n_blocks, lay.block_bytes
        )
        for t, o in zip(self.tiers, self._starts.tolist()):
            tn = t.n_blocks
            with t._lock:
                view[o : o + tn] = t.data
                t.data = view[o : o + tn]
        self._data_segment = seg
        self._data_spec = {
            "data_shm_name": seg.name,
            "meta": meta,
            "n_blocks": self.n_blocks,
            "block_tokens": lay.block_tokens,
            "n_layers_kv": lay.n_layers_kv,
            "n_kv_heads": lay.n_kv_heads,
            "head_dim": lay.head_dim,
            "dtype_bytes": lay.dtype_bytes,
            "tiering": {
                "starts": self._starts.tolist(),
                "media": list(self.tier_media),
            },
        }
        import atexit

        atexit.register(self.unshare_data)  # no leaked /dev/shm entries
        return self._data_spec

    def unshare_data(self) -> None:
        """Copy payloads back to private per-tier arrays and unlink.

        Safe to call repeatedly / when never shared; leaves ``share_meta``
        as-is (its own unshare handles it)."""
        seg = self._data_segment
        if seg is None:
            return
        from repro.core.shm import close_segment

        for t in self.tiers:
            with t._lock:
                t.data = np.array(t.data, np.uint8)
        self._data_segment = None
        self._data_spec = None
        close_segment(seg, unlink=True)
        import atexit

        try:
            atexit.unregister(self.unshare_data)
        except Exception:  # noqa: BLE001
            diag.note("tiers.unshare_data.unregister_failed")

    # ------------------------------------------------------------------
    @property
    def data(self):
        """Backing-kind probe only (``pool.data is None`` == meta); block
        payloads must go through read/write methods, which dispatch."""
        return self.tiers[0].data

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    @property
    def alloc_count(self) -> int:
        return sum(t.alloc_count for t in self.tiers)

    def tier_of(self, block_id: int) -> int:
        return int(np.searchsorted(self._starts, block_id, side="right")) - 1

    def tick(self, now: float) -> None:
        self.now = max(self.now, now)

    def free_blocks(self) -> int:
        return sum(t.free_blocks() for t in self.tiers)

    def shard_occupancy(self) -> list[int]:
        out: list[int] = []
        for t in self.tiers:
            out += t.shard_occupancy()
        return out

    def tier_occupancy(self, t: int) -> float:
        p = self.tiers[t]
        if p.n_blocks == 0:  # empty tier: occupancy 0, never ZeroDivision
            return 0.0
        return (p.n_blocks - p.free_blocks()) / p.n_blocks

    def fast_occupancy(self) -> float:
        return self.tier_occupancy(0)

    def watermark(self, t: int) -> float:
        w = self.cfg.tier_watermarks
        return float(w[t]) if t < len(w) else self.cfg.high_watermark

    def demote_target(self, t: int) -> float:
        w = self.cfg.tier_demote_targets
        return float(w[t]) if t < len(w) else self.cfg.demote_target

    def _split(self, block_ids) -> tuple[np.ndarray, np.ndarray]:
        """(ids, fast-mask) — the 2-tier split kept for compatibility."""
        ids = np.asarray(block_ids, np.intp)
        return ids, ids < self.offset

    def _split_tiers(self, block_ids) -> tuple[np.ndarray, np.ndarray]:
        """(ids, per-id tier index) over the whole chain."""
        ids = np.asarray(block_ids, np.intp)
        return ids, np.searchsorted(self._starts, ids, side="right") - 1

    # ------------------------------------------------------------------
    # Allocation (write admission)
    # ------------------------------------------------------------------
    def allocate(self, n: int, keys: list[bytes] | None = None) -> list[int]:
        """Allocate n blocks, choosing each block's tier.

        ``keys`` (optional, from the writeback path) feeds the ghost-LRU
        admission filter; without keys the policy is purely watermark-based.
        Down-chain blocks fill tiers in chain order (nearest medium first).
        """
        frees = [t.free_blocks() for t in self.tiers]
        if sum(frees) < n:
            raise OutOfPoolMemory(
                f"need {n}, have {frees[0]} fast + {sum(frees[1:])} spill"
            )
        pressured = self.fast_occupancy() >= self.watermark(0)
        ghost_hot = [False] * n
        if keys is not None and pressured:
            # peek only: the entry is consumed below, and only for blocks
            # the capacity clamp actually lets into the fast tier — a
            # returning key must not lose its one-shot admission to a
            # full fast tier it never reached
            ghost_hot = [self.policy.ghost_contains(k) for k in keys]
        # tier per position: fast unless pressured (ghost-hot always fast;
        # the first prefix_admit_blocks of a keyed chain also stay fast)
        pa = self.cfg.prefix_admit_blocks if keys is not None else 0
        want_fast = [
            (not pressured) or ghost_hot[i] or i < pa for i in range(n)
        ]
        n_fast = sum(want_fast)
        # clamp to capacity, overflowing down-chain (non-ghost fast-wishers
        # yield their fast slot before ghost-hot ones do, tail first)
        if n_fast > frees[0]:
            flip = n_fast - frees[0]
            for only_ghost in (False, True):
                for i in range(n - 1, -1, -1):
                    if not flip:
                        break
                    if want_fast[i] and ghost_hot[i] == only_ghost:
                        want_fast[i] = False
                        flip -= 1
            n_fast = frees[0]
        n_rest = n - n_fast
        if n_rest > sum(frees[1:]):
            flip = n_rest - sum(frees[1:])  # overflow back into fast
            for i in range(n):
                if not flip:
                    break
                if not want_fast[i]:
                    want_fast[i] = True
                    flip -= 1
            n_fast, n_rest = n - sum(frees[1:]), sum(frees[1:])
        # assign down-chain positions to tiers 1..k in chain order: the
        # earlier (prefix) positions land on the nearest medium
        counts = [n_fast] + [0] * (len(self.tiers) - 1)
        tier_at = [0] * n
        j, avail = 1, frees[1] if len(frees) > 1 else 0
        for i in range(n):
            if want_fast[i]:
                continue
            while avail == 0:
                j += 1
                avail = frees[j]
            tier_at[i] = j
            counts[j] += 1
            avail -= 1
        its = []
        for k, (t, c) in enumerate(zip(self.tiers, counts)):
            base = int(self._starts[k])
            its.append(
                iter([b + base for b in t.allocate(c)]) if c else iter([])
            )
        out = [next(its[tier_at[i]]) for i in range(n)]
        n_ghost = 0
        if keys is not None:
            for i, wf in enumerate(want_fast):
                if wf and ghost_hot[i] and self.policy.admit_hot(keys[i]):
                    n_ghost += 1
        self.tier_stats.fast_writes += n_fast
        self.tier_stats.spill_writes += n_rest
        self.tier_stats.ghost_admits += n_ghost
        for k, c in enumerate(counts):
            self.tier_writes[k] += c
        self.policy.reset(out)  # recycled blocks start cold
        return out

    def retain(self, block_ids: list[int]) -> None:
        if not len(block_ids):
            return
        ids, tix = self._split_tiers(block_ids)
        for k, t in enumerate(self.tiers):
            m = tix == k
            if m.any():
                t.retain((ids[m] - self._starts[k]).tolist())

    def release(self, block_ids: list[int]) -> None:
        if not len(block_ids):
            return
        ids, tix = self._split_tiers(block_ids)
        for k, t in enumerate(self.tiers):
            m = tix == k
            if m.any():
                t.release((ids[m] - self._starts[k]).tolist())

    # ------------------------------------------------------------------
    # Data plane + epochs (dispatch, merge in caller order)
    # ------------------------------------------------------------------
    def write_block(self, block_id: int, payload: np.ndarray | None) -> int:
        self.policy.touch([block_id], self.now)
        t = self.tier_of(block_id)
        return self.tiers[t].write_block(
            block_id - int(self._starts[t]), payload
        )

    def write_blocks(
        self, block_ids: list[int], payloads: np.ndarray | None = None
    ) -> list[int]:
        ids, tix = self._split_tiers(block_ids)
        self.policy.touch(ids, self.now)
        eps = np.empty(len(ids), np.int64)
        for k, t in enumerate(self.tiers):
            m = tix == k
            if not m.any():
                continue
            sub = payloads[m] if payloads is not None else None
            eps[m] = t.write_blocks((ids[m] - self._starts[k]).tolist(), sub)
        return eps.tolist()

    def read_block(self, block_id: int) -> tuple[np.ndarray, int]:
        t = self.tier_of(block_id)
        return self.tiers[t].read_block(block_id - int(self._starts[t]))

    def read_blocks(
        self, block_ids, out: np.ndarray | None = None
    ) -> tuple[np.ndarray | None, np.ndarray]:
        ids, tix = self._split_tiers(block_ids)
        eps = np.empty(len(ids), np.int64)
        meta = self.data is None
        dst = None
        if not meta:
            dst = (
                out
                if out is not None
                else np.empty((len(ids), self.layout.block_bytes), np.uint8)
            )
        for k, t in enumerate(self.tiers):
            m = tix == k
            if not m.any():
                continue
            p, e = t.read_blocks(ids[m] - self._starts[k])
            eps[m] = e
            if dst is not None:
                dst[m] = p
        return dst, eps

    def read_fragments(self, block_id: int, frag_ids: list[int]) -> np.ndarray:
        t = self.tier_of(block_id)
        return self.tiers[t].read_fragments(
            block_id - int(self._starts[t]), frag_ids
        )

    def validate_epoch(self, block_id: int, epoch: int) -> bool:
        t = self.tier_of(block_id)
        return self.tiers[t].validate_epoch(
            block_id - int(self._starts[t]), epoch
        )

    def validate_epochs(self, block_ids, epochs) -> np.ndarray:
        ids, tix = self._split_tiers(block_ids)
        exp = np.asarray(epochs)
        out = np.empty(len(ids), bool)
        for k, t in enumerate(self.tiers):
            m = tix == k
            if m.any():
                out[m] = t.validate_epochs(ids[m] - self._starts[k], exp[m])
        return out

    # ------------------------------------------------------------------
    # Hotness hooks (manager fetch path)
    # ------------------------------------------------------------------
    def touch_demand(self, block_ids, now: float) -> tuple[int, ...]:
        """Bump heat for a *planned* access (demand signal: fires even
        when the cutover later recomputes, so down-chain blocks that keep
        getting planned-over can still earn promotion and escape a
        permanent-cutover loop). Down-chain blocks whose heat crosses the
        promotion threshold enter ``promote_pending`` — the migrator
        consumes that set instead of sweeping the whole chain.

        Returns per-tier counts ``(n_tier0, n_tier1, ...)`` so the caller
        can model latency; a 2-tier chain unpacks as (n_fast, n_spill)."""
        self.tick(now)
        ids, tix = self._split_tiers(block_ids)
        w = 1.0
        decay = self.cfg.suffix_touch_decay
        if decay > 0.0 and len(ids) > 1:
            # chain position i cools faster toward the tail: the shared
            # prefix accumulates full heat, the suffix only a fraction
            w = np.maximum(
                1.0 - decay * np.arange(len(ids)) / (len(ids) - 1), 0.0
            )
        self.policy.touch(ids, self.now, weight=w)
        rest = ids[tix > 0]
        if len(rest):
            hot = rest[self.policy.heat[rest] >= self.cfg.promote_min_heat]
            self.promote_pending.update(hot.tolist())
        return tuple(int((tix == k).sum()) for k in range(len(self.tiers)))

    def count_tier_hits(self, block_ids) -> None:
        """Account an *actual* fetch (after scatter_read succeeds) —
        planned-but-recomputed or failed fetches don't inflate hit stats."""
        ids, fm = self._split(block_ids)
        n_fast = int(fm.sum())
        self.tier_stats.fast_hit_blocks += n_fast
        self.tier_stats.spill_hit_blocks += len(ids) - n_fast

    def stats_dict(self) -> dict:
        d = self.tier_stats.as_dict()
        rest_blocks = sum(t.n_blocks for t in self.tiers[1:])
        rest_used = sum(
            t.n_blocks - t.free_blocks() for t in self.tiers[1:]
        )
        d["fast_blocks"] = self.tiers[0].n_blocks
        d["spill_blocks"] = rest_blocks
        d["fast_occupancy"] = self.fast_occupancy()
        # aggregate over every down-chain tier; 0.0 when the chain is all
        # fast (never ZeroDivisionError on an empty tier)
        d["spill_occupancy"] = (
            rest_used / rest_blocks if rest_blocks else 0.0
        )
        d["ghost_entries"] = self.policy.ghost_len()
        d["tier_blocks"] = [t.n_blocks for t in self.tiers]
        d["tier_occupancy"] = [
            self.tier_occupancy(k) for k in range(len(self.tiers))
        ]
        d["tier_media"] = list(self.tier_media)
        d["tier_writes"] = list(self.tier_writes)
        return d

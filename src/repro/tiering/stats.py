"""Per-tier counters surfaced in ``Cluster.run`` summaries."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass
class TierStats:
    fast_hit_blocks: int = 0  # prefix-hit blocks served from the fast tier
    spill_hit_blocks: int = 0  # ... and from the spill tier
    fast_writes: int = 0  # fresh blocks admitted to the fast tier
    spill_writes: int = 0  # fresh blocks admitted straight to spill
    ghost_admits: int = 0  # pressured writes forced fast by the ghost filter
    demotions: int = 0  # blocks migrated fast -> spill
    promotions: int = 0  # blocks migrated spill -> fast
    spill_evictions: int = 0  # spill blocks destroyed to make demotion room
    migrated_bytes: int = 0
    migration_busy_s: float = 0.0  # modeled media time spent migrating

    def as_dict(self) -> dict:
        return dict(self.__dict__)

"""Background tier-migration engine (virtual clock, budgeted batches).

Runs as a daemon on the cluster's virtual time line: every
``migrate_interval_s`` it takes one step, and each step moves at most
``migrate_batch_blocks`` blocks per chain boundary — a bandwidth budget,
not a sweep.

  * **demotion** (ahead of pressure): when a tier's occupancy crosses its
    watermark, its coldest unreferenced indexed blocks migrate one tier
    down-chain until occupancy is back at the demote target.  Demoted
    prefixes stay fetchable (at that medium's latency) instead of being
    destroyed and recomputed — the whole point of the hierarchy.
  * **promotion**: down-chain blocks whose decayed heat crosses
    ``promote_min_heat`` (they keep getting fetched) migrate back to the
    fast tier, but never above the high watermark.
  * **last-tier eviction** (last resort): when the bottom of the chain is
    full, its coldest blocks are destroyed via ``GlobalIndex.evict_blocks``
    and their keys enter the ghost list, arming the admission filter.
    Intermediate tiers never destroy — their own boundary pass drains them
    further down-chain.

Migration I/O is accounted through the shared ``fabric.DeviceQueues`` so
it contends with foreground fetches on the pool devices, and every batch's
media time lands in ``TierStats.migration_busy_s``.

The engine is driven from ``EngineInstance.advance`` between decode steps:
each engine calls ``run_until(clock)``; steps fire once on the monotone
max over all callers (one daemon, many clocks).  In engine-worker
clusters the parent drives it the same way between worker rounds.

``index`` is anything speaking the ``GlobalIndex`` metadata surface the
migrator needs (``owners_of`` / ``remap_many`` / ``evict_blocks``): the
co-located ``GlobalIndex``/``ShardedIndex``, or — in ``index_rpc``
clusters — an ``RpcIndexClient``/``ShardedRpcIndexClient`` proxy, so the
migration daemon runs AGAINST THE RING (OWNERS/REMAP/EVICT_BLOCKS wire
ops) and no longer has to live in the metadata service's process. Only
the payload copies touch the shared pool.
"""

from __future__ import annotations

import numpy as np

from repro.core import fabric
from repro.core.fabric import DeviceQueues
from repro.tiering.tiers import TieredPool, TieringConfig


class MigrationEngine:
    def __init__(
        self,
        pool: TieredPool,
        index,
        cfg: TieringConfig | None = None,
        queues: DeviceQueues | None = None,
    ):
        self.pool = pool
        self.index = index
        self.cfg = cfg or pool.cfg
        self.queues = queues
        self.clock = 0.0
        self.steps = 0

    # ------------------------------------------------------------------
    def run_until(self, now: float) -> None:
        """Advance the daemon's virtual clock to ``now`` (monotone)."""
        interval = self.cfg.migrate_interval_s
        while self.clock + interval <= now:
            self.clock += interval
            self._step(self.clock)
            self.steps += 1

    def _step(self, now: float) -> None:
        self.pool.tick(now)
        cfg = self.cfg
        pool = self.pool
        fast = pool.tiers[0]
        if pool.tier_occupancy(0) >= pool.watermark(0):
            used = fast.n_blocks - fast.free_blocks()
            target = int(pool.demote_target(0) * fast.n_blocks)
            k = min(cfg.migrate_batch_blocks, used - target)
            if k > 0:
                self._demote(0, k, now)
        elif fast.free_blocks() > 0:
            self._promote(now)
        # deeper boundaries drain independently (tier t -> t+1), each
        # under its own watermark with its own per-step budget
        for t in range(1, pool.n_tiers - 1):
            if pool.tier_occupancy(t) < pool.watermark(t):
                continue
            tp = pool.tiers[t]
            used = tp.n_blocks - tp.free_blocks()
            target = int(pool.demote_target(t) * tp.n_blocks)
            k = min(cfg.migrate_batch_blocks, used - target)
            if k > 0:
                self._demote(t, k, now)
        # runs LAST so even a demote step (whose last-tier eviction can
        # destroy enqueued ids) leaves the pending set clean
        self._prune_pending()

    def _prune_pending(self) -> None:
        """Drop freed / re-referenced / no-longer-committed ids from
        ``promote_pending`` EVERY step, not just on promote passes: a
        foreground eviction can free a pending down-chain block between
        steps, and a demote-only step used to leave that stale id enqueued
        (the block-conservation property test pins the invariant that
        after a step the pending set only names live refcount-1 blocks)."""
        pool = self.pool
        pending = pool.promote_pending
        if not pending:
            return
        cand = np.fromiter(pending, np.intp, len(pending))
        dead = ~((pool.refcounts[cand] == 1) & pool.committed[cand])
        if dead.any():
            pending.difference_update(cand[dead].tolist())

    # ------------------------------------------------------------------
    def _candidates(self, pool, offset: int) -> np.ndarray:
        """Global ids of migration candidates in one tier: committed with
        no in-flight reference (refcount 1 = index only). One vectorized
        mask — the per-block key lookup happens only for the <= batch
        blocks actually chosen (``_migrate`` skips unindexed stragglers)."""
        return np.where((pool.refcounts == 1) & pool.committed)[0] + offset

    def _demote(self, src_t: int, k: int, now: float) -> None:
        pool = self.pool
        dst_t = src_t + 1
        cand = self._candidates(
            pool.tiers[src_t], int(pool._starts[src_t])
        )
        if not len(cand):
            return
        chosen = pool.policy.coldest(cand, k, now)
        dst = pool.tiers[dst_t]
        short = len(chosen) - dst.free_blocks()
        if short > 0:
            if dst_t == pool.n_tiers - 1:
                # bottom of the chain: make room by destroying its coldest
                # blocks (true eviction: keys reach the ghost list via
                # index.on_evict, arming the admission filter)
                sc = self._candidates(dst, int(pool._starts[dst_t]))
                victims = pool.policy.coldest(sc, short, now)
                freed = self.index.evict_blocks(victims.tolist())
                pool.tier_stats.spill_evictions += len(freed)
            # intermediate destination: its own boundary pass drains it
            # down-chain — never destroy, just take what fits this step
            if len(chosen) > dst.free_blocks():
                chosen = chosen[: dst.free_blocks()]
        if not len(chosen):
            return
        moved = self._migrate(chosen.tolist(), dst_t)
        pool.tier_stats.demotions += len(moved)
        self._account(
            len(moved), now, pool.tier_media[dst_t], to_fast=False
        )

    def _promote(self, now: float) -> None:
        """Promote from the pending set fed by ``TieredPool.touch_demand``
        (blocks whose heat crossed the threshold on access) — O(blocks
        touched), never an every-step sweep of the whole chain."""
        pool, cfg = self.pool, self.cfg
        pending = pool.promote_pending
        if not pending:
            return
        # promotion budget: stay STRICTLY under the high watermark — a
        # promotion landing exactly on it would trip the >= demote
        # trigger next step (promotion-induced demotion wave)
        cap = int(pool.watermark(0) * pool.fast.n_blocks)
        used = pool.fast.n_blocks - pool.fast.free_blocks()
        budget = min(cfg.migrate_batch_blocks, cap - used - 1)
        if budget <= 0:
            return
        cand = np.fromiter(pending, np.intp, len(pending))
        # drop stale entries (freed / re-referenced / already promoted)
        # and entries whose heat decayed back below the threshold while
        # they waited on budget — membership was decided at touch time
        live = cand[(pool.refcounts[cand] == 1) & pool.committed[cand]]
        live = live[
            pool.policy.heat_at(live, now) >= cfg.promote_min_heat
        ]
        chosen = pool.policy.hottest(live, budget, now)
        pending.difference_update(cand.tolist())
        pending.update(live.tolist())  # budget leftovers retry next step
        pending.difference_update(chosen.tolist())
        if not len(chosen):
            return
        moved = self._migrate(chosen.tolist(), 0)
        pool.tier_stats.promotions += len(moved)
        if moved:
            # promotion sources can sit in different media down-chain:
            # each batch pays its own medium
            _, tix = pool._split_tiers(moved)
            for t in sorted(set(tix.tolist())):
                self._account(
                    int((tix == t).sum()),
                    now,
                    pool.tier_media[t],
                    to_fast=True,
                )

    # ------------------------------------------------------------------
    def _migrate(self, src_ids: list[int], dst_t: int) -> list[int]:
        """Copy payloads to tier ``dst_t``, re-point the index, free the
        sources. Returns the global ids actually migrated (sources)."""
        pool, index = self.pool, self.index
        # one-lock row snapshot: (key, block, epoch) can't disagree the way
        # the old keys_of_blocks -> lookup_many two-call sequence could
        keys, src_ids, old_eps = index.owners_of(src_ids)
        if not keys:
            return []
        dst_pool = pool.tiers[dst_t]
        dst_off = int(pool._starts[dst_t])
        payloads, _ = pool.read_blocks(src_ids)
        dst_local = dst_pool.allocate(len(src_ids))
        new_eps = dst_pool.write_blocks(dst_local, payloads)
        dst_ids = [b + dst_off for b in dst_local]
        ok = index.remap_many(keys, src_ids, old_eps, dst_ids, new_eps)
        moved_src = [s for s, o in zip(src_ids, ok) if o]
        moved_dst = [d for d, o in zip(dst_ids, ok) if o]
        lost_dst = [d - dst_off for d, o in zip(dst_ids, ok) if not o]
        if lost_dst:  # raced with an eviction/re-publish: roll back copies
            dst_pool.release(lost_dst)
        if moved_src:
            pool.policy.move(moved_src, moved_dst)
            # freeing the source bumps its epoch: in-flight readers that
            # matched the old entry fail validation and re-plan (§5.1)
            pool.release(moved_src)
        return moved_src

    def _account(
        self, n_blocks: int, now: float, media: str, to_fast: bool
    ) -> None:
        if not n_blocks:
            return
        c = self.pool.constants
        size = n_blocks * self.pool.layout.block_bytes
        spill_t = fabric.spill_transfer_latency(size, media, c)
        fast_t = c.cxl_64b_latency + size / (
            c.cxl_adapter_write_bw if to_fast else c.cxl_adapter_read_bw
        )
        self.pool.tier_stats.migrated_bytes += size
        self.pool.tier_stats.migration_busy_s += spill_t + fast_t
        if self.queues is not None:
            # the fast-tier side of the copy occupies pool devices:
            # foreground fetches queue behind it (budgeted contention)
            addr = self.steps * self.pool.layout.block_bytes
            self.queues.submit(now, addr, size, interleave=True)

"""Background tier-migration engine (virtual clock, budgeted batches).

Runs as a daemon on the cluster's virtual time line: every
``migrate_interval_s`` it takes one step, and each step moves at most
``migrate_batch_blocks`` blocks — a bandwidth budget, not a sweep.

  * **demotion** (ahead of pressure): when fast-tier occupancy crosses the
    high watermark, the coldest unreferenced indexed blocks migrate to the
    spill tier until occupancy is back at ``demote_target``.  Demoted
    prefixes stay fetchable (at spill latency) instead of being destroyed
    and recomputed — the whole point of the hierarchy.
  * **promotion**: spill blocks whose decayed heat crosses
    ``promote_min_heat`` (they keep getting fetched) migrate back to fast,
    but never above the high watermark.
  * **spill eviction** (last resort): when the spill tier itself is full,
    its coldest blocks are destroyed via ``GlobalIndex.evict_blocks`` and
    their keys enter the ghost list, arming the admission filter.

Migration I/O is accounted through the shared ``fabric.DeviceQueues`` so
it contends with foreground fetches on the pool devices, and every batch's
media time lands in ``TierStats.migration_busy_s``.

The engine is driven from ``EngineInstance.advance`` between decode steps:
each engine calls ``run_until(clock)``; steps fire once on the monotone
max over all callers (one daemon, many clocks).

``index`` is anything speaking the ``GlobalIndex`` metadata surface the
migrator needs (``owners_of`` / ``remap_many`` / ``evict_blocks``): the
co-located ``GlobalIndex``/``ShardedIndex``, or — in ``index_rpc``
clusters — an ``RpcIndexClient``/``ShardedRpcIndexClient`` proxy, so the
migration daemon runs AGAINST THE RING (OWNERS/REMAP/EVICT_BLOCKS wire
ops) and no longer has to live in the metadata service's process. Only
the payload copies touch the shared pool.
"""

from __future__ import annotations

import numpy as np

from repro.core import fabric
from repro.core.fabric import DeviceQueues
from repro.tiering.tiers import TieredPool, TieringConfig


class MigrationEngine:
    def __init__(
        self,
        pool: TieredPool,
        index,
        cfg: TieringConfig | None = None,
        queues: DeviceQueues | None = None,
    ):
        self.pool = pool
        self.index = index
        self.cfg = cfg or pool.cfg
        self.queues = queues
        self.clock = 0.0
        self.steps = 0

    # ------------------------------------------------------------------
    def run_until(self, now: float) -> None:
        """Advance the daemon's virtual clock to ``now`` (monotone)."""
        interval = self.cfg.migrate_interval_s
        while self.clock + interval <= now:
            self.clock += interval
            self._step(self.clock)
            self.steps += 1

    def _step(self, now: float) -> None:
        self.pool.tick(now)
        cfg = self.cfg
        fast = self.pool.fast
        used = fast.n_blocks - fast.free_blocks()
        if used / fast.n_blocks >= cfg.high_watermark:
            target = int(cfg.demote_target * fast.n_blocks)
            k = min(cfg.migrate_batch_blocks, used - target)
            if k > 0:
                self._demote(k, now)
        elif fast.free_blocks() > 0:
            self._promote(now)
        # runs LAST so even a demote step (whose spill eviction can
        # destroy enqueued ids) leaves the pending set clean
        self._prune_pending()

    def _prune_pending(self) -> None:
        """Drop freed / re-referenced / no-longer-committed ids from
        ``promote_pending`` EVERY step, not just on promote passes: a
        foreground eviction can free a pending spill block between steps,
        and a demote-only step used to leave that stale id enqueued (the
        block-conservation property test pins the invariant that after a
        step the pending set only names live refcount-1 spill blocks)."""
        pool = self.pool
        pending = pool.promote_pending
        if not pending:
            return
        cand = np.fromiter(pending, np.intp, len(pending))
        local = cand - pool.offset
        dead = ~(
            (pool.spill.refcounts[local] == 1) & pool.spill.committed[local]
        )
        if dead.any():
            pending.difference_update(cand[dead].tolist())

    # ------------------------------------------------------------------
    def _candidates(self, pool, offset: int) -> np.ndarray:
        """Global ids of migration candidates in one tier: committed with
        no in-flight reference (refcount 1 = index only). One vectorized
        mask — the per-block key lookup happens only for the <= batch
        blocks actually chosen (``_migrate`` skips unindexed stragglers)."""
        return np.where((pool.refcounts == 1) & pool.committed)[0] + offset

    def _demote(self, k: int, now: float) -> None:
        pool = self.pool
        cand = self._candidates(pool.fast, 0)
        if not len(cand):
            return
        chosen = pool.policy.coldest(cand, k, now)
        # make room in spill by destroying its coldest blocks (true
        # eviction: keys go to the ghost list via index.on_evict)
        short = len(chosen) - pool.spill.free_blocks()
        if short > 0:
            sc = self._candidates(pool.spill, pool.offset)
            victims = pool.policy.coldest(sc, short, now)
            freed = self.index.evict_blocks(victims.tolist())
            pool.tier_stats.spill_evictions += len(freed)
            if len(chosen) > pool.spill.free_blocks():
                chosen = chosen[: pool.spill.free_blocks()]
        if not len(chosen):
            return
        n = self._migrate(chosen.tolist(), to_fast=False)
        pool.tier_stats.demotions += n
        self._account(n, now, to_fast=False)

    def _promote(self, now: float) -> None:
        """Promote from the pending set fed by ``TieredPool.touch_demand``
        (blocks whose heat crossed the threshold on access) — O(blocks
        touched), never an every-step sweep of the whole spill tier."""
        pool, cfg = self.pool, self.cfg
        pending = pool.promote_pending
        if not pending:
            return
        # promotion budget: stay STRICTLY under the high watermark — a
        # promotion landing exactly on it would trip the >= demote
        # trigger next step (promotion-induced demotion wave)
        cap = int(cfg.high_watermark * pool.fast.n_blocks)
        used = pool.fast.n_blocks - pool.fast.free_blocks()
        budget = min(cfg.migrate_batch_blocks, cap - used - 1)
        if budget <= 0:
            return
        cand = np.fromiter(pending, np.intp, len(pending))
        local = cand - pool.offset
        # drop stale entries (freed / re-referenced / already promoted)
        # and entries whose heat decayed back below the threshold while
        # they waited on budget — membership was decided at touch time
        live = cand[
            (pool.spill.refcounts[local] == 1) & pool.spill.committed[local]
        ]
        live = live[
            pool.policy.heat_at(live, now) >= cfg.promote_min_heat
        ]
        chosen = pool.policy.hottest(live, budget, now)
        pending.difference_update(cand.tolist())
        pending.update(live.tolist())  # budget leftovers retry next step
        pending.difference_update(chosen.tolist())
        if not len(chosen):
            return
        n = self._migrate(chosen.tolist(), to_fast=True)
        pool.tier_stats.promotions += n
        self._account(n, now, to_fast=True)

    # ------------------------------------------------------------------
    def _migrate(self, src_ids: list[int], to_fast: bool) -> int:
        """Copy payloads to the other tier, re-point the index, free the
        sources. Returns the number of blocks actually migrated."""
        pool, index = self.pool, self.index
        # one-lock row snapshot: (key, block, epoch) can't disagree the way
        # the old keys_of_blocks -> lookup_many two-call sequence could
        keys, src_ids, old_eps = index.owners_of(src_ids)
        if not keys:
            return 0
        dst_pool = pool.fast if to_fast else pool.spill
        dst_off = 0 if to_fast else pool.offset
        src_off = pool.offset if to_fast else 0
        src_pool = pool.spill if to_fast else pool.fast
        local_src = [b - src_off for b in src_ids]
        payloads, _ = src_pool.read_blocks(local_src)
        dst_local = dst_pool.allocate(len(src_ids))
        new_eps = dst_pool.write_blocks(dst_local, payloads)
        dst_ids = [b + dst_off for b in dst_local]
        ok = index.remap_many(keys, src_ids, old_eps, dst_ids, new_eps)
        moved_src = [s for s, o in zip(src_ids, ok) if o]
        moved_dst = [d for d, o in zip(dst_ids, ok) if o]
        lost_dst = [d - dst_off for d, o in zip(dst_ids, ok) if not o]
        if lost_dst:  # raced with an eviction/re-publish: roll back copies
            dst_pool.release(lost_dst)
        if moved_src:
            pool.policy.move(moved_src, moved_dst)
            # freeing the source bumps its epoch: in-flight readers that
            # matched the old entry fail validation and re-plan (§5.1)
            pool.release(moved_src)
        return len(moved_src)

    def _account(self, n_blocks: int, now: float, to_fast: bool) -> None:
        if not n_blocks:
            return
        c = self.pool.constants
        size = n_blocks * self.pool.layout.block_bytes
        spill_t = fabric.spill_transfer_latency(size, self.pool.spill_media, c)
        fast_t = c.cxl_64b_latency + size / (
            c.cxl_adapter_write_bw if to_fast else c.cxl_adapter_read_bw
        )
        self.pool.tier_stats.migrated_bytes += size
        self.pool.tier_stats.migration_busy_s += spill_t + fast_t
        if self.queues is not None:
            # the fast-tier side of the copy occupies pool devices:
            # foreground fetches queue behind it (budgeted contention)
            addr = self.steps * self.pool.layout.block_bytes
            self.queues.submit(now, addr, size, interleave=True)

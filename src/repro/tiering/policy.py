"""Hotness tracking + ghost-LRU admission for the tiered pool.

Two signals drive placement (ITME-style hotness-driven tiering):

  * **decayed access counters** — one float per block, exponentially
    decayed with virtual time (half-life ``half_life_s``) and bumped on
    every fetch/write touch.  Decay is applied *lazily*: each block keeps
    the virtual time of its last update, so a touch of k blocks is O(k)
    vectorized numpy work, never an O(pool) sweep.
  * **ghost LRU** — a bounded recency list of keys whose blocks were
    *destroyed* (evicted outright, not demoted).  A key that comes back
    after destruction proves the eviction was a mistake, so the admission
    filter routes its fresh blocks to the fast tier even under pressure
    (and the miss is counted, which is the classic ARC-style signal).
"""

from __future__ import annotations

import math
from collections import OrderedDict

import numpy as np


class HotnessTracker:
    """Per-block decayed heat + ghost-LRU admission filter."""

    def __init__(
        self,
        n_blocks: int,
        half_life_s: float = 30.0,
        ghost_capacity: int = 8192,
    ):
        self.n_blocks = n_blocks
        self.half_life_s = half_life_s
        self._decay_rate = math.log(2.0) / max(half_life_s, 1e-9)
        self.heat = np.zeros(n_blocks, np.float64)
        self._last = np.zeros(n_blocks, np.float64)
        self.ghost_capacity = ghost_capacity
        self._ghost: OrderedDict[bytes, None] = OrderedDict()
        self.ghost_hits = 0

    # ------------------------------------------------------------------
    # decayed-access counters
    # ------------------------------------------------------------------
    def touch(self, block_ids, now: float, weight: float = 1.0) -> None:
        """Decay-to-now then bump: O(blocks touched)."""
        ids = np.asarray(block_ids, np.intp)
        if not len(ids):
            return
        dt = np.maximum(0.0, now - self._last[ids])
        self.heat[ids] = self.heat[ids] * np.exp(-self._decay_rate * dt) + weight
        self._last[ids] = now

    def heat_at(self, block_ids, now: float) -> np.ndarray:
        """Decayed heat without bumping (read-only view for the migrator)."""
        ids = np.asarray(block_ids, np.intp)
        if not len(ids):
            return np.zeros(0, np.float64)
        dt = np.maximum(0.0, now - self._last[ids])
        return self.heat[ids] * np.exp(-self._decay_rate * dt)

    def reset(self, block_ids) -> None:
        """Forget history for recycled blocks (fresh allocation)."""
        ids = np.asarray(block_ids, np.intp)
        if len(ids):
            self.heat[ids] = 0.0

    def move(self, src_ids, dst_ids) -> None:
        """Carry heat across a tier migration (the block moved, not the
        data's popularity)."""
        src = np.asarray(src_ids, np.intp)
        dst = np.asarray(dst_ids, np.intp)
        if not len(src):
            return
        self.heat[dst] = self.heat[src]
        self._last[dst] = self._last[src]
        self.heat[src] = 0.0

    def coldest(self, candidate_ids, k: int, now: float) -> np.ndarray:
        """k coldest candidates, coldest first. argpartition keeps the
        selection O(n + k log k) — the candidate set can be a whole tier."""
        ids = np.asarray(candidate_ids, np.intp)
        heats = self.heat_at(ids, now)
        if len(ids) > k:
            part = np.argpartition(heats, k)[:k]
            ids, heats = ids[part], heats[part]
        order = np.argsort(heats, kind="stable")
        return ids[order]

    def hottest(self, candidate_ids, k: int, now: float) -> np.ndarray:
        ids = np.asarray(candidate_ids, np.intp)
        heats = self.heat_at(ids, now)
        if len(ids) > k:
            part = np.argpartition(-heats, k)[:k]
            ids, heats = ids[part], heats[part]
        order = np.argsort(-heats, kind="stable")
        return ids[order]

    # ------------------------------------------------------------------
    # ghost-LRU admission filter
    # ------------------------------------------------------------------
    def ghost_add(self, keys: list[bytes]) -> None:
        """Record destroyed keys (wired to ``GlobalIndex.on_evict``)."""
        g = self._ghost
        for k in keys:
            g[k] = None
            g.move_to_end(k)
        while len(g) > self.ghost_capacity:
            g.popitem(last=False)

    def ghost_contains(self, key: bytes | None) -> bool:
        """Peek without consuming (placement may still clamp to spill)."""
        return key is not None and key in self._ghost

    def admit_hot(self, key: bytes | None) -> bool:
        """True iff the key was recently destroyed and has now returned —
        admit its fresh block to the fast tier even under pressure.
        Consumes the ghost entry: call only when the admission is honored."""
        if key is None or key not in self._ghost:
            return False
        del self._ghost[key]
        self.ghost_hits += 1
        return True

    def ghost_len(self) -> int:
        return len(self._ghost)

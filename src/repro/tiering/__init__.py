"""Tiered pool memory: hotness-tracked HBM ↔ CXL ↔ spill hierarchy.

The Beluga pool is the *middle* of a real memory hierarchy: HBM above it,
colder/cheaper capacity (far-NUMA DRAM over RDMA, SSD) below it.  This
package turns the flat ``BelugaPool`` into a hotness-managed hierarchy:

  * ``policy``   — vectorized decayed-access hotness tracker + ghost-LRU
                   admission filter (O(blocks touched) per update);
  * ``tiers``    — ``TieredPool``: fast CXL tier + spill tier behind the
                   existing allocate/retain/release/epoch API;
  * ``migrator`` — virtual-clock background engine demoting cold blocks
                   ahead of pressure and promoting re-hot ones in budgeted
                   batches, contending with foreground fetches through
                   ``fabric.DeviceQueues``;
  * ``stats``    — per-tier occupancy / hit / demotion / promotion counters.
"""

from repro.tiering.migrator import MigrationEngine
from repro.tiering.policy import HotnessTracker
from repro.tiering.stats import TierStats
from repro.tiering.tiers import TieredPool, TieringConfig

__all__ = [
    "HotnessTracker",
    "MigrationEngine",
    "TierStats",
    "TieredPool",
    "TieringConfig",
]

"""Beluga pool walkthrough: allocator, coherence epochs, CXL-RPC, transfers.

    PYTHONPATH=src python examples/pool_demo.py
"""

import numpy as np

from repro.core.coherence import CoherenceError, CoherentReader, CoherentWriter
from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.rpc import CxlRpcClient, CxlRpcServer, ShmRing
from repro.core.transfer import TransferEngine


def main():
    layout = PoolLayout(block_tokens=16, n_layers_kv=8, n_kv_heads=4, head_dim=32)
    pool = BelugaPool(layout, n_blocks=128, n_shards=16, backing="numpy")
    index = GlobalIndex(pool)
    xfer = TransferEngine(pool, mode="beluga")
    print(f"pool: 128 blocks x {layout.block_bytes//1024} KiB over 16 shards "
          f"({layout.n_fragments} fragments/block)")

    # writer: gather-write two prompt blocks, publish in the index
    prompt = list(range(32))
    blocks = pool.allocate(2)
    kv = np.random.default_rng(0).normal(
        size=(2, layout.n_fragments, 16, 4, 32)).astype(np.float16)
    epochs = xfer.gather_write(blocks, kv)
    for key, b, e in zip(index.keys_for(prompt), blocks, epochs):
        index.publish(key, b, e, 16)
    print(f"writer: packed 2 blocks ({2*layout.n_fragments} fragments) in "
          f"{xfer.stats.requests_issued} fused transfer; published")
    print(f"shard occupancy (interleaved): {pool.shard_occupancy()}")

    # reader: prefix match + epoch-validated scatter-read
    hits = index.match_prefix(prompt + [99] * 16)
    got = xfer.scatter_read([b for _, b, _ in hits], [e for _, _, e in hits])
    assert np.array_equal(got, kv)
    print(f"reader: matched {len(hits)} blocks, payload bit-exact")

    # CXL-RPC: the metadata service behind a shared-memory ring, speaking
    # the repro.core.wire binary protocol (length-framed variable payloads)
    from repro.core.wire import RpcIndexClient, make_index_handler

    ring = ShmRing(n_slots=32, payload_bytes=4096)
    server = CxlRpcServer(
        ring, make_index_handler(index, max_reply=ring.payload_bytes)
    ).start()
    client = CxlRpcClient(ring)
    remote = RpcIndexClient(client, block_tokens=16)
    remote_hits = remote.match_prefix(prompt)  # whole chain, ONE round-trip
    server.stop()
    assert remote_hits == hits  # same chain, same result, over the ring
    print(f"CXL-RPC match_prefix -> {len(remote_hits)} blocks in one trip "
          f"(modeled RTT {client.modeled_rtt()*1e6:.2f} us vs RDMA-RC 8.39 us)")

    # coherence: recycling a block invalidates readers holding its epoch
    w, r = CoherentWriter(pool), CoherentReader(pool)
    key, bid, epoch = hits[0]
    pool.retain([bid])
    pool.release([bid])
    pool.release([bid])  # refcount 0: recycled, epoch bumped
    try:
        r.read_block(bid, epoch)
        print("ERROR: stale read went undetected")
    except CoherenceError as e:
        print(f"coherence: stale read rejected ({e})")
    assert len(index.match_prefix(prompt)) == 0  # stale entry dropped too


if __name__ == "__main__":
    main()

"""Train a reduced model for a few hundred steps with checkpoint/restart.

    PYTHONPATH=src python examples/train_tiny.py [--steps 300] [--kill-at 150]

``--kill-at`` simulates a crash mid-run: the script then restarts from the
latest committed checkpoint and verifies the loss curve continues.
"""

import argparse
import os
import shutil

import jax

from repro.configs.base import RuntimeConfig
from repro.configs.registry import reduced_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import Model
from repro.training import optimizer as opt_lib
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainLoopConfig, run_train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--kill-at", type=int, default=0)
    ap.add_argument("--ckpt", default="/tmp/repro_train_tiny_ckpt")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt, ignore_errors=True)
    cfg = reduced_config(args.arch)
    model = Model(cfg, RuntimeConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32))
    opt_cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=20, total_steps=args.steps)
    data_cfg = DataConfig(seq_len=64, global_batch=4, vocab_size=cfg.vocab_size)

    def log(step, m):
        print(f"step {step:4d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}")

    loop = TrainLoopConfig(steps=args.kill_at or args.steps, log_every=25,
                           checkpoint_every=50, checkpoint_dir=args.ckpt)
    data = SyntheticLM(data_cfg)
    params, opt_state, hist = run_train_loop(
        model, opt_cfg, loop, iter(data), on_metrics=log
    )

    if args.kill_at:
        print(f"\n--- simulated crash at step {args.kill_at}; restarting ---")
        from repro.checkpoint.checkpointer import Checkpointer

        ck = Checkpointer(args.ckpt)
        step = ck.latest_step()
        print(f"latest committed checkpoint: step {step}")
        params0 = model.init(jax.random.key(0))
        opt0 = opt_lib.init_opt_state(opt_cfg, params0)
        tree = ck.restore(step, {"params": params0, "opt_state": opt0})
        data2 = SyntheticLM(data_cfg)
        data2.load_state_dict(ck.load_extra(step)["data_state"])
        loop2 = TrainLoopConfig(steps=args.steps, log_every=25,
                                checkpoint_every=50, checkpoint_dir=args.ckpt)
        run_train_loop(model, opt_cfg, loop2, iter(data2),
                       params=tree["params"], opt_state=tree["opt_state"],
                       start_step=step, on_metrics=log)
    print("done")


if __name__ == "__main__":
    main()

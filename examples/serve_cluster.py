"""End-to-end cluster serving driver (the paper's Exp #5 scenario).

16 LLM instances + shared Beluga pool serve batched long-context requests;
compares transfer modes and scheduling policies, then demonstrates elastic
scale-out and an instance failure mid-run.

    PYTHONPATH=src python examples/serve_cluster.py
"""

from benchmarks.common import lveval_requests, qwen32b_layout
from repro.serving.request import summarize
from repro.serving.scheduler import Cluster, ClusterConfig


def main():
    layout = qwen32b_layout()
    print(f"Qwen3-32B pool layout: {layout.n_fragments} fragments x "
          f"{layout.fragment_bytes//1024} KiB = {layout.block_bytes/2**20:.1f} MiB/block")

    print("\n--- populate vs cache-hit, three transfer modes ---")
    for mode, sbt in [("none", 0), ("rdma", 256), ("beluga", 0)]:
        cfg = ClusterConfig(n_engines=16, transfer_mode=mode,
                            pool_blocks=262144, super_block_tokens=sbt)
        c = Cluster(cfg, layout)
        for r in lveval_requests(128, 15000, 64):
            c.dispatch(r)
        s1 = c.run()
        t0 = max(e.clock for e in c.engines)
        for r in lveval_requests(128, 15000, 64, tag="h", arrival0=t0):
            c.dispatch(r)
        c.run()
        hits = [r for r in c.requests if r.req_id.startswith("h")]
        s2 = summarize(hits, max(x.t_done for x in hits) - t0)
        print(f"{mode:7s} populate TTFT {s1['avg_ttft_s']:6.2f}s QPS {s1['qps']:5.2f} | "
              f"cache-hit TTFT {s2['avg_ttft_s']:6.2f}s QPS {s2['qps']:6.2f}")

    print("\n--- elastic scaling + failure (no KV rebalancing needed) ---")
    cfg = ClusterConfig(n_engines=8, transfer_mode="beluga", pool_blocks=131072)
    c = Cluster(cfg, layout)
    for r in lveval_requests(64, 8000, 32):
        c.dispatch(r)
    for e in c.engines:
        e.advance(2.0)
    dead = c.remove_engine(3)
    print(f"killed engine 3 mid-run; requeued {len(dead)} in-flight requests")
    c.add_engine()
    c.add_engine()
    print("added 2 engines (scale-out); they serve pool hits immediately")
    stats = c.run()
    print(f"all done: {stats['n_done']}/64, avg TTFT {stats['avg_ttft_s']:.2f}s, "
          f"index hit-rate {stats['index']['hit_rate']:.2f}")


if __name__ == "__main__":
    main()

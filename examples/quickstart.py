"""Quickstart: build an assigned arch, train a step, prefill+decode.

    PYTHONPATH=src python examples/quickstart.py [--arch olmo-1b]
"""

import argparse
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import RuntimeConfig
from repro.configs.registry import reduced_config
from repro.models import Model
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    args = ap.parse_args()

    # reduced config: same topology as the full arch, CPU-sized
    cfg = reduced_config(args.arch)
    model = Model(cfg, RuntimeConfig(remat="none", attn_chunk_q=32, attn_chunk_kv=32,
                                     decode_kv="replicated"))
    params = model.init(jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    from repro.configs.registry import get_config

    full = get_config(args.arch)
    print(f"arch={cfg.name}: {n/1e6:.2f}M params "
          f"(full config: {full.param_count()/1e9:.1f}B)")

    # --- one training step ---
    tokens = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    opt_cfg = OptimizerConfig(warmup_steps=2, total_steps=100)
    opt_state = init_opt_state(opt_cfg, params)
    step = jax.jit(make_train_step(model, opt_cfg))
    params, opt_state, metrics = step(params, opt_state, batch)
    print(f"train step: loss={float(metrics['loss']):.3f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")

    # --- prefill + decode ---
    prompt = tokens[:, :48]
    logits, cache = jax.jit(functools.partial(model.prefill_fn, max_len=96))(
        params, {"tokens": prompt}
    )
    out = [int(jnp.argmax(logits[0]))]
    pos = prompt.shape[1]
    dec = jax.jit(model.decode_fn)
    for _ in range(8):
        logits, cache = dec(params, cache,
                            jnp.asarray([out[-1], out[-1]]),
                            jnp.asarray([pos, pos]))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    print(f"greedy decode: {out}")


if __name__ == "__main__":
    main()

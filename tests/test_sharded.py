"""Sharded-path integration tests (8 fake devices, subprocess-isolated so
the fake device count never leaks into the main test session)."""

import os
import subprocess
import sys
import textwrap

import pytest


def _run(code: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=900,
    )
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    assert "PASS" in out.stdout


HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools, dataclasses
import jax, jax.numpy as jnp
from repro.configs.registry import reduced_config
from repro.configs.base import RuntimeConfig
from repro.models import Model
from repro.distributed.sharding import AxisRules
from repro.launch.mesh import axis_types_kw
mesh = jax.make_mesh((2, 4), ("data", "model"), **axis_types_kw(2))
rules = AxisRules.create(mesh)
"""


@pytest.mark.slow  # subprocess model compiles: minutes
def test_sharded_train_and_interleaved_decode():
    _run(HEADER + textwrap.dedent("""
        rt = RuntimeConfig(remat="full", attn_chunk_q=16, attn_chunk_kv=16,
                           decode_kv="pool_interleaved")
        for arch in ["command-r-35b", "jamba-1.5-large-398b", "mamba2-2.7b"]:
            cfg = reduced_config(arch)
            m = Model(cfg, rt, rules)
            params = jax.jit(m.init, out_shardings=m.param_shardings())(jax.random.key(0))
            tokens = jnp.ones((4, 32), jnp.int32)
            with mesh:
                loss, _ = jax.jit(m.loss_fn)(params, {"tokens": tokens, "labels": tokens})
                assert bool(jnp.isfinite(loss)), arch
                cache = jax.jit(lambda: m.init_cache(4, 32),
                                out_shardings=m.cache_shardings(4, 32))()
                dec = jax.jit(functools.partial(
                    m.decode_fn, kv_shard_axes=("model",), kv_batch_axes=("data",)))
                logits, _ = dec(params, cache, tokens[:, 0], jnp.zeros((4,), jnp.int32))
                assert bool(jnp.isfinite(logits).all()), arch
        print("PASS")
        """))


@pytest.mark.slow
def test_interleaved_decode_matches_replicated():
    """The LSE-merge distributed flash-decode must equal the single-chip
    softmax over the full cache (numerical equivalence of Beluga O9)."""
    _run(HEADER + textwrap.dedent("""
        cfg = reduced_config("command-r-35b")
        params = Model(cfg, RuntimeConfig(remat="none")).init(jax.random.key(1))
        outs = {}
        for mode in ["replicated", "pool_interleaved"]:
            rt = RuntimeConfig(remat="none", decode_kv=mode)
            m = Model(cfg, rt, rules)
            with mesh:
                kv_axes = ("batch", "kv_seq") if mode == "pool_interleaved" else ("batch", None)
                sh = m.cache_shardings(4, 32, kv_axes)
                cache = jax.jit(lambda: m.init_cache(4, 32), out_shardings=sh)()
                # prefill a few tokens through decode steps
                dec = jax.jit(functools.partial(
                    m.decode_fn, kv_shard_axes=("model",), kv_batch_axes=("data",)))
                logits = None
                for t in range(6):
                    logits, cache = dec(params, cache,
                                        jnp.full((4,), t % 7, jnp.int32),
                                        jnp.full((4,), t, jnp.int32))
                outs[mode] = logits
        import numpy as np
        a = np.asarray(outs["replicated"], np.float32)
        b = np.asarray(outs["pool_interleaved"], np.float32)
        err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        assert err < 2e-2, err
        print("PASS", err)
        """))


@pytest.mark.slow
def test_a2a_moe_matches_einsum_dispatch():
    _run(HEADER + textwrap.dedent("""
        cfg = reduced_config("llama4-maverick-400b-a17b")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        tokens = jax.random.randint(jax.random.key(3), (4, 32), 0, cfg.vocab_size)
        outs = {}
        for mode in ["einsum", "a2a"]:
            rt = RuntimeConfig(remat="none", attn_chunk_q=16, attn_chunk_kv=16,
                               moe_dispatch=mode)
            m = Model(cfg, rt, rules)
            params = jax.jit(m.init, out_shardings=m.param_shardings())(jax.random.key(0))
            with mesh:
                loss, _ = jax.jit(m.loss_fn)(params, {"tokens": tokens, "labels": tokens})
            outs[mode] = float(loss)
        diff = abs(outs["einsum"] - outs["a2a"])
        assert diff < 5e-3, outs
        print("PASS", outs)
        """))


def test_production_mesh_shapes():
    from repro.launch.mesh import make_production_mesh  # noqa: F401

    # shape math only (cannot build 512 fake devices in-session)
    import inspect

    src = inspect.getsource(make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src.replace("'", '"')

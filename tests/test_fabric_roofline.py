"""Fabric cost model + roofline machinery: sanity and invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import fabric
from repro.core.fabric import DEFAULT, DeviceQueues


# ---------------------------------------------------------------------------
# cost-model monotonicity + paper-anchored orderings
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(size=st.integers(64, 1 << 24))
def test_latency_monotone_in_size(size):
    bigger = size * 2
    for fn in (
        lambda s: fabric.cpu_write_latency(s, "ntstore"),
        lambda s: fabric.cpu_read_latency(s, "clflush"),
        lambda s: fabric.gpu_transfer_latency(s, 1, "fused_kernel"),
        lambda s: fabric.rdma_transfer_latency(s, 1),
        lambda s: fabric.local_dram_latency(s),
    ):
        assert fn(bigger) >= fn(size)


def test_table4_orderings():
    KB16 = 16 * 1024
    # O1: ntstore < clflush-write << uncacheable-write
    assert (
        fabric.cpu_write_latency(KB16, "ntstore")
        < fabric.cpu_write_latency(KB16, "clflush")
        < fabric.cpu_write_latency(KB16, "uncacheable")
    )
    # CPU loads: clflush-before-read is the only viable path
    assert (
        fabric.cpu_read_latency(KB16, "clflush")
        < fabric.cpu_read_latency(KB16, "uncacheable")
    )


def test_fragmentation_hurts_rdma_not_beluga():
    size = 4 << 20
    frag1 = fabric.rdma_transfer_latency(size, 1)
    frag128 = fabric.rdma_transfer_latency(size, 128)
    assert frag128 > frag1  # sglist splitting costs requests
    fused1 = fabric.gpu_transfer_latency(size, 1, "fused_kernel")
    fused128 = fabric.gpu_transfer_latency(size, 128, "fused_kernel")
    assert fused1 == fused128  # one launch regardless of fragments (§6.1)


def test_device_queue_interleaving_beats_hotspot():
    """Under a skewed (hot-region) load, interleaving must finish earlier —
    without it the hot region's device serializes everything (paper §5.3)."""
    rng = np.random.default_rng(0)
    blocks = rng.integers(0, 4, size=200)  # hot: 4 of 64 regions
    outcomes = {}
    for inter in (True, False):
        q = DeviceQueues(n_devices=8, total_bytes=64 * DEFAULT.interleave_bytes)
        done = 0.0
        for i, b in enumerate(blocks):
            done = max(
                done,
                q.submit(i * 1e-6, int(b) * DEFAULT.interleave_bytes,
                         256 * 1024, inter),
            )
        outcomes[inter] = done
    assert outcomes[True] < outcomes[False]


# ---------------------------------------------------------------------------
# roofline helpers
# ---------------------------------------------------------------------------


def test_useful_bytes_model():
    from repro.launch.roofline import useful_bytes_per_dev

    rec = {"arch": "command-r-35b", "shape": "decode_32k", "n_chips": 256}
    ub = useful_bytes_per_dev(rec)
    # params bf16 once + the full KV cache once, per chip
    n = 32.4e9
    kv = 40 * 2 * 8 * 128 * 2 * 128 * 32768 / 256
    assert abs(ub - (2 * n / 256 + kv)) / ub < 0.1


def test_cell_builder_covers_all_kinds():
    """build_cell produces lowerable specs for each shape kind (structure
    only — the full lowering is exercised by the dry-run artifacts)."""
    from repro.configs.base import SHAPES
    from repro.launch.steps import _decode_axes
    from repro.configs.base import RuntimeConfig

    class _R:  # minimal AxisRules stand-in for _decode_axes
        class mesh:
            axis_names = ("data", "model")

        dp = 16
        rules = {"batch": ("data",)}

    rt = RuntimeConfig()
    kv_axes, shard_axes, b_axes = _decode_axes(_R, SHAPES["decode_32k"], rt)
    assert kv_axes == ("batch", "kv_seq") and shard_axes == ("model",)
    kv_axes, shard_axes, b_axes = _decode_axes(_R, SHAPES["long_500k"], rt)
    assert kv_axes == (None, "kv_seq_long")
    assert shard_axes == ("data", "model") and b_axes == ()
    rt2 = RuntimeConfig(decode_kv="replicated")
    kv_axes, shard_axes, _ = _decode_axes(_R, SHAPES["decode_32k"], rt2)
    assert shard_axes == ()


def test_collective_dtype_correction():
    """bf16-convert-consumed all-reduce counts at bf16 width."""
    from repro.launch.hlo_analysis import analyze_hlo

    hlo = """
HloModule m

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[128,64]) -> bf16[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%sum
  ROOT %cv = bf16[128,64]{1,0} convert(%ar)
}
"""
    res = analyze_hlo(hlo)
    assert res["collective_bytes"] == 128 * 64 * 2  # bf16, not f32

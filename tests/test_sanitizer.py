"""BELUGA_SANITIZE runtime lock-order sanitizer (static-analysis PR).

The ``SanitizedLock`` recorder is exercised two ways:

  * directly in-process (the recorder classes can be instantiated without
    the env flag): nested acquisition records an edge, an inverted
    nesting appends a violation, out-of-order release is legal;
  * end-to-end via subprocesses launched with ``BELUGA_SANITIZE=1`` and
    ``BELUGA_SANITIZE_LOG`` set, whose dumps are then validated with
    ``python -m tools.beluga_lint --check-lock-log`` against the static
    graph — consistent runs pass, an inverted nesting fails the check.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from repro.core import locks
from repro.core.locks import SanitizedLock

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_recorder():
    locks.reset()
    yield
    locks.reset()


# ---------------------------------------------------------------------------
# in-process recorder semantics
# ---------------------------------------------------------------------------
def test_nested_acquire_records_edge():
    a, b = SanitizedLock("t.A"), SanitizedLock("t.B")
    with a:
        with b:
            pass
    assert ("t.A", "t.B") in locks.recorded_edges()
    assert locks.violations() == []


def test_inverted_nesting_is_a_violation():
    a, b = SanitizedLock("t.A"), SanitizedLock("t.B")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    vs = locks.violations()
    assert len(vs) == 1
    assert vs[0]["edge"] == ["t.B", "t.A"]


def test_reacquire_same_name_is_not_an_edge():
    # two instances sharing a role (e.g. per-client slot locks) must not
    # produce a self-edge when one is held while the other is taken
    a1, a2 = SanitizedLock("t.A"), SanitizedLock("t.A")
    with a1:
        with a2:
            pass
    assert locks.recorded_edges() == []


def test_out_of_order_release_is_legal():
    a, b = SanitizedLock("t.A"), SanitizedLock("t.B")
    a.acquire()
    b.acquire()
    a.release()  # Lock allows non-LIFO release; stack must cope
    b.release()
    assert not a.locked() and not b.locked()
    # a fresh nesting afterwards still records correctly
    with a:
        with b:
            pass
    assert ("t.A", "t.B") in locks.recorded_edges()


def test_make_lock_registers_declaration():
    locks.make_lock("t.declared", blocking_ok=True)
    assert locks.declared_locks().get("t.declared") is True


def test_dump_shape(tmp_path):
    a, b = SanitizedLock("t.A"), SanitizedLock("t.B")
    with a:
        with b:
            pass
    out = tmp_path / "lock_order.0.json"
    locks.dump(str(out))
    payload = json.loads(out.read_text())
    assert payload["pid"] == os.getpid()
    assert ["t.A", "t.B"] in payload["edges"]
    assert payload["violations"] == []


# ---------------------------------------------------------------------------
# end-to-end: sanitized subprocess -> autodump -> --check-lock-log
# ---------------------------------------------------------------------------
def _run_sanitized(tmp_path, body: str) -> subprocess.CompletedProcess:
    script = tmp_path / "scenario.py"
    script.write_text(body)
    env = dict(os.environ)
    env["BELUGA_SANITIZE"] = "1"
    env["BELUGA_SANITIZE_LOG"] = str(tmp_path / "logs")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, str(script)], env=env, cwd=REPO,
        capture_output=True, text=True, timeout=180,
    )


def _check_lock_log(tmp_path) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-m", "tools.beluga_lint", "src",
         "--check-lock-log", str(tmp_path / "logs")],
        cwd=REPO, capture_output=True, text=True, timeout=180,
    )


REAL_WORKLOAD = """
from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, PoolLayout

layout = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)
pool = BelugaPool(layout, n_blocks=256, n_shards=8, backing="meta")
idx = GlobalIndex(pool)
tokens = list(range(64))
keys = idx.keys_for(tokens)
blocks = pool.allocate(len(keys))
idx.publish_many(keys, blocks, pool.write_blocks(blocks), 16)
# match_prefix validates epochs under the index lock: the canonical
# index._lock -> pool._lock edge of the static graph
assert idx.match_prefix(tokens)
"""


def test_sanitized_real_workload_consistent_with_static_graph(tmp_path):
    proc = _run_sanitized(tmp_path, REAL_WORKLOAD)
    assert proc.returncode == 0, proc.stderr
    dumps = os.listdir(tmp_path / "logs")
    assert dumps, "sanitizer did not autodump"
    payload = json.loads((tmp_path / "logs" / dumps[0]).read_text())
    assert ["index.GlobalIndex._lock", "pool.BelugaPool._lock"] \
        in payload["edges"]
    assert payload["violations"] == []
    check = _check_lock_log(tmp_path)
    assert check.returncode == 0, check.stdout + check.stderr


INVERTED_WORKLOAD = REAL_WORKLOAD + """
# a nesting the static graph forbids: pool._lock outer, index._lock inner
with pool._lock:
    with idx._lock:
        pass
"""


def test_sanitized_inversion_fails_lock_log_check(tmp_path):
    proc = _run_sanitized(tmp_path, INVERTED_WORKLOAD)
    assert proc.returncode == 0, proc.stderr
    check = _check_lock_log(tmp_path)
    assert check.returncode == 1, check.stdout
    assert "cycle" in check.stdout or "inversion" in check.stdout


def test_check_lock_log_reports_missing_dir(tmp_path):
    check = _check_lock_log(tmp_path)  # logs/ never created
    assert check.returncode == 1
    assert "no lock-order logs" in check.stdout

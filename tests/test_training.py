"""Training substrate: optimizer, data pipeline, checkpoint, fault tolerance."""

import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, SyntheticLM, make_dataset
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    StragglerPolicy,
    plan_elastic_remesh,
)
from repro.training import optimizer as opt_lib
from repro.training.optimizer import OptimizerConfig


def test_adamw_reduces_loss_on_quadratic():
    cfg = OptimizerConfig(peak_lr=0.1, warmup_steps=1, total_steps=100,
                          weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = opt_lib.init_opt_state(cfg, params)

    def loss_fn(p):
        return jnp.sum(jnp.square(p["w"]))

    losses = []
    for _ in range(60):
        g = jax.grad(loss_fn)(params)
        params, state = opt_lib.apply_updates(cfg, params, g, state)
        losses.append(float(loss_fn(params)))
    assert losses[-1] < 0.05 * losses[0]


def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1e-3, min_lr=1e-4, warmup_steps=10, total_steps=100)
    lrs = [float(opt_lib.lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9  # peak after warmup
    assert lrs[-1] <= lrs[1]
    assert lrs[-1] >= 1e-4 - 1e-9


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert abs(float(opt_lib.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 100.0


def test_grad_accumulation_matches_full_batch():
    from repro.configs.base import RuntimeConfig
    from repro.configs.registry import reduced_config
    from repro.models import Model
    from repro.training.train_loop import make_train_step

    cfg = reduced_config("olmo-1b")
    m = Model(cfg, RuntimeConfig(remat="none", attn_chunk_q=16, attn_chunk_kv=16))
    params = m.init(jax.random.key(0))
    opt_cfg = OptimizerConfig(warmup_steps=1, total_steps=10,
                              grad_compression="none")
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    }
    batch["labels"] = batch["tokens"]
    s1 = jax.jit(make_train_step(m, opt_cfg, accum_steps=1))
    s2 = jax.jit(make_train_step(m, opt_cfg, accum_steps=2))
    st0 = opt_lib.init_opt_state(opt_cfg, params)
    p1, _, m1 = s1(params, st0, batch)
    st0 = opt_lib.init_opt_state(opt_cfg, params)
    p2, _, m2 = s2(params, st0, batch)
    d = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
    )
    assert d < 2e-2, d  # bf16 params: one-ulp differences allowed


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_synthetic_data_deterministic_and_restartable():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=128)
    a = SyntheticLM(cfg)
    batches = [next(a) for _ in range(5)]
    state = a.state_dict()
    more = [next(a) for _ in range(3)]
    b = SyntheticLM(cfg)
    b.load_state_dict(state)
    replay = [next(b) for _ in range(3)]
    for x, y in zip(more, replay):
        assert np.array_equal(x["tokens"], y["tokens"])


def test_data_ranks_disjoint_union():
    full = SyntheticLM(DataConfig(seq_len=8, global_batch=8, dp_rank=0, dp_size=1))
    r0 = SyntheticLM(DataConfig(seq_len=8, global_batch=8, dp_rank=0, dp_size=2))
    r1 = SyntheticLM(DataConfig(seq_len=8, global_batch=8, dp_rank=1, dp_size=2))
    b0, b1 = next(r0), next(r1)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_packed_file_dataset(tmp_path):
    path = tmp_path / "corpus.bin"
    tokens = np.arange(16 * 32, dtype=np.int32)
    tokens.tofile(path)
    cfg = DataConfig(seq_len=32, global_batch=4, source="file", path=str(path))
    ds = make_dataset(cfg)
    b1 = next(ds)
    assert b1["tokens"].shape == (4, 32)
    state = ds.state_dict()
    b2 = next(ds)
    ds2 = make_dataset(cfg)
    ds2.load_state_dict(state)
    assert np.array_equal(next(ds2)["tokens"], b2["tokens"])


# ---------------------------------------------------------------------------
# checkpointing (incl. bf16 + commit semantics)
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_bf16(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {
        "w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.bfloat16),
        "m": jnp.asarray(np.random.default_rng(1).normal(size=(4, 8)), jnp.float32),
        "step": jnp.asarray(7, jnp.int32),
    }
    ck.save(10, tree, extra={"data_state": {"step": 3}})
    assert ck.latest_step() == 10
    got = ck.restore(10, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        assert jnp.array_equal(a, b)
    assert ck.load_extra(10)["data_state"]["step"] == 3


def test_checkpoint_uncommitted_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"x": jnp.ones((2,))})
    os.remove(os.path.join(ck.step_dir(5), "_COMMITTED"))  # simulate crash
    assert ck.latest_step() is None


def test_checkpoint_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save(s, {"x": jnp.full((2,), float(s))})
    assert ck.latest_step() == 3
    assert not os.path.exists(ck.step_dir(1))
    got = ck.restore(3, {"x": jnp.zeros((2,))})
    assert float(got["x"][0]) == 3.0


def test_train_resume_equivalence(tmp_path):
    """Fault-tolerance contract: crash + resume == uninterrupted run."""
    from repro.configs.base import RuntimeConfig
    from repro.configs.registry import reduced_config
    from repro.models import Model
    from repro.training.train_loop import (
        TrainLoopConfig,
        make_train_step,
        run_train_loop,
    )

    cfg = reduced_config("qwen1.5-0.5b")
    m = Model(cfg, RuntimeConfig(remat="none", attn_chunk_q=16, attn_chunk_kv=16))
    opt_cfg = OptimizerConfig(warmup_steps=2, total_steps=8,
                              grad_compression="none")
    data_cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=cfg.vocab_size)

    # uninterrupted 8 steps
    p_full, _, hist = run_train_loop(
        m, opt_cfg, TrainLoopConfig(steps=8, log_every=8), iter(SyntheticLM(data_cfg))
    )

    # 4 steps + checkpoint, then resume for 4 more
    ckdir = str(tmp_path / "ck")
    data = SyntheticLM(data_cfg)
    p_half, opt_half, _ = run_train_loop(
        m, opt_cfg,
        TrainLoopConfig(steps=4, log_every=4, checkpoint_every=4, checkpoint_dir=ckdir),
        iter(data),
    )
    ck = Checkpointer(ckdir)
    step = ck.latest_step()
    assert step == 4
    params0 = m.init(jax.random.key(0))
    opt0 = opt_lib.init_opt_state(opt_cfg, params0)
    restored = ck.restore(step, {"params": params0, "opt_state": opt0})
    data2 = SyntheticLM(data_cfg)
    data2.load_state_dict(ck.load_extra(step)["data_state"])
    p_res, _, _ = run_train_loop(
        m, opt_cfg, TrainLoopConfig(steps=8, log_every=8), iter(data2),
        params=restored["params"], opt_state=restored["opt_state"], start_step=4,
    )
    d = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res))
    )
    assert d < 2e-2, f"resume diverged from uninterrupted run by {d}"


# ---------------------------------------------------------------------------
# fault-tolerance policies
# ---------------------------------------------------------------------------


def test_heartbeat_detection():
    hb = HeartbeatMonitor(n_hosts=4, timeout_s=10.0)
    for h in range(4):
        hb.beat(h, now=0.0)
    hb.beat(2, now=50.0)
    assert set(hb.dead_hosts(now=55.0)) == {0, 1, 3}


def test_elastic_remesh_plan():
    plan = plan_elastic_remesh(
        (2, 16, 16), ("pod", "data", "model"), hosts_per_unit=4,
        failed_hosts=[3], checkpoint_step=1200,
    )
    assert plan.new_shape == (1, 16, 16)
    assert plan.degraded
    assert "1200" in plan.note


def test_straggler_policy():
    sp = StragglerPolicy(window=5, slow_factor=1.5)
    for step in range(5):
        for h in range(4):
            sp.record(h, 1.0 if h != 2 else 2.5)
    assert sp.stragglers() == [2]

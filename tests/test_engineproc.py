"""Zero-copy cross-process data plane: shared block storage + engine
worker processes + doorbell wakeups.

What is pinned here:

  * ``Doorbell`` — park/wake latency bounded by the wait ceiling, safe
    with no reader attached, FIFO unlinked by the creator only;
  * ``BelugaPool.share_data`` / ``SharedPoolData`` — stores on either
    side of the process boundary are the SAME bytes (zero-copy), and
    ``unshare_data`` copies back + unlinks;
  * ``PoolRpcClient`` — allocator ops over the ring, type-faithful
    ``OutOfPoolMemory``, atomic rollback of partially-chunked allocates,
    slot partitioning so N clients share one ring;
  * cluster parity — data_plane="shared" (in-process AND 1-worker) is
    bit-identical to the private reference, stats dict for stats dict;
  * lifecycle hygiene — segments + doorbell FIFOs all unlinked on
    close/__exit__/mid-construction failure/worker kill -9;
  * config gates — worker-mode prerequisites (and the LIFTED tiering
    gates: the tiered pool now rides the full production stack);
  * ``FaultInjector`` delay/drop now intercepts the pipelined
    post/collect split, not just serial ``call``.
"""

from __future__ import annotations

import os
import subprocess
import sys
import threading
import time
from multiprocessing import shared_memory

import numpy as np
import pytest

from repro.core.pool import BelugaPool, OutOfPoolMemory, PoolLayout
from repro.core.rpc import (
    CxlRpcClient,
    CxlRpcServer,
    RetryPolicy,
    ShmRing,
)
from repro.core.shm import Doorbell
from repro.core.shmpool import SharedPoolData, WorkerPoolView
from repro.core.wire import PoolRpcClient, make_pool_handler
from repro.serving.engineproc import partition_slots
from repro.serving.request import Request
from repro.serving.scheduler import Cluster, ClusterConfig

LAYOUT = PoolLayout(
    block_tokens=8, n_layers_kv=2, n_kv_heads=2, head_dim=8, dtype_bytes=2
)


def _segment_gone(name: str) -> bool:
    try:
        seg = shared_memory.SharedMemory(name=name)
    except FileNotFoundError:
        return True
    seg.close()
    return False


# ---------------------------------------------------------------------------
# Doorbell
# ---------------------------------------------------------------------------


def test_doorbell_wakes_parked_waiter_within_ceiling():
    db = Doorbell.create()
    try:
        db.open_read()
        woke = []

        def park():
            t0 = time.perf_counter()
            db.wait(5.0)  # ceiling far above the expected wake
            woke.append(time.perf_counter() - t0)

        t = threading.Thread(target=park)
        t.start()
        time.sleep(0.05)  # let it block in the FIFO read
        producer = Doorbell.attach(db.path)
        producer.ring()
        t.join(timeout=5)
        producer.close()
        assert woke and woke[0] < 1.0, woke  # rang, not timed out
    finally:
        db.close()
    assert not os.path.exists(db.path)


def test_doorbell_ring_with_no_reader_is_safe_and_attacher_never_unlinks():
    db = Doorbell.create()
    producer = Doorbell.attach(db.path)
    producer.ring()  # nobody listening: must not raise or block
    producer.ring()
    producer.close()
    assert os.path.exists(db.path)  # attach-side close never unlinks
    db.close()
    assert not os.path.exists(db.path)
    db.close()  # idempotent


# ---------------------------------------------------------------------------
# shared data segment
# ---------------------------------------------------------------------------


def test_share_data_zero_copy_both_directions():
    pool = BelugaPool(LAYOUT, n_blocks=64, n_shards=4, backing="numpy")
    spec = pool.share_data()
    view = SharedPoolData(spec)
    try:
        ids = pool.allocate(2)
        payload = np.arange(
            2 * LAYOUT.block_bytes, dtype=np.uint8
        ).reshape(2, -1)
        # attach-side store, owner-side load: same bytes, no copy hop
        eps = view.write_blocks(ids, payload)
        assert np.array_equal(pool.data[ids], payload)
        assert pool.validate_epochs(ids, eps).all()
        # owner-side store, attach-side load
        pool.data[ids[0]] ^= 0xFF
        got, eps2 = view.read_blocks([ids[0]])
        assert np.array_equal(got[0], payload[0] ^ 0xFF)
        assert int(eps2[0]) == eps[0]
    finally:
        view.close()
        # attach-side close must NOT unlink
        assert not _segment_gone(spec["data_shm_name"])
        pool.unshare_data()
        pool.unshare_meta()
    assert _segment_gone(spec["data_shm_name"])
    assert _segment_gone(spec["meta"]["shm_name"])


def test_unshare_data_copies_payloads_back():
    pool = BelugaPool(LAYOUT, n_blocks=16, n_shards=4, backing="numpy")
    spec = pool.share_data()
    view = SharedPoolData(spec)
    ids = pool.allocate(1)
    view.write_blocks(ids, np.full((1, LAYOUT.block_bytes), 7, np.uint8))
    view.close()
    pool.unshare_data()
    pool.unshare_meta()
    assert (pool.data[ids[0]] == 7).all()  # survived the unshare
    assert pool.validate_epoch(ids[0], int(pool.epochs[ids[0]]))


def test_share_data_requires_numpy_backing():
    pool = BelugaPool(LAYOUT, n_blocks=16, n_shards=4, backing="meta")
    with pytest.raises(ValueError, match="numpy"):
        pool.share_data()


# ---------------------------------------------------------------------------
# allocator over the ring
# ---------------------------------------------------------------------------


def _pool_service(pool, n_slots=16, payload=256):
    ring = ShmRing(n_slots=n_slots, payload_bytes=payload)
    srv = CxlRpcServer(
        ring, make_pool_handler(pool, max_reply=payload)
    ).start()
    return ring, srv


def test_pool_rpc_ops_and_type_faithful_oom():
    pool = BelugaPool(LAYOUT, n_blocks=32, n_shards=4, backing="numpy")
    ring, srv = _pool_service(pool)
    try:
        client = PoolRpcClient(CxlRpcClient(ring), pool.n_blocks,
                               max_payload=256)
        ids = client.allocate(4)
        assert pool.free_blocks() == 28 == client.free_blocks()
        client.retain(ids)
        client.release(ids)
        assert pool.refcounts[ids].tolist() == [1] * 4
        client.release(ids)
        assert pool.free_blocks() == 32
        with pytest.raises(OutOfPoolMemory):
            client.allocate(33)
    finally:
        srv.stop()


def test_pool_rpc_chunked_allocate_rolls_back_atomically():
    pool = BelugaPool(LAYOUT, n_blocks=32, n_shards=4, backing="numpy")
    ring, srv = _pool_service(pool, payload=64)  # tiny slots force chunks
    try:
        client = PoolRpcClient(CxlRpcClient(ring), pool.n_blocks,
                               max_payload=64)
        assert client._max_ids < 32  # the request below really chunks
        with pytest.raises(OutOfPoolMemory):
            client.allocate(40)  # some chunks succeed, then the well runs dry
        # atomicity: every block of the failed allocate was handed back
        assert pool.free_blocks() == 32
        assert client.allocate(32) and pool.free_blocks() == 0
    finally:
        srv.stop()


def test_slot_partitioning_shares_one_ring():
    assert partition_slots(10, 3) == [(0, 3), (3, 6), (6, 10)]
    with pytest.raises(ValueError, match=">= 2"):
        partition_slots(8, 5)
    pool = BelugaPool(LAYOUT, n_blocks=64, n_shards=4, backing="numpy")
    ring, srv = _pool_service(pool, n_slots=8)
    try:
        lo, hi = partition_slots(8, 2)[0]
        a = PoolRpcClient(
            CxlRpcClient(ring, slot_range=(lo, hi)), 64, max_payload=256
        )
        b = PoolRpcClient(
            CxlRpcClient(ring, slot_range=partition_slots(8, 2)[1]),
            64, max_payload=256,
        )
        got = []

        def worker(cl):
            for _ in range(20):
                ids = cl.allocate(2)
                cl.release(ids)
                got.extend(ids)

        ts = [threading.Thread(target=worker, args=(c,)) for c in (a, b)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert len(got) == 80 and pool.free_blocks() == 64
        with pytest.raises(ValueError):
            CxlRpcClient(ring, slot_range=(4, 3))
    finally:
        srv.stop()


def test_worker_pool_view_full_surface():
    pool = BelugaPool(LAYOUT, n_blocks=32, n_shards=4, backing="numpy")
    spec = pool.share_data()
    ring, srv = _pool_service(pool)
    try:
        view = WorkerPoolView(
            SharedPoolData(spec),
            PoolRpcClient(CxlRpcClient(ring), 32, max_payload=256),
        )
        assert view.is_tiered is False
        assert view.layout.block_bytes == LAYOUT.block_bytes
        ids = view.allocate(2)
        eps = view.write_blocks(
            ids, np.zeros((2, LAYOUT.block_bytes), np.uint8)
        )
        assert view.validate_epochs(ids, eps).all()
        assert pool.committed[ids].all()  # visible to the owner
        view.retain(ids)
        view.release(ids)
        assert pool.refcounts[ids].tolist() == [1, 1]
        view.release(ids)
        assert view.free_blocks() == 32
        view.close()
    finally:
        srv.stop()
        pool.unshare_data()
        pool.unshare_meta()


# ---------------------------------------------------------------------------
# cluster parity + worker mode
# ---------------------------------------------------------------------------


def _workload():
    rng = np.random.default_rng(3)
    base = rng.integers(0, 1000, 64).tolist()
    out = []
    for i in range(16):
        toks = (
            base + rng.integers(0, 1000, 24).tolist()
            if i % 2
            else rng.integers(0, 1000, 80).tolist()
        )
        out.append((f"r{i}", [int(t) for t in toks], 8, i * 0.03))
    return out


def _run_cluster(**kw):
    cfg = ClusterConfig(
        n_engines=kw.pop("n_engines", 1), policy="round_robin",
        pool_blocks=512, pool_shards=4, hbm_slots_per_engine=64,
        block_tokens=8, index_rpc=True, index_transport="process",
        index_shards=2, **kw,
    )
    with Cluster(cfg, LAYOUT, backing="numpy") as c:
        for rid, toks, nout, arr in _workload():
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        stats = c.run()
        names = c.shm_segment_names()
        paths = c.doorbell_paths()
    return stats, names, paths


def test_shared_data_plane_inprocess_bit_identical():
    ref, _, _ = _run_cluster(data_plane="private")
    shared, names, _ = _run_cluster(data_plane="shared")
    assert ref == shared  # FULL stats dict, index counters included
    assert ref["n_done"] == 16 and ref["hit_tokens"] > 0
    for n in names:
        assert _segment_gone(n), n


def test_worker_process_n1_bit_identical():
    """Acceptance: one engine worker OS process reproduces the private
    in-process run stat for stat — the process boundary is invisible."""
    ref, _, _ = _run_cluster(data_plane="private")
    w1, names, paths = _run_cluster(data_plane="shared", engine_processes=1)
    assert ref == w1
    for n in names:
        assert _segment_gone(n), n
    for p in paths:
        assert not os.path.exists(p), p


def test_worker_processes_n2_share_one_segment():
    cfg = ClusterConfig(
        n_engines=2, policy="round_robin", pool_blocks=512, pool_shards=4,
        hbm_slots_per_engine=64, block_tokens=8, index_rpc=True,
        index_transport="process", index_shards=2, data_plane="shared",
        engine_processes=2,
    )
    with Cluster(cfg, LAYOUT, backing="numpy") as c:
        assert len(c.workers) == 2
        for rid, toks, nout, arr in _workload():
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        stats = c.run()
        assert stats["n_done"] == 16
        # both workers really ran traffic, against the one shared pool
        per_worker = [w.stats_dict() for w in c.workers]
        assert all(ws["transfer"]["bytes_written"] > 0 for ws in per_worker)
        assert all(r.engine_id in (0, 1) for r in c.requests)
        assert {r.engine_id for r in c.requests} == {0, 1}
        names, paths = c.shm_segment_names(), c.doorbell_paths()
        assert len(names) == 7  # meta+data+pool ring+2 shard rings+2 cmd
    for n in names:
        assert _segment_gone(n), n
    for p in paths:
        assert not os.path.exists(p), p


def test_worker_mode_elastic_scaling_gated():
    cfg = ClusterConfig(
        n_engines=1, policy="round_robin", pool_blocks=256, pool_shards=4,
        hbm_slots_per_engine=32, block_tokens=8, index_rpc=True,
        index_transport="process", data_plane="shared", engine_processes=1,
    )
    with Cluster(cfg, LAYOUT, backing="numpy") as c:
        with pytest.raises(NotImplementedError, match="elastic"):
            c.add_engine()
        with pytest.raises(NotImplementedError, match="elastic"):
            c.remove_engine(0)


# ---------------------------------------------------------------------------
# config gates
# ---------------------------------------------------------------------------


def test_tiering_rides_the_full_production_stack():
    """Gate lifted (both PR-7 NotImplementedError walls are gone): the
    tiered pool is a first-class citizen of the cross-process planes.
    tiering + sharded process metadata + shared data plane + engine
    workers + selfheal is ONE legal cluster — it builds, serves traffic
    through worker processes (keyed alloc + demand touches over the
    allocator ring), migrates in the parent, and tears down leak-free."""
    from repro.tiering import TieringConfig

    cluster = Cluster(
        ClusterConfig(
            n_engines=2, engine_processes=2, policy="round_robin",
            data_plane="shared", index_rpc=True, index_transport="process",
            index_shards=4, selfheal=True, pool_blocks=256, pool_shards=4,
            hbm_slots_per_engine=32, block_tokens=8, journal_capacity=512,
            tiering=TieringConfig(enabled=True, spill_blocks=256),
        ),
        LAYOUT, backing="numpy",
    )
    try:
        assert cluster.migrator is not None
        assert "tiering" in cluster.pool.share_data()  # concatenated spec
        for rid, toks, nout, arr in _workload():
            cluster.dispatch(Request(rid, toks, nout, arrival=arr))
        stats = cluster.run()
        assert stats["n_done"] == 16
        tiering = stats["tiering"]
        assert tiering["fast_writes"] + tiering["spill_writes"] > 0
        assert stats["index"]["hits"] > 0  # prefix reuse across workers
        names, paths = cluster.shm_segment_names(), cluster.doorbell_paths()
        assert names and paths
    finally:
        cluster.close()
    for n in names:
        assert _segment_gone(n), n
    for p in paths:
        assert not os.path.exists(p), p


def test_data_plane_and_worker_config_gates():
    def cfg(**kw):
        return ClusterConfig(
            n_engines=1, pool_blocks=256, hbm_slots_per_engine=32, **kw
        )

    with pytest.raises(ValueError, match="private.*shared"):
        Cluster(cfg(data_plane="zero_copy"), LAYOUT)
    with pytest.raises(ValueError, match="backing='numpy'"):
        Cluster(cfg(data_plane="shared"), LAYOUT, backing="meta")
    with pytest.raises(ValueError, match="data_plane='shared'"):
        Cluster(cfg(engine_processes=1), LAYOUT, backing="numpy")
    with pytest.raises(ValueError, match="index_transport='process'"):
        Cluster(
            cfg(engine_processes=1, data_plane="shared"),
            LAYOUT, backing="numpy",
        )
    with pytest.raises(ValueError, match="must equal n_engines"):
        Cluster(
            cfg(engine_processes=2, data_plane="shared", index_rpc=True,
                index_transport="process"),
            LAYOUT, backing="numpy",
        )
    with pytest.raises(NotImplementedError, match="round_robin"):
        Cluster(
            cfg(engine_processes=1, data_plane="shared", index_rpc=True,
                index_transport="process", policy="cache_aware"),
            LAYOUT, backing="numpy",
        )


def test_selfheal_plus_workers_builds_and_tears_down_cleanly():
    """The combined config — supervised metadata shards AND supervised
    engine workers over the shared data plane — is legal (the PR-7 gate
    is gone), serves traffic, and leaks neither segments nor FIFOs."""
    cluster = Cluster(
        ClusterConfig(
            n_engines=2, engine_processes=2, policy="round_robin",
            data_plane="shared", index_rpc=True, index_transport="process",
            selfheal=True, pool_blocks=256, hbm_slots_per_engine=32,
            journal_capacity=512,
        ),
        LAYOUT, backing="numpy",
    )
    names = cluster.shm_segment_names()
    fifos = cluster.doorbell_paths()
    with cluster:
        from repro.serving.engineproc import EngineWorkerSupervisor

        assert all(
            isinstance(w, EngineWorkerSupervisor) for w in cluster.workers
        )
        for i in range(4):
            cluster.dispatch(Request(
                req_id=f"r{i}", tokens=list(range(24)), n_output=4,
                arrival=0.0,
            ))
        stats = cluster.run()
        assert stats["n_done"] == 4
        assert all(r.state == "done" for r in cluster.requests)
        assert stats["selfheal"]["worker_restarts"] == 0
    assert names and fifos
    assert all(_segment_gone(n) for n in names)
    assert all(not os.path.exists(p) for p in fifos)


def test_parked_worker_wakes_on_stop_without_a_doorbell_ring():
    """A worker parked on its command Doorbell whose POSTER dies can
    never be rung awake — the park must be a bounded poll (the
    ``doorbell_wait_s`` ceiling), so flipping CTRL_STOP alone, with no
    FIFO write, still gets the worker to exit cleanly and promptly."""
    cluster = Cluster(
        ClusterConfig(
            n_engines=1, engine_processes=1, policy="round_robin",
            data_plane="shared", index_rpc=True, index_transport="process",
            pool_blocks=256, hbm_slots_per_engine=32,
        ),
        LAYOUT, backing="numpy",
    )
    with cluster:
        host = cluster.workers[0]
        assert host.spec.doorbell_wait_s <= 0.1  # the wake bound's source
        time.sleep(0.2)  # idle long enough to be parked on the FIFO
        assert host.alive()
        from repro.core.rpc import CTRL_STOP

        t0 = time.perf_counter()
        host.ring.ctrl[CTRL_STOP] = 1  # ... with NO doorbell write
        host.proc.join(timeout=5.0)
        woke = time.perf_counter() - t0
        assert not host.alive(), "worker never woke from a dead doorbell"
        assert woke < 2.0, f"wake took {woke:.2f}s — unbounded park?"


# ---------------------------------------------------------------------------
# lifecycle hygiene under failure
# ---------------------------------------------------------------------------


def test_worker_boot_failure_leaks_nothing(monkeypatch):
    """A worker that never reaches CTRL_READY aborts construction; every
    segment and FIFO created before the failure must still be gone."""
    from repro.serving import engineproc

    seen: list = []
    real_ready = engineproc.EngineWorkerHost.wait_ready

    def failing_ready(self, timeout=20.0):
        seen.append(self)
        real_ready(self, timeout=5.0)
        return False  # claim the boot timed out

    monkeypatch.setattr(
        engineproc.EngineWorkerHost, "wait_ready", failing_ready
    )
    cfg = ClusterConfig(
        n_engines=1, policy="round_robin", pool_blocks=256, pool_shards=4,
        hbm_slots_per_engine=32, block_tokens=8, index_rpc=True,
        index_transport="process", data_plane="shared", engine_processes=1,
    )
    with pytest.raises(RuntimeError, match="failed to boot"):
        Cluster(cfg, LAYOUT, backing="numpy")
    assert seen  # the failure really happened at worker boot
    for host in seen:
        assert _segment_gone(host.ring.shm_name)
        if host.doorbell is not None:
            assert not os.path.exists(host.doorbell.path)
        assert not host.alive()


def test_worker_kill9_leaves_no_leaks():
    cfg = ClusterConfig(
        n_engines=1, policy="round_robin", pool_blocks=256, pool_shards=4,
        hbm_slots_per_engine=32, block_tokens=8, index_rpc=True,
        index_transport="process", data_plane="shared", engine_processes=1,
    )
    c = Cluster(cfg, LAYOUT, backing="numpy")
    names, paths = c.shm_segment_names(), c.doorbell_paths()
    assert names and paths
    c.workers[0].kill()  # SIGKILL: no atexit, no finally, nothing
    assert not c.workers[0].alive()
    c.close()
    for n in names:
        assert _segment_gone(n), n
    for p in paths:
        assert not os.path.exists(p), p


# ---------------------------------------------------------------------------
# FaultInjector: pipelined post/collect split
# ---------------------------------------------------------------------------


def test_fault_injector_intercepts_pipelined_rounds():
    from repro.core.index import GlobalIndex
    from repro.core.wire import RpcIndexClient, make_index_handler
    from repro.distributed.fault_tolerance import (
        FaultEvent,
        FaultInjector,
        FaultPlan,
    )

    pool = BelugaPool(LAYOUT, n_blocks=256, n_shards=4, backing="meta")
    index = GlobalIndex(pool)
    # tiny slots: a 64-key lookup splits into several chunks, which the
    # client ships through the pipelined post/collect split
    ring = ShmRing(n_slots=8, payload_bytes=256)
    srv = CxlRpcServer(ring, make_index_handler(index, max_reply=256)).start()
    try:
        rpc = CxlRpcClient(ring)
        client = RpcIndexClient(
            rpc, LAYOUT.block_tokens, max_payload=256,
            retry=RetryPolicy(base_backoff=0.05),
        )
        tokens = list(range(64 * LAYOUT.block_tokens))
        keys = client.keys_for(tokens)
        assert len(keys) == 64
        ids = pool.allocate(64)
        eps = pool.write_blocks(ids)
        client.publish_many(keys, ids, eps, len(tokens))
        inj = FaultInjector(
            FaultPlan([FaultEvent(t=0.0, kind="drop", duration=0.4)]),
            supervisors=[],
        ).start()
        inj.attach_client(0, rpc)
        t0 = time.perf_counter()
        got = client.lookup_many(keys)  # pipelined — and dropped at post
        assert time.perf_counter() - t0 > 0.2  # really sat out the window
        assert all(e is not None for e in got)
        # the drop flowed through the client's OWN retry machinery
        assert rpc.stats.retries >= 1
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# exp14 smoke under a HARD timeout (CI leg's in-repo twin)
# ---------------------------------------------------------------------------


def test_exp14_procengine_smoke_under_hard_timeout():
    """Runs the exp14 parity + sweep + chaos harness (tiny config) in a
    subprocess with a hard kill-timeout: a hung worker or service child
    fails this test in bounded time — the guard the CI smoke relies on."""
    code = (
        "from benchmarks.exp14_procengine import run\n"
        "rows = run(fast=True)\n"
        "assert any('bit_identical=True' in r[2] for r in rows), rows\n"
        "assert any('restarts=1' in r[2] for r in rows), rows\n"
        "print('SMOKE-PASS')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,  # HARD guard: hung child == fast failure
        # (raised from 240: run() now also drives the chaos drill —
        # worker kill + allocator rolling restart)
    )
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-3000:])
    assert "SMOKE-PASS" in out.stdout

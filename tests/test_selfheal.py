"""Self-healing metadata plane: journal, rebuild, supervisor, retry,
degrade, fault injection, and the kill -9 chaos gates (ISSUE 6).

Layered like the feature:

  * ``ShardJournal`` / ``live_entries``       — the flight recorder;
  * ``GlobalIndex.rebuild_from_journal``      — crash-restart replay;
  * OP_SNAPSHOT / OP_RESTORE                  — wire-level rebuild ops;
  * ``RetryPolicy`` + ``adopt_ring``          — client-side healing;
  * ``ShardSupervisor``                       — kill -9 -> respawn ->
    journal replay -> adopt, with bounded detection latency DECOUPLED
    from the service child's idle backoff;
  * degraded mode                             — sharded match holes +
    ``KVCacheManager`` absorbing plane outages (never raises to engine);
  * ``FaultPlan`` / ``FaultInjector``         — declarative chaos driven
    through the real retry machinery;
  * chaos differential gates                  — kill -9 mid-stream
    converges to the no-fault run (stale-free streams bit-identical;
    full streams complete with block conservation).
"""

from __future__ import annotations

import random
import time

import pytest

from repro.core import wire
from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.procserver import ProcessRpcServer, ShardSupervisor
from repro.core.rpc import (
    CxlRpcClient,
    CxlRpcServer,
    RetryPolicy,
    RpcError,
    ServiceDiedError,
    ShmRing,
)
from repro.core.shm import (
    JOURNAL_PUBLISH,
    JOURNAL_REMAP,
    JOURNAL_RETRACT,
    ShardJournal,
    live_entries,
)
from repro.distributed.fault_tolerance import (
    ElasticPlan,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    HeartbeatMonitor,
    StragglerPolicy,
    plan_elastic_remesh,
)

from tests.test_metadata_equivalence import Backend, make_ops, replay, _key

LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)
FAST_RETRY = RetryPolicy(max_retries=10, base_backoff=0.005, max_backoff=0.1)


def _k(i: int) -> bytes:
    return i.to_bytes(4, "little") * 4


def _segment_gone(name: str) -> bool:
    from repro.core.shm import attach_segment

    try:
        seg = attach_segment(name)
    except FileNotFoundError:
        return True
    seg.close()
    return False


# ---------------------------------------------------------------------------
# ShardJournal + live_entries
# ---------------------------------------------------------------------------
def test_journal_roundtrip_and_live_fold():
    j = ShardJournal.create(capacity=64)
    try:
        j.append_publish([_k(1), _k(2), _k(3)], [10, 11, 12], [1, 1, 1], 16)
        j.append_retract([11])
        j.append_publish([_k(1)], [13], [2], 16)  # re-publish: moves to end
        j.append_remap([_k(3)], [20], [5])
        recs = j.records()
        assert len(j) == 6 and len(recs) == 6
        assert recs[0] == (JOURNAL_PUBLISH, _k(1), 10, 1, 16)
        assert recs[3][0] == JOURNAL_RETRACT and recs[3][2] == 11
        assert recs[5] == (JOURNAL_REMAP, _k(3), 20, 5, -1)
        live = live_entries(recs)
        # key2 retracted; key3 remapped (keeps n_tokens); key1 re-published
        # LAST so it folds to the journal's MRU end
        assert live == {_k(3): (20, 5, 16), _k(1): (13, 2, 16)}
        assert list(live) == [_k(3), _k(1)]
    finally:
        j.close()


def test_journal_retract_removes_only_last_publisher():
    """A recycled block id must retract the CURRENT owner's row, not a
    stale alias that published the same id earlier — mirroring which row
    the live index actually dropped."""
    recs = [
        (JOURNAL_PUBLISH, _k(1), 10, 1, 16),
        (JOURNAL_PUBLISH, _k(2), 10, 2, 16),  # block 10 recycled to key2
        (JOURNAL_RETRACT, b"\0" * 16, 10, 0, 0),
    ]
    live = live_entries(recs)
    # key2 (last publisher) gone; key1's stale alias row survives, as in
    # the live index (match GCs it later, identically pre/post rebuild)
    assert _k(2) not in live and _k(1) in live


def test_journal_overflow_compacts_in_place():
    j = ShardJournal.create(capacity=8)
    try:
        for i in range(6):
            j.append_publish([_k(1)], [100 + i], [i], 16)
        j.append_publish([_k(2)], [200], [1], 16)
        assert len(j) == 7 and j.generation == 0
        # 2 more would exceed capacity -> compaction to the 2 live rows
        j.append_publish([_k(3), _k(4)], [300, 400], [1, 1], 16)
        assert j.generation == 1
        assert len(j) == 4  # 2 live survivors + 2 new
        live = live_entries(j.records())
        assert live[_k(1)] == (105, 5, 16) and _k(4) in live
    finally:
        j.close()


def test_journal_overflow_beyond_live_raises():
    j = ShardJournal.create(capacity=2)
    try:
        j.append_publish([_k(1), _k(2)], [1, 2], [1, 1], 16)
        with pytest.raises(RuntimeError, match="overflow"):
            j.append_publish([_k(3)], [3], [1], 16)
    finally:
        j.close()


def test_journal_attach_validates_capacity():
    j = ShardJournal.create(capacity=16)
    try:
        with pytest.raises(ValueError, match="capacity mismatch"):
            ShardJournal.attach(j.name, 32)
        j2 = ShardJournal.attach(j.name, 16)
        j2.close()
    finally:
        j.close()


# ---------------------------------------------------------------------------
# rebuild + snapshot/restore
# ---------------------------------------------------------------------------
def test_rebuild_from_journal_restores_observable_state():
    pool = BelugaPool(LAYOUT, n_blocks=256, n_shards=4, backing="meta")
    idx = GlobalIndex(pool)
    keys = [_key(0, i) for i in range(6)]
    blocks = pool.allocate(6)
    eps = pool.write_blocks(blocks)
    j = ShardJournal.create(capacity=64)
    try:
        idx.publish_many(keys, blocks, eps, 16)
        j.append_publish(keys, blocks, eps, 16)
        freed = idx.evict_blocks([blocks[2]])
        assert freed == [blocks[2]]
        j.append_retract(freed)
        # "crash": a brand-new index replays the journal
        rebuilt = GlobalIndex(pool)
        assert rebuilt.rebuild_from_journal(j.records()) == 5
        for i, k in enumerate(keys):
            if i == 2:
                assert rebuilt.lookup(k) is None
            else:
                ent = rebuilt.lookup(k)
                assert (ent.block_id, ent.epoch) == (blocks[i], eps[i])
        # the match path agrees with the pre-crash index: cut at the hole
        hits = rebuilt.match_prefix_keys(keys)
        assert [b for _, b, _ in hits] == blocks[:2]
    finally:
        j.close()


def test_snapshot_restore_ops_roundtrip_over_ring():
    """OP_SNAPSHOT pages the index in LRU order over a tiny ring (many
    pages) and OP_RESTORE rebuilds a fresh shard to the same entries."""
    pool = BelugaPool(LAYOUT, n_blocks=256, n_shards=4, backing="meta")
    idx = GlobalIndex(pool)
    ring = ShmRing(n_slots=4, payload_bytes=512)  # forces paging
    server = CxlRpcServer(
        ring, wire.make_index_handler(idx, max_reply=ring.payload_bytes)
    ).start()
    try:
        proxy = wire.RpcIndexClient(CxlRpcClient(ring), block_tokens=16)
        keys = [_key(1, i) for i in range(40)]
        blocks = pool.allocate(40)
        eps = pool.write_blocks(blocks)
        proxy.publish_many(keys, blocks, eps, 16)
        snap = proxy.snapshot_all()
        assert len(snap) == 40
        assert [k for k, *_ in snap] == keys  # LRU order = publish order
        # restore into a second, empty shard behind its own ring
        idx2 = GlobalIndex(pool)
        ring2 = ShmRing(n_slots=4, payload_bytes=512)
        server2 = CxlRpcServer(
            ring2, wire.make_index_handler(idx2, max_reply=ring2.payload_bytes)
        ).start()
        try:
            proxy2 = wire.RpcIndexClient(CxlRpcClient(ring2), block_tokens=16)
            n = proxy2.restore_entries(
                [k for k, *_ in snap],
                [b for _, b, _, _ in snap],
                [e for _, _, e, _ in snap],
                [t for *_, t in snap],
            )
            assert n == 40
            assert proxy2.snapshot_all() == snap
        finally:
            server2.stop()
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# retry + adopt_ring
# ---------------------------------------------------------------------------
def test_retry_policy_backoff_is_bounded_exponential():
    pol = RetryPolicy(max_retries=6, base_backoff=0.01, max_backoff=0.05)
    waits = [pol.backoff(a) for a in range(1, 7)]
    assert waits[:3] == [0.01, 0.02, 0.04]
    assert all(w <= 0.05 for w in waits[3:])


def test_adopt_ring_cuts_client_to_fresh_generation():
    pool = BelugaPool(LAYOUT, n_blocks=128, n_shards=4, backing="meta")
    spec = pool.share_meta()
    srv1 = ProcessRpcServer(spec, n_slots=8, payload_bytes=1 << 14).start()
    srv2 = None
    client = CxlRpcClient(srv1.ring, liveness=srv1.alive)
    try:
        assert srv1.wait_ready(10)
        proxy = wire.RpcIndexClient(
            client, block_tokens=16, retry=FAST_RETRY
        )
        keys = [_key(2, i) for i in range(3)]
        blocks = pool.allocate(3)
        proxy.publish_many(keys, blocks, pool.write_blocks(blocks), 16)
        srv1.kill()
        # dead generation: liveness turns the wait into ServiceDiedError,
        # and the retry budget here is too small to outlive the outage
        with pytest.raises((ServiceDiedError, RpcError)):
            wire.RpcIndexClient(
                client, block_tokens=16,
                retry=RetryPolicy(max_retries=1, base_backoff=0.001),
            ).lookup_many(keys)
        srv2 = ProcessRpcServer(spec, n_slots=8, payload_bytes=1 << 14).start()
        assert srv2.wait_ready(10)
        client.adopt_ring(srv2.ring, liveness=srv2.alive)
        assert client.stats.restarts == 1
        # fresh generation serves (empty index: no journal was replayed)
        assert proxy.lookup_many(keys) == [None, None, None]
    finally:
        srv1.close()
        if srv2 is not None:
            srv2.close()
        pool.unshare_meta()


# ---------------------------------------------------------------------------
# supervisor: kill -9 -> respawn -> journal replay -> adopt
# ---------------------------------------------------------------------------
def test_supervisor_restarts_and_replays_journal():
    pool = BelugaPool(LAYOUT, n_blocks=256, n_shards=4, backing="meta")
    spec = pool.share_meta()
    sup = ShardSupervisor(
        spec, journal_capacity=256, probe_interval=0.01,
        n_slots=8, payload_bytes=1 << 14,
    ).start()
    try:
        assert sup.wait_ready(10)
        client = CxlRpcClient(sup.ring, liveness=sup.server.alive)
        sup.register_client(client)
        proxy = wire.RpcIndexClient(
            client, block_tokens=16, journal=sup.journal, retry=FAST_RETRY,
            on_freed=pool.release,
        )
        keys = [_key(3, i) for i in range(8)]
        blocks = pool.allocate(8)
        eps = pool.write_blocks(blocks)
        proxy.publish_many(keys, blocks, eps, 16)
        freed = proxy.evict_blocks([blocks[5]])
        assert freed == [blocks[5]]
        before = [
            None if e is None else (e.block_id, e.epoch)
            for e in proxy.lookup_many(keys)
        ]
        served_before = sup.served
        sup.kill()
        # the NEXT op rides retry straight through detection + respawn +
        # replay + adopt_ring — no caller-visible failure
        after = [
            None if e is None else (e.block_id, e.epoch)
            for e in proxy.lookup_many(keys)
        ]
        assert after == before
        assert sup.restarts == 1
        assert client.stats.restarts == 1
        assert client.stats.retries >= 1
        # cumulative service counters span generations
        assert sup.served > served_before
        # zero lost / double-freed: every non-evicted block is still
        # owned by the rebuilt index, the evicted one is back in the pool
        assert pool.free_blocks() == 256 - 7
        names = sup.segment_names()
        assert len(names) == 3  # journal + live ring + 1 retired ring
    finally:
        sup.close()
        pool.unshare_meta()
    for n in names:
        assert _segment_gone(n), n


def test_warm_snapshot_restores_eviction_order_and_counters():
    """Journal rebuild alone replays in INSERTION order — recency and
    hit/miss counters die with the shard.  With a warm snapshot captured
    pre-crash, the respawned shard restores the snapshot's LRU order
    (so post-restart eviction picks the true LRU victim, not the oldest
    insert) and re-seeds the cumulative counters."""
    pool = BelugaPool(LAYOUT, n_blocks=256, n_shards=4, backing="meta")
    spec = pool.share_meta()
    sup = ShardSupervisor(
        spec, journal_capacity=256, probe_interval=0.01,
        n_slots=8, payload_bytes=1 << 14,
    ).start()
    try:
        assert sup.wait_ready(10)
        client = CxlRpcClient(sup.ring, liveness=sup.server.alive)
        sup.register_client(client)
        proxy = wire.RpcIndexClient(
            client, block_tokens=16, journal=sup.journal, retry=FAST_RETRY,
            on_freed=pool.release,
        )
        keys = [_key(9, i) for i in range(6)]
        blocks = pool.allocate(6)
        proxy.publish_many(keys, blocks, pool.write_blocks(blocks), 16)
        # re-touch the FIRST half: recency order now differs from
        # insertion order (3,4,5 are the LRU end, 0,1,2 the MRU end)
        assert len(proxy.match_prefix_keys(keys[:3])) == 3
        hits_before = proxy.stats()["hits"]
        assert hits_before >= 3
        assert sup.capture_snapshot()
        sup.kill()
        # the next op rides retry through respawn + journal rebuild +
        # warm-snapshot restore
        snap = proxy.snapshot_all()
        assert sup.restarts == 1
        assert [k for k, *_ in snap] == keys[3:] + keys[:3]
        # counters survived the restart (OP_SEED_STATS)
        assert proxy.stats()["hits"] == hits_before
        # and the next eviction picks the true LRU victim — the entry
        # insertion order would have spared
        assert proxy.evict_lru(1) == [blocks[3]]
        assert pool.free_blocks() == 256 - 5
    finally:
        sup.close()
        pool.unshare_meta()


def test_detection_latency_decoupled_from_idle_backoff():
    """The service child may idle-sleep arbitrarily long (satellite:
    configurable backoff ceiling) — crash DETECTION is the supervisor's
    probe alone, so restart latency stays bounded by probe + grace."""
    pool = BelugaPool(LAYOUT, n_blocks=64, n_shards=4, backing="meta")
    spec = pool.share_meta()
    sup = ShardSupervisor(
        spec, journal_capacity=64, probe_interval=0.01, grace=0.02,
        n_slots=8, payload_bytes=1 << 14,
        idle_spin_passes=1, idle_backoff_s=0.25,  # pathologically sleepy
    ).start()
    try:
        assert sup.wait_ready(10)
        assert sup.server.spec.idle_backoff_s == 0.25  # knob reaches child
        sup.kill()
        t0 = time.monotonic()
        deadline = t0 + 5.0
        while sup.restarts == 0 and time.monotonic() < deadline:
            time.sleep(0.005)
        detected = time.monotonic() - t0
        assert sup.restarts == 1, "crash never detected"
        # bound: probe+grace+respawn+replay — far below the 0.25 s idle
        # sleep times the ~200-pass spin the OLD fixed backoff implied,
        # and completely independent of idle_backoff_s
        assert detected < 3.0
    finally:
        sup.close()
        pool.unshare_meta()


def test_supervisor_gives_up_after_max_restarts():
    pool = BelugaPool(LAYOUT, n_blocks=64, n_shards=4, backing="meta")
    spec = pool.share_meta()
    sup = ShardSupervisor(
        spec, journal_capacity=64, probe_interval=0.005, max_restarts=2,
        n_slots=8, payload_bytes=1 << 14,
    ).start()
    try:
        assert sup.wait_ready(10)
        for _ in range(4):
            sup.kill()
            deadline = time.monotonic() + 5.0
            while time.monotonic() < deadline:
                if sup.restarts >= sup.max_restarts or sup.server.alive():
                    break
                time.sleep(0.005)
        time.sleep(0.05)  # give a runaway probe loop rope to hang itself
        assert sup.restarts == 2  # flapping shard: resuscitation capped
    finally:
        sup.close()
        pool.unshare_meta()


# ---------------------------------------------------------------------------
# degraded mode
# ---------------------------------------------------------------------------
def test_sharded_degrade_turns_dead_shard_into_holes():
    pool = BelugaPool(LAYOUT, n_blocks=256, n_shards=4, backing="meta")
    spec = pool.share_meta()
    servers = [
        ProcessRpcServer(spec, n_slots=8, payload_bytes=1 << 14).start()
        for _ in range(2)
    ]
    clients = [
        CxlRpcClient(s.ring, liveness=s.alive) for s in servers
    ]
    try:
        for s in servers:
            assert s.wait_ready(10)
        proxy = wire.ShardedRpcIndexClient(
            clients, 16, on_freed=pool.release,
            retry=RetryPolicy(max_retries=2, base_backoff=0.002),
            degrade=True,
        )
        keys = [_key(4, i) for i in range(12)]
        blocks = pool.allocate(12)
        proxy.publish_many(keys, blocks, pool.write_blocks(blocks), 16)
        full = proxy.match_prefix_keys(keys)
        assert len(full) == 12
        from repro.core.index import shard_of_key

        dead = shard_of_key(keys[0], 2)
        servers[dead].kill()  # NO supervisor: the shard stays down
        hits = proxy.match_prefix_keys(keys)
        # the dead shard's first position is a hole -> merged prefix cuts
        # before it; serving got a (possibly empty) prefix, not an error
        assert len(hits) < 12
        assert proxy.degraded_ops >= 1
        assert sum(c.stats.degraded_ops for c in clients) >= 1
        first_dead_pos = min(
            i for i, k in enumerate(keys) if shard_of_key(k, 2) == dead
        )
        assert len(hits) <= first_dead_pos
    finally:
        for s in servers:
            s.close()
        pool.unshare_meta()


def test_manager_degraded_mode_never_raises_to_engine():
    from repro.core.transfer import TransferEngine
    from repro.kvcache.hbm_cache import HbmPagedCache
    from repro.kvcache.manager import KVCacheManager

    pool = BelugaPool(LAYOUT, n_blocks=128, n_shards=4, backing="meta")

    class FlakyIndex(GlobalIndex):
        """In-process index whose REMOTE ops fail like a dead transport."""

        down = True

        def _die(self):
            if self.down:
                raise ServiceDiedError("injected outage")

        def match_prefix_keys(self, keys):
            self._die()
            return super().match_prefix_keys(keys)

        def filter_unpublished(self, keys):
            self._die()
            return super().filter_unpublished(keys)

        def publish_many(self, *a, **k):
            self._die()
            return super().publish_many(*a, **k)

        def evict_lru(self, *a, **k):
            self._die()
            return super().evict_lru(*a, **k)

    idx = FlakyIndex(pool)
    mgr = KVCacheManager(
        pool, idx, HbmPagedCache(64, 16), TransferEngine(pool),
        degraded_ok=True,
    )
    tokens = list(range(64))
    # match degrades to all-miss (full recompute), no exception
    plan = mgr.plan_fetch(tokens)
    assert plan.n_hit_tokens == 0 and plan.hit_blocks == []
    # writeback degrades to "skip offload", no exception, nothing leaked
    free0 = pool.free_blocks()
    assert mgr.writeback("s0", tokens) == 0
    assert pool.free_blocks() == free0
    assert mgr.stats.degraded_ops == 2
    # plane heals -> the same calls go remote again
    idx.down = False
    assert mgr.writeback("s0", tokens) == 4
    assert mgr.plan_fetch(tokens).n_hit_tokens == 64
    assert mgr.stats.degraded_ops == 2
    # without the opt-in, the fault propagates (strict mode unchanged)
    idx.down = True
    mgr2 = KVCacheManager(
        pool, idx, HbmPagedCache(64, 16), TransferEngine(pool),
    )
    with pytest.raises(ServiceDiedError):
        mgr2.plan_fetch(tokens)


def test_manager_degraded_publish_rolls_back_blocks():
    """A publish that dies AFTER the blocks were allocated must hand them
    back — an unpublished block the index never saw can never be evicted,
    so keeping it would leak pool memory on every outage-window write."""
    from repro.core.transfer import TransferEngine
    from repro.kvcache.hbm_cache import HbmPagedCache
    from repro.kvcache.manager import KVCacheManager

    pool = BelugaPool(LAYOUT, n_blocks=128, n_shards=4, backing="meta")

    class PublishDies(GlobalIndex):
        def publish_many(self, *a, **k):
            raise ServiceDiedError("injected outage")

    mgr = KVCacheManager(
        pool, PublishDies(pool), HbmPagedCache(64, 16), TransferEngine(pool),
        degraded_ok=True,
    )
    free0 = pool.free_blocks()
    assert mgr.writeback("s0", list(range(64))) == 0
    assert pool.free_blocks() == free0  # allocated blocks returned
    assert mgr.stats.degraded_ops == 1


# ---------------------------------------------------------------------------
# fault_tolerance policies (previously untested) + FaultPlan/FaultInjector
# ---------------------------------------------------------------------------
def test_heartbeat_monitor_grace_windows():
    mon = HeartbeatMonitor(n_hosts=3, timeout_s=10.0)
    mon.beat(0, now=0.0)
    mon.beat(1, now=5.0)
    # host 2 never beat; host 0 beyond grace at t=11
    assert mon.dead_hosts(now=11.0) == [0, 2]
    mon.beat(0, now=12.0)
    assert mon.dead_hosts(now=13.0) == [2]
    assert mon.dead_hosts(now=13.0 + 1e18) == [0, 1, 2]


def test_elastic_plan_shrinks_outer_dp_axis_only():
    plan = plan_elastic_remesh(
        (4, 2, 8), ("data", "fsdp", "model"), hosts_per_unit=1,
        failed_hosts=[0], checkpoint_step=100,
    )
    assert plan.new_shape == (3, 2, 8)
    assert plan.degraded and plan.restart_step == 100
    noop = plan_elastic_remesh(
        (4, 2, 8), ("data", "fsdp", "model"), 1, [], 100
    )
    assert noop.new_shape == (4, 2, 8) and not noop.degraded
    with pytest.raises(RuntimeError, match="all DP slices"):
        plan_elastic_remesh((1, 4), ("data", "model"), 1, [0], 0)
    assert ElasticPlan((2, 2), (2, 2), ("data", "model"), 0, "x").degraded is False


def test_straggler_policy_flags_slow_hosts():
    pol = StragglerPolicy(window=4, slow_factor=1.5)
    assert pol.stragglers() == []  # <2 hosts: no signal
    for t in (1.0, 1.1, 0.9, 1.0, 1.05):  # >window: oldest rolls off
        pol.record(0, t)
    for t in (1.0, 1.0, 1.1):
        pol.record(1, t)
    for t in (2.0, 2.2, 1.9):
        pol.record(2, t)
    assert pol.stragglers() == [2]
    assert len(pol.history[0]) == 4


def test_fault_plan_due_and_active_windows():
    plan = FaultPlan([
        FaultEvent(t=0.5, kind="kill", shard=1),
        FaultEvent(t=0.1, kind="delay", shard=0, duration=0.3, delay_s=0.01),
        FaultEvent(t=0.2, kind="drop", shard=0, duration=0.2),
    ])
    assert [e.t for e in plan.events] == [0.1, 0.2, 0.5]
    assert [e.kind for e in plan.due(0.25)] == ["delay", "drop"]
    assert plan.pending() == 1
    assert plan.due(0.25) == []  # one-way cursor
    assert {e.kind for e in plan.active(0, 0.3)} == {"delay", "drop"}
    assert plan.active(0, 0.45) == []  # both windows closed
    assert plan.active(1, 0.3) == []  # other shard untouched
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(t=0.0, kind="explode")


def test_fault_injector_kill_and_drop_through_retry():
    """Kills reach the supervisor; a drop window makes the wrapped client
    raise TimeoutError, which the wire client's OWN retry absorbs for
    idempotent ops once the window closes."""

    class FakeSup:
        kills = 0

        def kill(self):
            FakeSup.kills += 1

    pool = BelugaPool(LAYOUT, n_blocks=64, n_shards=4, backing="meta")
    idx = GlobalIndex(pool)
    ring = ShmRing(n_slots=4, payload_bytes=1 << 14)
    server = CxlRpcServer(
        ring, wire.make_index_handler(idx, max_reply=ring.payload_bytes)
    ).start()
    try:
        client = CxlRpcClient(ring)
        # virtual clock: the test advances time by hand
        clock = {"t": 0.0}
        inj = FaultInjector(
            FaultPlan([
                FaultEvent(t=0.0, kind="kill", shard=0),
                FaultEvent(t=1.0, kind="drop", shard=0, duration=1.0),
            ]),
            supervisors=[FakeSup()],
            clock=lambda: clock["t"],
        ).start()
        inj.attach_client(0, client)
        assert inj.advance() == [FaultEvent(t=0.0, kind="kill", shard=0)]
        assert FakeSup.kills == 1
        proxy = wire.RpcIndexClient(client, block_tokens=16)
        keys = [_key(5, i) for i in range(2)]
        assert proxy.lookup_many(keys) == [None, None]  # window not open
        clock["t"] = 1.5  # inside the drop window: replies "lost"
        with pytest.raises(TimeoutError, match="fault-injected"):
            proxy.lookup_many(keys)
        # with retry, the op outlives the window: backoff sleeps don't
        # advance the virtual clock, so close it by hand mid-retry
        retried = wire.RpcIndexClient(
            client, block_tokens=16,
            retry=RetryPolicy(max_retries=8, base_backoff=0.01),
        )
        import threading

        threading.Timer(0.03, lambda: clock.update(t=2.5)).start()
        assert retried.lookup_many(keys) == [None, None]
        assert client.stats.retries >= 1
    finally:
        server.stop()
        pool.unshare_meta()


# ---------------------------------------------------------------------------
# pipelined chunk rounds (satellite): equivalence at tiny payloads
# ---------------------------------------------------------------------------
def test_pipelined_pure_reads_match_serial_results():
    """A payload small enough to force MANY chunk rounds: the pipelined
    post/collect path for pure reads must return exactly what the served
    index answers in-process (order, None-holes, filter indices)."""
    pool = BelugaPool(LAYOUT, n_blocks=512, n_shards=8, backing="meta")
    idx = GlobalIndex(pool)
    ring = ShmRing(n_slots=8, payload_bytes=512)
    server = CxlRpcServer(
        ring, wire.make_index_handler(idx, max_reply=ring.payload_bytes)
    ).start()
    try:
        proxy = wire.RpcIndexClient(CxlRpcClient(ring), block_tokens=16)
        assert proxy._max_lookup < 40  # tiny chunks: rounds really happen
        keys = [_key(6, i) for i in range(180)]
        blocks = pool.allocate(120)
        eps = pool.write_blocks(blocks)
        proxy.publish_many(keys[:120], blocks, eps, 16)
        got = proxy.lookup_many(keys)
        want = idx.lookup_many(keys)
        assert [
            None if e is None else (e.block_id, e.epoch, e.n_tokens)
            for e in got
        ] == [
            None if e is None else (e.block_id, e.epoch, e.n_tokens)
            for e in want
        ]
        assert proxy.filter_unpublished(keys) == idx.filter_unpublished(keys)
        [unowned] = pool.allocate(1)  # valid id, never published
        found = proxy.owners_of(blocks + [unowned])
        assert found == idx.owners_of(blocks + [unowned])
        # and the serial paths on the same proxy still agree (match must
        # NOT pipeline: LRU touch order is part of its contract)
        hits = proxy.match_prefix_keys(keys)
        assert [b for _, b, _ in hits] == blocks
    finally:
        server.stop()
        pool.unshare_meta()


# ---------------------------------------------------------------------------
# chaos differential gates (the merge gate from the issue)
# ---------------------------------------------------------------------------
class SupervisedBackend(Backend):
    """Differential-harness backend: process transport behind
    ``ShardSupervisor``s with journals + retry — the self-healing
    deployment, with a ``kill`` chaos hook."""

    def __init__(self, n_shards: int, degrade: bool = False):
        self.kind = "supervised"
        self.pool = BelugaPool(LAYOUT, n_blocks=4096, n_shards=8, backing="meta")
        self._servers = []
        spec = self.pool.share_meta()
        self.sups = [
            ShardSupervisor(
                spec, journal_capacity=4096, probe_interval=0.01,
                n_slots=8, payload_bytes=1 << 14,
            ).start()
            for _ in range(n_shards)
        ]
        clients = []
        for sup in self.sups:
            assert sup.wait_ready(10)
            cl = CxlRpcClient(sup.ring, liveness=sup.server.alive)
            sup.register_client(cl)
            clients.append(cl)
        self.view = wire.ShardedRpcIndexClient(
            clients, LAYOUT.block_tokens, on_freed=self.pool.release,
            journals=[s.journal for s in self.sups],
            retry=RetryPolicy(max_retries=12, base_backoff=0.01,
                              max_backoff=0.2),
            degrade=degrade,
        )

    def kill(self, shard: int = 0) -> None:
        self.sups[shard].kill()

    def close(self) -> None:
        for sup in self.sups:
            sup.close()
        self.pool.unshare_meta()


def test_chaos_differential_stale_free_stream_bit_identical():
    """Kill -9 mid-stream; retry + supervisor + journal replay must make
    the fault INVISIBLE: observations bit-identical to the no-fault run
    (stale-free streams — no evictions, so no 'modulo')."""
    ops = make_ops(random.Random(17), 24, staleness=False)
    half = len(ops) // 2
    with Backend("inproc", 3) as ref:
        want = replay(ref, ops[:half]) + replay(ref, ops[half:])
    with SupervisedBackend(3) as b:
        got = replay(b, ops[:half])
        b.kill(0)
        got += replay(b, ops[half:])
        assert b.sups[0].restarts == 1
        assert b.view.rpcs[0].stats.restarts == 1
    assert got == want


def test_chaos_differential_full_stream_conserves_blocks():
    """Full op set (evictions, remap, stale holes) under kill -9: the
    stream must COMPLETE (no error reaches the driver), every block must
    end up either free or owned by exactly one valid index entry, and
    post-recovery lookups must agree with the plane's own final state —
    the no-fault run modulo eviction victims, which the rebuilt LRU
    order may legitimately reorder."""
    ops = make_ops(random.Random(23), 30)
    half = len(ops) // 2
    # no-fault supervised reference: same deployment, same split, no kill
    with SupervisedBackend(3) as ref:
        replay(ref, ops[:half])
        replay(ref, ops[half:])
        ref_free = ref.pool.free_blocks()
    with SupervisedBackend(3) as b:
        replay(b, ops[:half])
        b.kill(0)
        obs = replay(b, ops[half:])
        assert b.sups[0].restarts == 1
        assert obs  # stream ran to completion through the outage
        # conservation: the kill freed/lost no block the no-fault run
        # kept (a lost block would lower free_blocks, a double-free
        # trips the pool's own assertions before we ever get here; the
        # COUNT matches because rebuilt-LRU eviction may pick different
        # victims but frees the same quota)
        assert b.pool.free_blocks() == ref_free
        # self-consistency after recovery: a fresh match over a published
        # doc returns exactly its surviving entries
        for doc in range(4):
            keys = [_key(doc, i) for i in range(8)]
            hits = b.view.match_prefix_keys(keys)
            looked = b.view.lookup_many([k for k, _, _ in hits])
            assert [
                (e.block_id, e.epoch) for e in looked
            ] == [(bid, ep) for _, bid, ep in hits]


def test_chaos_kill_during_outage_heavy_write_load():
    """Publishes landing DURING the outage must either fail-soft or land
    exactly once — after recovery the journal-rebuilt shard and the pool
    agree block for block (the zero lost / zero double-freed gate)."""
    with SupervisedBackend(2) as b:
        pool, view = b.pool, b.view
        all_blocks = []
        for doc in range(3):
            keys = [_key(doc, i) for i in range(8)]
            blocks = pool.allocate(8)
            view.publish_many(keys, blocks, pool.write_blocks(blocks), 16)
            all_blocks += blocks
            if doc == 0:
                b.kill(1)  # crash while the write load keeps coming
        for doc in range(3):
            keys = [_key(doc, i) for i in range(8)]
            hits = view.match_prefix_keys(keys)
            assert len(hits) == 8, f"doc {doc} lost entries"
        assert pool.free_blocks() == 4096 - 24
        assert b.sups[1].restarts == 1


@pytest.mark.slow
def test_chaos_smoke_subprocess_isolated():
    """CI chaos smoke with hard timeout: the exp11 chaos sweep (kill -9
    one supervised shard mid-load) runs in a SUBPROCESS so a hung child
    can't stall the suite; asserts actual recovery."""
    import json
    import subprocess
    import sys

    code = (
        "import json;"
        "from benchmarks.exp11_rpc import chaos_sweep;"
        "print(json.dumps(chaos_sweep(2048, True)))"
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=120,
        cwd=".", env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert out.returncode == 0, out.stderr[-2000:]
    ch = json.loads(out.stdout.strip().splitlines()[-1])
    assert ch["restarts"] >= 1
    assert ch["recovery_s"] is not None and ch["recovery_s"] < 30
    assert ch["post_recovery_keys_per_s"] > 0

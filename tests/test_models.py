"""Per-arch smoke tests (REQUIRED): every assigned architecture instantiates
a reduced config of the same family and runs one forward/train step on CPU,
asserting output shapes + no NaNs. Plus prefill/decode consistency."""

import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import RuntimeConfig, SHAPES, shape_applicable
from repro.configs.registry import ASSIGNED, get_config, reduced_config
from repro.models import Model
from repro.models import transformer as stack_lib
from repro.models.layers import norm_apply, unembed_apply

RT = RuntimeConfig(remat="none", attn_chunk_q=16, attn_chunk_kv=16,
                   decode_kv="replicated")


def _batch(cfg, b=2, s=32):
    key = jax.random.key(9)
    if cfg.frontend == "audio_stub":
        return {
            "frame_embeds": jax.random.normal(key, (b, s, cfg.d_model), jnp.bfloat16),
            "labels": jnp.ones((b, s), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        npat = cfg.n_frontend_tokens
        return {
            "tokens": jnp.ones((b, s - npat), jnp.int32),
            "patch_embeds": jax.random.normal(key, (b, npat, cfg.d_model), jnp.bfloat16),
            "labels": jnp.ones((b, s - npat), jnp.int32),
        }
    return {
        "tokens": jnp.ones((b, s), jnp.int32),
        "labels": jnp.ones((b, s), jnp.int32),
    }


def _arch_params(archs):
    """jamba's reduced config is by far the heaviest compile (~1 min for
    the train-step smoke alone): keep it out of tier-1, behind -m slow."""
    return [
        pytest.param(a, marks=pytest.mark.slow)
        if a == "jamba-1.5-large-398b"
        else a
        for a in archs
    ]


@pytest.mark.parametrize("arch", _arch_params(sorted(ASSIGNED)))
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    m = Model(cfg, RT)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg)

    loss, aux = jax.jit(m.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # one full train step (grad + adamw)
    from repro.training.optimizer import OptimizerConfig, init_opt_state
    from repro.training.train_loop import make_train_step

    opt_cfg = OptimizerConfig(warmup_steps=1, total_steps=10)
    opt_state = init_opt_state(opt_cfg, params)
    step = jax.jit(make_train_step(m, opt_cfg))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_arch_smoke_decode_shapes(arch):
    cfg = reduced_config(arch)
    m = Model(cfg, RT)
    params = m.init(jax.random.key(0))
    b, max_len = 2, 32
    cache = m.init_cache(b, max_len)
    logits, cache2 = jax.jit(m.decode_fn)(
        params, cache, jnp.ones((b,), jnp.int32), jnp.zeros((b,), jnp.int32)
    )
    assert logits.shape == (b, cfg.padded_vocab(1))
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite decode logits"


@pytest.mark.parametrize(
    "arch",
    _arch_params(["olmo-1b", "command-r-35b", "mamba2-2.7b", "jamba-1.5-large-398b"]),
)
def test_prefill_decode_matches_full_forward(arch):
    import dataclasses

    cfg = reduced_config(arch)
    if cfg.moe.enabled:  # avoid capacity-drop divergence
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    m = Model(cfg, RT)
    params = m.init(jax.random.key(1))
    b, s = 2, 31
    tokens = jax.random.randint(jax.random.key(2), (b, s + 1), 0, cfg.vocab_size)
    x, positions = m.embed(params, {"tokens": tokens})
    h, _, _ = stack_lib.forward_full(params, x, positions, cfg, m.runtime, None)
    h = norm_apply(params["final_ln"], h, cfg)
    want = unembed_apply(params["embed"], h[:, s - 1 : s, :], None)[:, 0]

    logits_pre, cache = jax.jit(functools.partial(m.prefill_fn, max_len=32))(
        params, {"tokens": tokens[:, : s - 1]}
    )
    got, _ = jax.jit(m.decode_fn)(
        params, cache, tokens[:, s - 1], jnp.full((b,), s - 1, jnp.int32)
    )
    rel = float(jnp.max(jnp.abs(want - got))) / (
        float(jnp.max(jnp.abs(want))) + 1e-9
    )
    assert rel < 2e-2, f"{arch}: prefill+decode diverges from full forward ({rel})"


def test_long_500k_applicability_matrix():
    """The skip matrix in DESIGN.md §5 must match shape_applicable."""
    runnable = {
        a for a in ASSIGNED
        if shape_applicable(get_config(a), SHAPES["long_500k"])[0]
    }
    assert runnable == {"jamba-1.5-large-398b", "mamba2-2.7b"}


def test_param_counts_match_published():
    expect = {
        "jamba-1.5-large-398b": 398e9,
        "arctic-480b": 480e9,
        "mamba2-2.7b": 2.7e9,
        "olmo-1b": 1.2e9,
        "command-r-35b": 35e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.15, (arch, got, n)


def test_fp8_kv_cache_decode_parity():
    """fp8-e4m3 KV cache (RuntimeConfig.use_fp8_kv) halves cache bytes and
    stays within quantization tolerance of the bf16 cache decode."""
    import dataclasses

    cfg = reduced_config("command-r-35b")
    outs = {}
    for fp8 in (False, True):
        rt = dataclasses.replace(RT, use_fp8_kv=fp8)
        m = Model(cfg, rt)
        params = m.init(jax.random.key(1))
        cache = m.init_cache(2, 32)
        if fp8:
            assert jax.tree.leaves(cache)[0].dtype == jnp.float8_e4m3fn
        dec = jax.jit(m.decode_fn)
        logits = None
        for t in range(6):
            logits, cache = dec(params, cache,
                                jnp.full((2,), t % 5, jnp.int32),
                                jnp.full((2,), t, jnp.int32))
        outs[fp8] = logits
    a = np.asarray(outs[False], np.float32)
    b = np.asarray(outs[True], np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < 0.1, rel


def test_fp8_kv_prefill_then_decode():
    import dataclasses
    import functools

    cfg = reduced_config("olmo-1b")
    rt = dataclasses.replace(RT, use_fp8_kv=True)
    m = Model(cfg, rt)
    params = m.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(2), (2, 24), 0, cfg.vocab_size)
    logits, cache = jax.jit(functools.partial(m.prefill_fn, max_len=32))(
        params, {"tokens": tokens}
    )
    assert jax.tree.leaves(cache)[0].dtype == jnp.float8_e4m3fn
    out, _ = jax.jit(m.decode_fn)(
        params, cache, tokens[:, -1], jnp.full((2,), 24, jnp.int32)
    )
    assert bool(jnp.isfinite(out).all())

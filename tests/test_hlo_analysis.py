"""Roofline HLO analyzer: validated against analytically-known programs."""

import subprocess
import sys
import textwrap


def test_analyzer_counts_scan_trip_counts():
    """A 10-layer scan of known matmuls on 8 fake devices: analyzer flops
    must match the analytic per-device count within 5% (XLA's own
    cost_analysis undercounts ~10x here). Runs in a subprocess so the fake
    device count never leaks into this test session."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_analysis import analyze_hlo

        from repro.launch.mesh import axis_types_kw
        mesh = jax.make_mesh((2, 4), ("data", "model"), **axis_types_kw(2))
        def body(x, w):
            def layer(h, wl):
                h = jnp.tanh(h @ wl)
                h = jax.lax.with_sharding_constraint(
                    h, NamedSharding(mesh, P("data", None, "model")))
                return h, None
            x, _ = jax.lax.scan(layer, x, w)
            return x.sum()

        B, S, D, L = 8, 16, 256, 10
        x = jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16)
        w = jax.ShapeDtypeStruct((L, D, D), jnp.bfloat16)
        with mesh:
            f = jax.jit(body, in_shardings=(
                NamedSharding(mesh, P("data", None, "model")),
                NamedSharding(mesh, P(None, None, "model"))))
            c = f.lower(x, w).compile()
        res = analyze_hlo(c.as_text())
        expected = 2 * (B//2) * S * D * (D//4) * L
        ratio = res["flops"] / expected
        assert 0.95 < ratio < 1.10, (res["flops"], expected)
        assert res["collective_counts"].get("all-gather", 0) >= L
        assert res["unknown_trip_loops"] == 0
        print("OK", ratio)
        """
    )
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_analyzer_on_plain_text():
    from repro.launch.hlo_analysis import analyze_hlo

    hlo = """
HloModule test

ENTRY %main (p0: f32[128,256], p1: f32[256,64]) -> f32[128,64] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = f32[256,64]{1,0} parameter(1)
  ROOT %dot.1 = f32[128,64]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    res = analyze_hlo(hlo)
    assert res["flops"] == 2 * 128 * 256 * 64
    # bytes: read both operands + write output
    assert res["bytes_accessed"] == 4 * (128 * 256 + 256 * 64 + 128 * 64)


def test_dryrun_artifacts_are_complete():
    """The committed dry-run results must cover every runnable cell on both
    production meshes (deliverable e) with zero errors."""
    import glob
    import json
    import os

    recs = []
    for f in glob.glob("results/dryrun/*.json"):
        with open(f) as fh:
            recs.append(json.load(fh))
    if not recs:
        import pytest

        pytest.skip("no dry-run artifacts present")
    from repro.configs.registry import ASSIGNED

    base = [
        r
        for r in recs
        if r.get("tag", "baseline") == "baseline" and r.get("arch") in ASSIGNED
    ]
    by_status = {}
    for r in base:
        by_status.setdefault(r["status"], []).append(r["cell"])
    assert not by_status.get("error"), by_status.get("error")
    # 10 archs x 4 shapes x 2 meshes = 80; 8 archs skip long_500k on each mesh
    assert len(by_status.get("ok", [])) >= 64
    assert len(by_status.get("skipped", [])) == 16

"""Cross-process metadata plane: torture + lifecycle (ISSUE-5 tentpole).

Covers:
  * shared-memory ``ShmRing`` create/attach round-trip (two mappings of
    one segment really alias);
  * wire-codec fuzz frames injected through REAL shared memory: the
    service process answers RESP_ERROR in-band and keeps serving;
  * slot exhaustion + timeout quarantine against a deliberately SLOW
    service process, with full recovery once it catches up;
  * kill -9 of the service process: clients get ``RpcStats.errors`` plus
    a raised ``RpcError`` FAST — not a hang, not a silent timeout-burn;
  * cluster lifecycle hygiene: ``index_transport="process"`` clusters
    unlink every named segment on ``close()``/``__exit__`` AND when the
    constructor dies half-way (no leaked /dev/shm entries);
  * thread-vs-process cluster parity: virtual-time exp05-style summary
    stats identical transport-for-transport (acceptance criterion);
  * a subprocess-isolated exp11 process-transport smoke with a HARD
    timeout, so a hung service child fails the suite fast instead of
    stalling it (the CI guard).
"""

from __future__ import annotations

import os
import random
import subprocess
import sys
import time
from multiprocessing import shared_memory

import numpy as np
import pytest

from repro.core import wire
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.procserver import ProcessRpcServer, SharedPoolMeta
from repro.core.rpc import (
    REQ_READY,
    RESP_READY,
    CxlRpcClient,
    RpcError,
    ShmRing,
)
from repro.serving.request import Request
from repro.serving.scheduler import Cluster, ClusterConfig

LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)


def _pool(n_blocks=2048):
    return BelugaPool(LAYOUT, n_blocks=n_blocks, n_shards=8, backing="meta")


def _segment_gone(name: str) -> bool:
    try:
        seg = shared_memory.SharedMemory(name=name)
    except FileNotFoundError:
        return True
    seg.close()
    return False


def _server(pool, **kw) -> tuple[ProcessRpcServer, CxlRpcClient]:
    srv = ProcessRpcServer(pool.share_meta(), **kw).start()
    return srv, CxlRpcClient(srv.ring, liveness=srv.alive)


# ---------------------------------------------------------------------------
# shared-memory ring plumbing
# ---------------------------------------------------------------------------


def test_shm_ring_attach_aliases_creator_mapping():
    ring = ShmRing.create_shared(n_slots=4, payload_bytes=256)
    try:
        other = ShmRing.attach(ring.shm_name, 4, 256)
        ring.write_req(2, b"hello-over-shm")
        ring.status[2] = REQ_READY
        assert other.read_req(2) == b"hello-over-shm"  # same bytes
        assert int(other.status[2]) == REQ_READY
        other.write_resp(2, b"answer")
        other.status[2] = RESP_READY
        assert ring.read_resp(2) == b"answer"
        other.close()  # attacher close never unlinks
        assert not _segment_gone(ring.shm_name)
    finally:
        ring.close()
    assert _segment_gone(ring.shm_name)  # creator close unlinks


def test_shared_pool_meta_sees_parent_mutations():
    pool = _pool()
    spec = pool.share_meta()
    view = SharedPoolMeta(spec["shm_name"], spec["n_blocks"], spec["block_tokens"])
    try:
        blocks = pool.allocate(4)
        eps = pool.write_blocks(blocks)
        assert np.asarray(view.validate_epochs(blocks, eps), bool).all()
        assert view.refcounts[blocks[0]] == 1
        pool.release([blocks[1]])  # epoch bump must be visible
        assert not view.validate_epoch(blocks[1], eps[1])
        view.release(blocks)  # deferred no-op: parent state untouched
        assert pool.refcounts[blocks[0]] == 1
    finally:
        view.close()
        pool.unshare_meta()
    assert _segment_gone(spec["shm_name"])
    # the pool keeps working on private arrays after unshare
    more = pool.allocate(2)
    assert pool.validate_epochs(more, pool.write_blocks(more)).all()


# ---------------------------------------------------------------------------
# torture: fuzz, slow child, killed child
# ---------------------------------------------------------------------------


def test_fuzz_frames_through_real_shared_memory():
    """Garbage through the actual segment: every malformed frame comes
    back as an in-band RpcError, the service process survives, and
    well-formed traffic flows before/between/after."""
    pool = _pool()
    srv, client = _server(pool, n_slots=8, payload_bytes=4096)
    proxy = wire.RpcIndexClient(client, block_tokens=16)
    rng = random.Random(99)
    try:
        tokens = list(range(160))
        keys = proxy.keys_for(tokens)
        blocks = pool.allocate(len(keys))
        proxy.publish_many(list(keys), blocks, pool.write_blocks(blocks), 16)
        good = wire.encode_match(list(keys))
        frames = [
            b"",
            bytes([99, 0, 0, 0, 0]),            # unknown op
            good[:3],                            # truncated header
            good[: len(good) - 7],               # truncated body
            wire.encode_match([b"k" * 16]) * 2,  # trailing garbage is data
            bytes([wire.OP_MATCH]) + (10**6).to_bytes(4, "little"),  # huge n
        ] + [rng.randbytes(rng.randint(1, 120)) for _ in range(20)]
        errors = 0
        for frame in frames:
            try:
                client.call(frame, timeout=5)
            except RpcError:
                errors += 1
        assert errors >= len(frames) - 1  # trailing-garbage one may pass
        assert srv.alive()
        assert client.stats.errors == errors
        # the index behind the fuzz is untouched and still serves
        assert [b for _, b, _ in proxy.match_prefix(tokens)] == blocks
    finally:
        srv.close()
        pool.unshare_meta()


def test_slow_service_process_timeout_quarantine_and_recovery():
    """A slow CHILD (handler_delay) exhausts the slots via timeout
    quarantine; once it catches up the slots are reclaimed and traffic
    recovers — same guarantees as the thread transport, across a real
    process boundary."""
    pool = _pool()
    srv, client = _server(
        pool, n_slots=2, payload_bytes=4096, handler_delay=0.25
    )
    proxy = wire.RpcIndexClient(client, block_tokens=16)
    try:
        keys = [b"\x01" * 16]
        for _ in range(2):
            with pytest.raises(TimeoutError):
                client.call(wire.encode_match(keys), timeout=0.05)
        assert client.stats.timeouts == 2
        assert client.free_slots() == 0  # both slots quarantined
        with pytest.raises(RuntimeError, match="no free RPC slots"):
            client.call(wire.encode_match(keys))
        # wait for the child to answer the stale requests, then reclaim
        deadline = time.time() + 10
        while srv.served < 2 and time.time() < deadline:
            time.sleep(0.02)
        assert srv.served >= 2
        hits = proxy.match_prefix_keys(keys)  # acquires -> reclaims slot
        assert hits == []
        assert client.free_slots() >= 1
        assert srv.alive()
    finally:
        srv.close()
        pool.unshare_meta()


def test_killed_service_process_raises_fast_not_deadlock():
    pool = _pool()
    srv, client = _server(pool, n_slots=4, payload_bytes=4096)
    proxy = wire.RpcIndexClient(client, block_tokens=16)
    try:
        tokens = list(range(64))
        keys = proxy.keys_for(tokens)
        blocks = pool.allocate(len(keys))
        proxy.publish_many(list(keys), blocks, pool.write_blocks(blocks), 16)
        assert len(proxy.match_prefix(tokens)) == 4
        srv.kill()  # ungraceful: no drain, no reply to anything in flight
        t0 = time.perf_counter()
        with pytest.raises(RpcError, match="died"):
            # generous timeout ON PURPOSE: liveness detection must beat it
            client.call(wire.encode_match(list(keys)), timeout=30)
        assert time.perf_counter() - t0 < 5.0  # fast failure, not a hang
        assert client.stats.errors == 1
        # an already-POSTED slot fails the same way
        slot = client.post(wire.encode_match(list(keys)))
        with pytest.raises(RpcError, match="died"):
            client.collect(slot, timeout=30)
        assert client.stats.errors == 2
    finally:
        srv.close()
        pool.unshare_meta()


def test_sharded_process_fanout_with_one_dead_shard():
    """Sharded front over process rings: killing ONE shard's service
    fails the fan-out with an accounted error while the other shard
    stays serviceable."""
    pool = _pool()
    spec = pool.share_meta()
    servers = [
        ProcessRpcServer(spec, n_slots=4, payload_bytes=1 << 14).start()
        for _ in range(2)
    ]
    clients = [
        CxlRpcClient(s.ring, liveness=s.alive) for s in servers
    ]
    proxy = wire.ShardedRpcIndexClient(
        clients, LAYOUT.block_tokens, on_freed=pool.release
    )
    try:
        tokens = list(range(24 * 16))
        keys = proxy.keys_for(tokens)
        blocks = pool.allocate(len(keys))
        proxy.publish_many(list(keys), blocks, pool.write_blocks(blocks), 16)
        assert [b for _, b, _ in proxy.match_prefix(tokens)] == blocks
        servers[1].kill()
        with pytest.raises(RpcError, match="died"):
            proxy.match_prefix_keys(keys)
        assert clients[1].stats.errors >= 1
        # the surviving shard still answers its own sub-chain
        from repro.core.index import partition_keys

        kl0 = partition_keys(keys, 2)[0][0]
        assert len(proxy.shards[0].match_prefix_keys(kl0)) == len(kl0)
    finally:
        for s in servers:
            s.close()
        pool.unshare_meta()


# ---------------------------------------------------------------------------
# cluster integration: parity + lifecycle hygiene
# ---------------------------------------------------------------------------


def _run_small_cluster(**kw):
    with Cluster(
        ClusterConfig(
            n_engines=2, pool_blocks=2048, hbm_slots_per_engine=256,
            index_rpc_slots=8, **kw,
        ),
        LAYOUT,
    ) as c:
        base = list(range(512))
        for i in range(8):
            c.dispatch(Request(f"r{i}", base, 8, 0.0))
        s1 = c.run()
        t0 = max(e.clock for e in c.engines)
        tail = [Request(f"h{i}", base, 8, t0) for i in range(4)]
        for r in tail:
            c.dispatch(r)
        s2 = c.run()
        assert all(r.hit_tokens > 0 for r in tail)
        served = [srv.served for srv in c._rpc_servers]
        return s1, s2, served


def test_cluster_process_transport_reproduces_thread_stats():
    """Acceptance: index_transport='process' (S=1 and S=4) reproduces the
    thread-transport virtual-time summary stats EXACTLY — the transport
    changes where the service runs, never what it answers."""
    for shards in (1, 4):
        thr = _run_small_cluster(index_rpc=True, index_shards=shards)
        prc = _run_small_cluster(
            index_rpc=True, index_shards=shards, index_transport="process"
        )
        assert prc[:2] == thr[:2], shards
        assert len(prc[2]) == shards and all(n > 0 for n in prc[2])


def test_cluster_process_transport_config_validation():
    with pytest.raises(ValueError, match="requires index_rpc"):
        Cluster(ClusterConfig(n_engines=1, index_transport="process"), LAYOUT)
    with pytest.raises(ValueError, match="thread.*process"):
        Cluster(
            ClusterConfig(n_engines=1, index_rpc=True, index_transport="smoke"),
            LAYOUT,
        )
def test_cluster_tiering_over_process_transport_runs_and_tears_down():
    """Gate lifted: a tiered pool rides process transport like a flat one
    — the concatenated metadata segment feeds the shard services, hits
    land, and every segment is unlinked on exit."""
    from repro.tiering import TieringConfig

    c = Cluster(
        ClusterConfig(
            n_engines=1, pool_blocks=64, hbm_slots_per_engine=32,
            index_rpc=True, index_shards=2, index_rpc_slots=8,
            index_transport="process",
            tiering=TieringConfig(enabled=True, spill_blocks=64),
        ),
        LAYOUT,
    )
    names = c.shm_segment_names()
    assert len(names) == 3  # concatenated pool meta + one ring per shard
    try:
        base = list(range(64))
        for i in range(4):
            c.dispatch(Request(f"t{i}", base, 4, 0.05 * i))
        stats = c.run()
        assert stats["index"]["hits"] > 0
        assert stats["tiering"]["fast_writes"] > 0
    finally:
        c.close()
    for n in names:
        assert _segment_gone(n), n


def test_cluster_releases_every_segment_on_exit():
    c = Cluster(
        ClusterConfig(
            n_engines=1, pool_blocks=1024, hbm_slots_per_engine=64,
            index_rpc=True, index_shards=2, index_rpc_slots=8,
            index_transport="process",
        ),
        LAYOUT,
    )
    names = c.shm_segment_names()
    assert len(names) == 3  # pool meta + one ring per shard
    assert all(not _segment_gone(n) for n in names)
    c.close()
    assert c.shm_segment_names() == []
    for n in names:
        assert _segment_gone(n), n
    c.close()  # idempotent


def test_cluster_mid_construction_failure_leaks_nothing(monkeypatch):
    """An exception AFTER the segments exist (engine construction) must
    still unlink them all and reap the service processes."""
    created: list = []
    real_init = ProcessRpcServer.__init__

    def recording_init(self, *a, **kw):
        real_init(self, *a, **kw)
        created.append(self)

    monkeypatch.setattr(ProcessRpcServer, "__init__", recording_init)

    def boom(self, engine_id):
        raise RuntimeError("engine construction failed")

    monkeypatch.setattr(Cluster, "_make_engine", boom)
    with pytest.raises(RuntimeError, match="engine construction"):
        Cluster(
            ClusterConfig(
                n_engines=2, pool_blocks=1024, hbm_slots_per_engine=64,
                index_rpc=True, index_shards=2, index_rpc_slots=8,
                index_transport="process",
            ),
            LAYOUT,
        )
    assert len(created) == 2  # the failure really happened downstream
    for srv in created:
        assert _segment_gone(srv.spec.ring_name)
        assert _segment_gone(srv.spec.pool_shm_name)
        assert not srv.alive()


# ---------------------------------------------------------------------------
# CI smoke: exp11 process transport under a HARD timeout
# ---------------------------------------------------------------------------


def test_exp11_process_transport_smoke_under_hard_timeout():
    """Runs the exp11 thread-vs-process sweep machinery (tiny config) in
    a subprocess with a hard kill-timeout: a hung service child fails
    this test in bounded time instead of stalling the whole workflow —
    the same guard the CI smoke leg relies on."""
    code = (
        "from benchmarks.exp11_rpc import shard_sweep\n"
        "for transport in ('thread', 'process'):\n"
        "    cells = shard_sweep(512, fast=True, transport=transport,\n"
        "                        shard_counts=(1, 2))\n"
        "    assert [c['n_shards'] for c in cells] == [1, 2], cells\n"
        "    assert all(c['wall_keys_per_s'] > 0 for c in cells)\n"
        "    assert all(c['errors'] == 0 and c['timeouts'] == 0 for c in cells)\n"
        "print('SMOKE-PASS')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))
        ),
        timeout=180,  # HARD guard: hung child == fast failure
    )
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-3000:])
    assert "SMOKE-PASS" in out.stdout


# ---------------------------------------------------------------------------
# diag counters: tolerated teardown failures are counted, not invisible
# (the exception-hygiene contract of beluga-lint, PR 9)
# ---------------------------------------------------------------------------
def test_close_segment_failure_bumps_diag_counter():
    from repro.core import diag
    from repro.core.shm import close_segment

    class ExplodingSeg:
        def close(self):
            raise RuntimeError("torn down twice")

        def unlink(self):
            raise RuntimeError("gone")

    diag.reset()
    close_segment(ExplodingSeg(), unlink=True)  # must not raise
    assert diag.count("shm.close_segment.close_failed") == 1
    assert diag.count("shm.close_segment.unlink_failed") == 1
    # idempotent hygiene: None is a no-op, counters untouched
    close_segment(None, unlink=True)
    assert diag.count("shm.close_segment.close_failed") == 1

"""Fault-tolerant DATA plane (ISSUE 8): engine-worker supervision, lease
reconciliation, selfheal cutover into workers, allocator rolling restart.

Layered like the feature:

  * ``WorkerLeaseLedger``       — the parent-held retained-block ledger
    and its epoch-validity reconcile rules (release vs keep vs skip);
  * ``EngineWorkerSupervisor``  — kill -9 -> detect -> reconcile leases
    -> respawn on a fresh command ring -> replay un-acked submits;
  * chaos differential gates    — kill -9 a worker before/mid drain and
    the run converges with the no-fault supervised reference (the merge
    gate: free-block count + summary stats);
  * shard kill WHILE workers are attached — the ring-generation cutover
    travels over the worker command codec (WCMD_ADOPT) so the respawned
    shard serves workers again;
  * allocator rolling restart   — ``Cluster.restart_allocator`` moves
    the allocator ring under live workers with zero request loss;
  * RESULTS-page kill          — the host surfaces a retryable error
    in bounded time (no hang, no partial-decode crash), leaks nothing.
"""

from __future__ import annotations

import os
import threading
import time

import numpy as np
import pytest

from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.rpc import ServiceDiedError
from repro.core.shmpool import WorkerLeaseLedger
from repro.serving.request import Request
from repro.serving.scheduler import Cluster, ClusterConfig

LAYOUT = PoolLayout(
    block_tokens=8, n_layers_kv=2, n_kv_heads=2, head_dim=8, dtype_bytes=2
)


def _segment_gone(name: str) -> bool:
    from multiprocessing import shared_memory

    try:
        seg = shared_memory.SharedMemory(name=name)
    except FileNotFoundError:
        return True
    seg.close()
    return False


def _workload(n: int = 16):
    rng = np.random.default_rng(7)
    base = rng.integers(0, 1000, 64).tolist()
    out = []
    for i in range(n):
        toks = (
            base + rng.integers(0, 1000, 24).tolist()
            if i % 2
            else rng.integers(0, 1000, 80).tolist()
        )
        out.append((f"r{i}", [int(t) for t in toks], 8, i * 0.03))
    return out


def _chaos_cluster(**kw) -> Cluster:
    cfg = ClusterConfig(
        n_engines=kw.pop("n_engines", 2),
        engine_processes=kw.pop("engine_processes", 2),
        policy="round_robin", pool_blocks=512, pool_shards=4,
        hbm_slots_per_engine=64, block_tokens=8, index_rpc=True,
        index_transport="process", index_shards=kw.pop("index_shards", 1),
        data_plane="shared", selfheal=True, journal_capacity=2048,
        supervisor_probe_interval=0.01, **kw,
    )
    return Cluster(cfg, LAYOUT, backing="numpy")


def _hygiene(names, paths):
    for n in names:
        assert _segment_gone(n), n
    for p in paths:
        assert not os.path.exists(p), p


# ---------------------------------------------------------------------------
# WorkerLeaseLedger: epoch-validity reconcile rules
# ---------------------------------------------------------------------------
def test_lease_ledger_reconcile_epoch_rules():
    """Release exactly the refs a dead worker still held:
      * allocated, never written (epoch == grant)        -> release;
      * written + published (index owns (b, grant+1))    -> keep, the
        alloc-ref transferred to the index at publish;
      * written, never published (committed, unowned)    -> release;
      * epoch advanced past grant+1 (freed + recycled)   -> skip
        (leak-not-corrupt: never free under a new owner)."""
    pool = BelugaPool(LAYOUT, n_blocks=64, n_shards=4, backing="meta")
    idx = GlobalIndex(pool)
    led = WorkerLeaseLedger()

    a, b, c, d = pool.allocate(4)
    led.on_alloc(0, [a, b, c, d], pool)
    # b: written and published -> its row is index-owned at grant+1,
    # which a live worker mirrors by clearing the lease at publish time
    # (ledger.on_publish); here the worker "dies" before that message,
    # so reconcile must reach the same verdict via owners_of
    [eb] = pool.write_blocks([b])
    idx.publish_many([b"k" * 16], [b], [eb], 8)
    # c: written, never published
    pool.write_blocks([c])
    # d: released by the worker pre-crash, recycled to another owner
    led.on_release(0, [d])
    pool.release([d])
    [d2] = pool.allocate(1)
    assert d2 == d
    led.on_alloc(1, [d2], pool)  # now worker 1's lease

    free0 = pool.free_blocks()
    summary = led.reconcile(0, pool, owners_of=idx.owners_of)
    # a and c released; b kept (index-owned); d not in worker 0's leases
    assert summary["released"] == 2
    assert sorted(summary["blocks"]) == sorted([a, c])
    assert b in summary["kept"]
    assert pool.free_blocks() == free0 + 2
    assert int(pool.refcounts[b]) == 1  # the index's ref, untouched
    assert int(pool.refcounts[d]) == 1  # worker 1's ref, untouched
    # exactly-once: a second reconcile finds nothing
    again = led.reconcile(0, pool, owners_of=idx.owners_of)
    assert again["released"] == 0 and again["skipped"] == 0


def test_lease_ledger_publish_clears_lease_and_release_tolerates_unknown():
    pool = BelugaPool(LAYOUT, n_blocks=32, n_shards=4, backing="meta")
    led = WorkerLeaseLedger()
    ids = pool.allocate(2)
    led.on_alloc(0, ids, pool)
    led.on_publish(0, [ids[0]])  # alloc-ref transferred to the index
    assert list(led.leases(0)) == [ids[1]]
    # workers route INDEX-owned eviction releases through their ring;
    # those ids were never this worker's lease — must not underflow
    led.on_release(0, [ids[0], ids[0], 999])
    assert list(led.leases(0)) == [ids[1]]


# ---------------------------------------------------------------------------
# chaos differential: worker kill -9 (the merge gate)
# ---------------------------------------------------------------------------
def test_worker_kill_between_submits_converges_with_no_fault_run():
    """Kill -9 a worker after half the submits landed, before the drain:
    the supervisor detects the death at the next submit, respawns the
    worker on a fresh command ring and replays its un-acked ledger — the
    run's FINAL observables (summary stats, free-block count, per-request
    timings) converge with the no-fault supervised reference."""
    work = _workload()
    with _chaos_cluster() as ref:
        for rid, toks, nout, arr in work:
            ref.dispatch(Request(rid, toks, nout, arrival=arr))
        want = ref.run()
        ref_free = ref.pool.free_blocks()
    with _chaos_cluster() as c:
        half = len(work) // 2
        for rid, toks, nout, arr in work[:half]:
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        c.workers[0].kill()  # SIGKILL mid-stream, submits un-acked
        for rid, toks, nout, arr in work[half:]:
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        got = c.run()
        assert c.workers[0].restarts == 1
        assert all(r.state == "done" for r in c.requests)
        assert all(r.t_done is not None for r in c.requests)
        assert c.pool.free_blocks() == ref_free
        names, paths = c.shm_segment_names(), c.doorbell_paths()
    # the fault is INVISIBLE in the summary: the respawned engine
    # replayed every submit into the same deterministic virtual-time
    # sim the reference ran
    for k in ("n_done", "hit_tokens", "total_prompt_tokens", "avg_ttft_s",
              "avg_tpot_s", "pool_free"):
        assert got[k] == want[k], k
    assert got["selfheal"]["worker_restarts"] == 1
    _hygiene(names, paths)


def test_worker_kill_mid_drain_reconciles_leases_and_converges():
    """SIGKILL while the drain is RUNNING: the worker dies holding pool
    leases (allocated/written blocks not yet published).  collect_run
    heals — reconcile releases the dead worker's leases exactly once —
    and re-runs on the respawned worker; block conservation pins that
    nothing leaked and nothing was double-freed."""
    work = _workload()
    with _chaos_cluster() as ref:
        for rid, toks, nout, arr in work:
            ref.dispatch(Request(rid, toks, nout, arrival=arr))
        ref.run()
        ref_free = ref.pool.free_blocks()
    with _chaos_cluster() as c:
        for rid, toks, nout, arr in work:
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        killer = threading.Timer(0.02, c.workers[0].kill)
        killer.start()
        stats = c.run()
        killer.cancel()
        if c.workers[0].restarts == 0:
            # the drain finished before the timer fired on a slow box:
            # kill now and drive one more (empty) run through recovery
            c.workers[0].kill()
            c.workers[0].check()
            time.sleep(0.05)
            c.workers[0].check()
            stats = c.run()
        assert c.workers[0].restarts >= 1
        assert stats["n_done"] == len(work)
        assert all(r.state == "done" for r in c.requests)
        # conservation: mid-flight leases were released exactly once —
        # a leak would leave free_blocks short, a double free trips the
        # pool's own refcount assertions long before this line
        assert c.pool.free_blocks() == ref_free
        recs = [r for r in c.workers[0].reconciled if r is not None]
        assert recs, "lease reconciliation never ran"
        names, paths = c.shm_segment_names(), c.doorbell_paths()
    _hygiene(names, paths)


# ---------------------------------------------------------------------------
# metadata-shard kill while workers are attached (cutover INTO workers)
# ---------------------------------------------------------------------------
def test_shard_kill_with_attached_workers_cuts_over_and_serves():
    """Kill -9 the metadata shard under live workers: the supervisor
    respawns it on a FRESH ring and the registered cutover forwarders
    ADOPT every worker's in-process client over the command ring — the
    next run publishes and matches against the new generation."""
    work = _workload()
    with _chaos_cluster() as ref:
        for rid, toks, nout, arr in work:
            ref.dispatch(Request(rid, toks, nout, arrival=arr))
        ref.run()
        ref_free = ref.pool.free_blocks()
    with _chaos_cluster() as c:
        half = len(work) // 2
        for rid, toks, nout, arr in work[:half]:
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        c.run()
        sup = c._supervisors[0]
        sup.kill()
        deadline = time.monotonic() + 10.0
        while sup.restarts == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert sup.restarts == 1, "shard crash never healed"
        # ``restarts`` bumps BEFORE the worker ADOPTs fan out (it must:
        # the flap cap counts stillborn attempts too); check() takes the
        # supervisor lock, so returning from it means the in-progress
        # restart — including every forwarded cutover — completed.
        # Without the barrier phase 2 can race into the degraded window
        # and (correctly) release-instead-of-publish, which diverges
        # from the no-fault reference this test pins equality against.
        sup.check()
        for rid, toks, nout, arr in work[half:]:
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        stats = c.run()
        assert stats["selfheal"]["restarts"] == 1
        assert stats["selfheal"]["worker_restarts"] == 0  # workers lived
        assert all(r.state == "done" for r in c.requests)
        # the journal replay (incl. worker publishes proxied over the
        # allocator ring) conserved every block
        assert c.pool.free_blocks() == ref_free
        names, paths = c.shm_segment_names(), c.doorbell_paths()
    _hygiene(names, paths)


# ---------------------------------------------------------------------------
# allocator rolling restart (kill_allocator recovery drill)
# ---------------------------------------------------------------------------
def test_allocator_rolling_restart_is_invisible_to_workers():
    work = _workload()
    with _chaos_cluster() as ref:
        for rid, toks, nout, arr in work:
            ref.dispatch(Request(rid, toks, nout, arrival=arr))
        ref.run()
        ref_free = ref.pool.free_blocks()
    with _chaos_cluster() as c:
        half = len(work) // 2
        for rid, toks, nout, arr in work[:half]:
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        c.run()
        old_ring_name = c._pool_ring.shm_name
        c.restart_allocator()
        assert c._pool_ring.shm_name != old_ring_name
        for rid, toks, nout, arr in work[half:]:
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        stats = c.run()
        assert stats["selfheal"]["allocator_restarts"] == 1
        assert stats["selfheal"]["worker_restarts"] == 0
        assert stats["n_done"] == len(work)
        assert all(r.state == "done" for r in c.requests)
        assert c.pool.free_blocks() == ref_free
        names, paths = c.shm_segment_names(), c.doorbell_paths()
    _hygiene(names, paths)


# ---------------------------------------------------------------------------
# kill during a pending RESULTS page (satellite): retryable, leak-free
# ---------------------------------------------------------------------------
def test_results_page_kill_surfaces_retryable_error_in_bounded_time():
    """A worker killed -9 with a RESULTS page pending must surface
    ``ServiceDiedError`` (retryable) to the host within the liveness
    probe's bound — never a hang on a dead slot or a partial-decode
    crash — and its close() still unlinks segment + FIFO."""
    from repro.core.wire import WireError  # noqa: F401 — must NOT be raised

    with _chaos_cluster(n_engines=1, engine_processes=1) as c:
        for rid, toks, nout, arr in _workload(4):
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        c.run()
        sup = c.workers[0]
        host = sup.host
        # crash the worker, then leave a RESULTS page pending against the
        # dead process (the worker can also die between post and serve;
        # either way the slot never turns RESP_READY)
        host.proc.kill()
        host.proc.join(timeout=5)
        import struct

        slot = host.client.post(struct.pack("<BII", 3, 0, 1 << 20))
        t0 = time.monotonic()
        with pytest.raises(ServiceDiedError):
            host.client.collect(slot, timeout=30.0)
        assert time.monotonic() - t0 < 5.0  # bounded, not the timeout
        # the SUPERVISED surface rides the same failure through heal +
        # replay: results come back from the respawned generation
        sup.check()
        time.sleep(sup.grace + 0.05)
        sup.check()
        assert sup.restarts == 1
        sup.apply_results(c.requests)
        names, paths = c.shm_segment_names(), c.doorbell_paths()
    _hygiene(names, paths)


# ---------------------------------------------------------------------------
# supervisor unit behavior
# ---------------------------------------------------------------------------
def test_worker_supervisor_replays_only_unacked_submits():
    """Requests already seen done (acked via apply_results) must NOT be
    replayed — only the un-acked ledger rides into the new generation."""
    with _chaos_cluster(n_engines=1, engine_processes=1) as c:
        work = _workload(8)
        for rid, toks, nout, arr in work[:4]:
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        c.run()  # phase 1 done + acked -> pruned from the ledger
        sup = c.workers[0]
        assert sup.load() == 0
        for rid, toks, nout, arr in work[4:]:
            c.dispatch(Request(rid, toks, nout, arrival=arr))
        assert sup.load() == 4
        sup.kill()
        # next submit path heals; drive it via a run instead
        stats = c.run()
        assert sup.restarts == 1
        assert stats["n_done"] == 8  # parent folds BOTH phases
        # the respawned worker only ever saw the 4 replayed requests
        assert sup.host.n_submitted == 4
        assert sup.load() == 0


# ---------------------------------------------------------------------------
# client slot hygiene on a dead ring (the worker-partition hazard)
# ---------------------------------------------------------------------------
def test_dead_ring_retries_cannot_exhaust_a_narrow_slot_partition():
    """Fail-fast retries against a dead service must not burn slots.

    Engine workers own a NARROW slot range of each shared metadata ring.
    A dead service quarantines every slot its caller gave up on — but a
    dead ring has no writer left, so those slots are reclaimable.  A
    worker that keeps degrading ops while its WCMD_ADOPT cutover is
    still queued behind the in-flight RUN must see ServiceDiedError on
    every attempt, never 'no free RPC slots (QD exceeded)'."""
    from repro.core.rpc import CxlRpcClient, ShmRing

    ring = ShmRing(n_slots=8, payload_bytes=64)
    client = CxlRpcClient(ring, liveness=lambda: False, slot_range=(0, 3))
    for _ in range(12):  # 4x the partition width
        with pytest.raises(ServiceDiedError):
            client.call(b"\x01ping", timeout=1.0)
    assert client.free_slots() >= 2  # partition reclaimed, not bled dry


# ---------------------------------------------------------------------------
# lock-discipline regressions surfaced by beluga-lint (PR 9)
# ---------------------------------------------------------------------------
def test_reconcile_probes_index_with_mutex_dropped():
    """The ``owners_of`` probe is a metadata-plane RPC: holding
    ``ledger.mutex`` across it would stall every live worker's
    ALLOC/RELEASE for the probe's latency (the L003 finding this PR
    fixed).  The probe callback must observe the mutex RELEASED."""
    pool = BelugaPool(LAYOUT, n_blocks=32, n_shards=4, backing="meta")
    idx = GlobalIndex(pool)
    led = WorkerLeaseLedger()
    blocks = pool.allocate(3)
    led.on_alloc(0, blocks, pool)
    [eb] = pool.write_blocks(blocks[:1])
    idx.publish_many([b"r" * 16], blocks[:1], [eb], 8)

    seen = {}

    def probing_owners_of(ids):
        seen["mutex_held"] = led.mutex.locked()
        return idx.owners_of(ids)

    summary = led.reconcile(0, pool, owners_of=probing_owners_of)
    assert seen == {"mutex_held": False}, "probe ran under the mutex"
    assert blocks[0] in summary["kept"]


def test_journal_publish_clears_lease_under_ledger_mutex():
    """``handle_journal_request`` runs on the allocator service thread
    while reconcile mutates the same per-worker lease dict from the
    parent main thread: the publish-side lease clear must hold
    ``ledger.mutex`` (the race beluga-lint's graph review surfaced)."""
    from repro.core import wire
    from repro.core.shm import ShardJournal

    pool = BelugaPool(LAYOUT, n_blocks=32, n_shards=4, backing="meta")
    led = WorkerLeaseLedger()
    blocks = pool.allocate(2)
    led.on_alloc(0, blocks, pool)
    jrnl = ShardJournal.create(capacity=16)
    try:
        held_at_clear = []
        real = led.on_publish

        def spying_on_publish(worker, ids):
            held_at_clear.append(led.mutex.locked())
            return real(worker, ids)

        led.on_publish = spying_on_publish
        frame = wire.encode_jrnl_publish(
            0, [b"j" * 16] * 2, blocks, [1, 1], 8
        )
        wire.handle_journal_request(frame, [jrnl], ledger=led, worker=0)
        assert held_at_clear == [True], "lease clear ran outside the mutex"
        # and the lease is actually gone
        assert not led.leases(0)
    finally:
        jrnl.close()
